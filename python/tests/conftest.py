"""Test configuration: enable f64 so the jnp oracle can be checked
against scipy at double precision (the kernels themselves are exercised
in f32, as deployed)."""

import jax

jax.config.update("jax_enable_x64", True)
