"""Pallas kernels vs the pure-jnp oracle (and scipy where applicable).

This is the CORE correctness signal for the L1 layer: everything the
rust runtime executes was lowered from exactly these functions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import scipy.linalg

from compile.kernels import ebv_step, lu_factor, ref, spmv, trisolve


def dominant_matrix(n, seed, dtype=np.float64):
    """Diagonally dominant random system (the paper's Eq. 2 setting)."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1.0, 1.0, size=(n, n))
    np.fill_diagonal(a, 0.0)
    diag = np.abs(a).sum(axis=1) + rng.uniform(1.0, 2.0, size=n)
    np.fill_diagonal(a, diag)
    return a.astype(dtype)


# ---------------------------------------------------------------------------
# reference oracle vs scipy (the oracle itself must be right)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 8, 17, 32])
def test_ref_factor_matches_scipy(n):
    a = dominant_matrix(n, seed=n)
    packed = np.asarray(ref.lu_factor_ref(jnp.asarray(a)))
    l = np.tril(packed, -1) + np.eye(n)
    u = np.triu(packed)
    np.testing.assert_allclose(l @ u, a, rtol=0, atol=1e-9)


@pytest.mark.parametrize("n", [2, 8, 31])
def test_ref_solve_matches_scipy(n):
    a = dominant_matrix(n, seed=100 + n)
    b = np.random.default_rng(n).uniform(-1, 1, n)
    x = np.asarray(ref.lu_solve_ref(jnp.asarray(a), jnp.asarray(b)))
    expected = scipy.linalg.solve(a, b)
    np.testing.assert_allclose(x, expected, rtol=0, atol=1e-8)


def test_fold_permutation_structure():
    p = np.asarray(ref.fold_permutation(6))
    np.testing.assert_array_equal(p, [0, 5, 1, 4, 2, 3])
    p = np.asarray(ref.fold_permutation(5))
    np.testing.assert_array_equal(p, [0, 4, 1, 3, 2])
    # Always a permutation.
    for n in (1, 2, 9, 16):
        assert sorted(np.asarray(ref.fold_permutation(n)).tolist()) == list(range(n))


# ---------------------------------------------------------------------------
# pallas kernels vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 8, 16, 33, 64])
def test_lu_factor_kernel_matches_ref(n):
    a = jnp.asarray(dominant_matrix(n, seed=n, dtype=np.float32))
    got = lu_factor.lu_factor(a)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 32, 64])
def test_trisolve_kernel_matches_ref(n):
    a = jnp.asarray(dominant_matrix(n, seed=n, dtype=np.float32))
    b = jnp.asarray(np.random.default_rng(n).uniform(-1, 1, n).astype(np.float32))
    lu = ref.lu_factor_ref(a)
    got = trisolve.trisolve(lu, b)
    want = ref.backward_ref(lu, ref.forward_ref(lu, b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-4)


@pytest.mark.parametrize("n", [4, 8, 16, 32])
def test_ebv_step_grid_factorization_matches_ref(n):
    """The fold-paired grid path computes the same factors."""
    a = jnp.asarray(dominant_matrix(n, seed=7 * n, dtype=np.float32))
    got = ebv_step.lu_factor_stepped(a)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-4)


def test_spmv_kernel_matches_ref_and_dense():
    n, k = 32, 4
    rng = np.random.default_rng(3)
    dense = np.zeros((n, n), dtype=np.float32)
    values = np.zeros((n, k), dtype=np.float32)
    cols = -np.ones((n, k), dtype=np.int32)
    for i in range(n):
        w = rng.integers(0, k + 1)
        picked = rng.choice(n, size=w, replace=False)
        for slot, j in enumerate(sorted(picked)):
            v = rng.uniform(-1, 1)
            values[i, slot] = v
            cols[i, slot] = j
            dense[i, j] = v
    x = rng.uniform(-1, 1, n).astype(np.float32)
    got = spmv.spmv_ell(jnp.asarray(values), jnp.asarray(cols), jnp.asarray(x))
    want = ref.spmv_ell_ref(jnp.asarray(values), jnp.asarray(cols), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=0, atol=1e-5)


def test_spmv_blocked_grid_matches_whole_array():
    n, k = 64, 5
    rng = np.random.default_rng(4)
    values = rng.uniform(-1, 1, (n, k)).astype(np.float32)
    cols = rng.integers(0, n, (n, k)).astype(np.int32)
    x = rng.uniform(-1, 1, n).astype(np.float32)
    whole = spmv.spmv_ell(jnp.asarray(values), jnp.asarray(cols), jnp.asarray(x))
    blocked = spmv.spmv_ell(
        jnp.asarray(values), jnp.asarray(cols), jnp.asarray(x), block_rows=16
    )
    np.testing.assert_allclose(np.asarray(whole), np.asarray(blocked), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, seeds, dtypes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=2, max_value=24), seed=st.integers(0, 2**16))
def test_prop_factor_reconstructs(n, seed):
    a = dominant_matrix(n, seed=seed, dtype=np.float32)
    packed = np.asarray(lu_factor.lu_factor(jnp.asarray(a)))
    l = np.tril(packed, -1).astype(np.float64) + np.eye(n)
    u = np.triu(packed).astype(np.float64)
    np.testing.assert_allclose(l @ u, a, rtol=0, atol=5e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=2, max_value=24), seed=st.integers(0, 2**16))
def test_prop_solve_residual_small(n, seed):
    a = dominant_matrix(n, seed=seed, dtype=np.float32)
    b = np.random.default_rng(seed).uniform(-1, 1, n).astype(np.float32)
    lu = lu_factor.lu_factor(jnp.asarray(a))
    x = np.asarray(trisolve.trisolve(lu, jnp.asarray(b)))
    residual = np.max(np.abs(a.astype(np.float64) @ x - b))
    assert residual < 1e-3, f"residual={residual}"


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 8, 12, 16]),
    seed=st.integers(0, 2**16),
)
def test_prop_fold_grid_equals_fused_kernel(n, seed):
    a = dominant_matrix(n, seed=seed, dtype=np.float32)
    stepped = np.asarray(ebv_step.lu_factor_stepped(jnp.asarray(a)))
    fused = np.asarray(lu_factor.lu_factor(jnp.asarray(a)))
    np.testing.assert_allclose(stepped, fused, rtol=0, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_prop_spmv_matches_dense(n, k, seed):
    rng = np.random.default_rng(seed)
    values = rng.uniform(-1, 1, (n, k)).astype(np.float32)
    cols = rng.integers(-1, n, (n, k)).astype(np.int32)
    values[cols < 0] = 0.0
    x = rng.uniform(-1, 1, n).astype(np.float32)
    dense = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for s in range(k):
            if cols[i, s] >= 0:
                dense[i, cols[i, s]] += values[i, s]
    got = np.asarray(spmv.spmv_ell(jnp.asarray(values), jnp.asarray(cols), jnp.asarray(x)))
    np.testing.assert_allclose(got, dense @ x, rtol=0, atol=1e-4)
