"""Blocked (MXU-form) LU kernel vs the per-step kernel and the oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import lu_blocked, lu_factor, ref

from .test_kernels import dominant_matrix


@pytest.mark.parametrize("n,nb", [(8, 4), (16, 4), (16, 8), (32, 8), (64, 16)])
def test_blocked_matches_ref(n, nb):
    a = jnp.asarray(dominant_matrix(n, seed=n + nb, dtype=np.float32))
    got = lu_blocked.lu_factor_blocked(a, nb=nb)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=5e-4)


def test_blocked_matches_per_step_kernel():
    n = 32
    a = jnp.asarray(dominant_matrix(n, seed=5, dtype=np.float32))
    blocked = lu_blocked.lu_factor_blocked(a, nb=8)
    per_step = lu_factor.lu_factor(a)
    np.testing.assert_allclose(
        np.asarray(blocked), np.asarray(per_step), rtol=0, atol=5e-4
    )


def test_ragged_final_panel():
    # n not divisible by nb exercises the edge guard.
    n, nb = 20, 8
    a = jnp.asarray(dominant_matrix(n, seed=9, dtype=np.float32))
    got = lu_blocked.lu_factor_blocked(a, nb=nb)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=5e-4)


def test_block_of_one_degenerates_to_per_step():
    n = 12
    a = jnp.asarray(dominant_matrix(n, seed=11, dtype=np.float32))
    got = lu_blocked.lu_factor_blocked(a, nb=1)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([8, 16, 24, 32]),
    nb=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_prop_blocked_reconstructs(n, nb, seed):
    a = dominant_matrix(n, seed=seed, dtype=np.float32)
    packed = np.asarray(lu_blocked.lu_factor_blocked(jnp.asarray(a), nb=nb))
    l = np.tril(packed, -1).astype(np.float64) + np.eye(n)
    u = np.triu(packed).astype(np.float64)
    np.testing.assert_allclose(l @ u, a, rtol=0, atol=1e-3)
