"""L2 model graphs: shapes, batching semantics, and AOT lowering."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

from .test_kernels import dominant_matrix


def test_lu_solve_matches_ref():
    n = 48
    a = jnp.asarray(dominant_matrix(n, seed=1, dtype=np.float32))
    b = jnp.asarray(np.random.default_rng(1).uniform(-1, 1, n).astype(np.float32))
    x = model.lu_solve(a, b)
    want = ref.lu_solve_ref(a, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), rtol=0, atol=1e-3)


def test_batched_solve_matches_loop():
    n, k = 32, 5
    a = jnp.asarray(dominant_matrix(n, seed=2, dtype=np.float32))
    bs = jnp.asarray(np.random.default_rng(2).uniform(-1, 1, (k, n)).astype(np.float32))
    batched = model.lu_solve_batched(a, bs)
    assert batched.shape == (k, n)
    for i in range(k):
        single = model.lu_solve(a, bs[i])
        np.testing.assert_allclose(
            np.asarray(batched[i]), np.asarray(single), rtol=0, atol=1e-4
        )


def test_factor_only_graph():
    n = 24
    a = jnp.asarray(dominant_matrix(n, seed=3, dtype=np.float32))
    packed = model.lu_factor(a)
    assert packed.shape == (n, n)
    want = ref.lu_factor_ref(a)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(want), rtol=0, atol=1e-4)


def test_residual_helper():
    a = jnp.eye(4, dtype=jnp.float32)
    x = jnp.ones(4, dtype=jnp.float32)
    assert float(model.residual_inf(a, x, x)) == 0.0


# ---------------------------------------------------------------------------
# AOT lowering
# ---------------------------------------------------------------------------


def test_to_hlo_text_produces_parseable_module():
    n = 8
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    b = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(model.lu_solve).lower(a, b)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_build_all_writes_manifest_and_files(tmp_path):
    # Shrink the size grid so the test is fast.
    old = (aot.SOLVE_SIZES, aot.FACTOR_SIZES, aot.BATCHED, aot.SPMV_SHAPES)
    aot.SOLVE_SIZES, aot.FACTOR_SIZES = (8,), (8,)
    aot.BATCHED, aot.SPMV_SHAPES = ((8, 2),), ((8, 2),)
    try:
        aot.build_all(str(tmp_path))
    finally:
        aot.SOLVE_SIZES, aot.FACTOR_SIZES, aot.BATCHED, aot.SPMV_SHAPES = old

    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    kinds = {e["kind"] for e in manifest["entries"]}
    assert kinds == {"lu_solve", "lu_factor", "lu_solve_batched", "spmv"}
    for e in manifest["entries"]:
        f = tmp_path / e["file"]
        assert f.exists(), e["file"]
        assert "HloModule" in f.read_text()[:200]
        assert e["inputs"] and e["outputs"]


def test_manifest_shapes_are_consistent():
    """The manifest rows must describe exactly what the graphs take."""
    n, k = 8, 2
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    bs = jax.ShapeDtypeStruct((k, n), jnp.float32)
    out = jax.eval_shape(model.lu_solve_batched, a, bs)
    leaves = jax.tree_util.tree_leaves(out)
    assert [list(o.shape) for o in leaves] == [[k, n]]
