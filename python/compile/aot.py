"""AOT lowering: JAX/Pallas model → HLO text + manifest.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Sizes compiled for the dense solve path. 256 is the largest size that
# keeps interpret-mode CPU execution snappy; on a real TPU the same
# lowering (without interpret) extends to the paper's 16000 range.
SOLVE_SIZES = (32, 64, 128, 256)
FACTOR_SIZES = (64, 128)
BATCHED = ((64, 8), (128, 8))
SPMV_SHAPES = ((256, 8),)

MANIFEST_VERSION = 1


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, args, name, kind, n, batch, out_dir):
    """Lower ``fn(*args)``, write the HLO file, return the manifest row."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    inputs = [list(a.shape) for a in args]
    dtype_name = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}
    input_dtypes = [dtype_name.get(a.dtype, str(a.dtype)) for a in args]
    out = jax.eval_shape(fn, *args)
    outputs = [list(o.shape) for o in jax.tree_util.tree_leaves(out)]
    print(f"  {name}: {len(text)} chars, inputs={inputs} outputs={outputs}")
    return {
        "name": name,
        "kind": kind,
        "n": n,
        "batch": batch,
        "dtype": "f32",
        "input_dtypes": input_dtypes,
        "file": fname,
        "inputs": inputs,
        "outputs": outputs,
    }


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    f32 = jnp.float32
    entries = []

    print("lowering lu_solve:")
    for n in SOLVE_SIZES:
        a = jax.ShapeDtypeStruct((n, n), f32)
        b = jax.ShapeDtypeStruct((n,), f32)
        entries.append(
            lower_entry(model.lu_solve, (a, b), f"lu_solve_n{n}", "lu_solve", n, 1, out_dir)
        )

    print("lowering lu_factor:")
    for n in FACTOR_SIZES:
        a = jax.ShapeDtypeStruct((n, n), f32)
        entries.append(
            lower_entry(model.lu_factor, (a,), f"lu_factor_n{n}", "lu_factor", n, 1, out_dir)
        )

    print("lowering lu_solve_batched:")
    for n, k in BATCHED:
        a = jax.ShapeDtypeStruct((n, n), f32)
        bs = jax.ShapeDtypeStruct((k, n), f32)
        entries.append(
            lower_entry(
                model.lu_solve_batched,
                (a, bs),
                f"lu_solve_n{n}_b{k}",
                "lu_solve_batched",
                n,
                k,
                out_dir,
            )
        )

    print("lowering spmv:")
    for n, k in SPMV_SHAPES:
        vals = jax.ShapeDtypeStruct((n, k), f32)
        cols = jax.ShapeDtypeStruct((n, k), jnp.int32)
        x = jax.ShapeDtypeStruct((n,), f32)
        entries.append(
            lower_entry(model.spmv, (vals, cols, x), f"spmv_n{n}_k{k}", "spmv", n, 1, out_dir)
        )

    manifest = {
        "version": MANIFEST_VERSION,
        "generated_by": "compile.aot",
        "entries": entries,
    }
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(entries)} entries)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
