"""Whole-matrix EBV LU factorization as a single Pallas kernel.

The `(n, n)` system lives in one VMEM block (f32 · 256² = 256 KiB — well
inside a TPU core's ~16 MiB VMEM; DESIGN.md §Perf carries the footprint
table). The elimination loop runs inside the kernel: per step, the
L-column scale (the paper's Eq. 6-a) is one vector op on the VPU lanes
and the rank-1 trailing update (Eq. 6-c) is one masked outer-product
update — the bi-vector pair processed in a single fused sweep.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lu_kernel(a_ref, lu_ref):
    n = a_ref.shape[0]
    lu_ref[...] = a_ref[...]
    idx = jax.lax.iota(jnp.int32, n)

    def step(r, _):
        lu = lu_ref[...]
        piv = jax.lax.dynamic_index_in_dim(jax.lax.dynamic_index_in_dim(lu, r, 0, keepdims=False), r, 0, keepdims=False)
        col = jax.lax.dynamic_index_in_dim(lu, r, 1, keepdims=False)  # column r
        row = jax.lax.dynamic_index_in_dim(lu, r, 0, keepdims=False)  # row r
        below = idx > r
        f = jnp.where(below, col / piv, 0.0)
        # Write the multipliers into column r, then apply the rank-1
        # bi-vector update to the trailing block.
        col_new = jnp.where(below, f, col)
        lu = jax.lax.dynamic_update_index_in_dim(lu, col_new, r, 1)
        row_masked = jnp.where(idx > r, row, 0.0)
        lu_ref[...] = lu - jnp.outer(f, row_masked)
        return 0

    jax.lax.fori_loop(0, n - 1, step, 0)


@functools.partial(jax.jit, static_argnames=())
def lu_factor(a):
    """Packed unpivoted LU of ``a`` via the Pallas kernel."""
    n = a.shape[0]
    return pl.pallas_call(
        _lu_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)
