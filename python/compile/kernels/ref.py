"""Pure-jnp reference implementations (the correctness oracles).

Everything here is written for clarity, not speed: ``pytest`` asserts the
Pallas kernels (and, transitively, the AOT artifacts the rust runtime
executes) against these functions.
"""

import jax
import jax.numpy as jnp


def lu_factor_ref(a):
    """Unpivoted Doolittle LU, packed in one matrix.

    Matches the paper's setting (Eq. 2): diagonally dominant systems,
    no pivoting. Returns ``LU`` with the unit-lower multipliers below
    the diagonal and ``U`` on/above it.
    """
    n = a.shape[0]

    def step(r, lu):
        piv = lu[r, r]
        idx = jnp.arange(n)
        col_mask = idx > r
        f = jnp.where(col_mask, lu[:, r] / piv, 0.0)
        # Store multipliers in column r.
        lu = lu.at[:, r].set(jnp.where(col_mask, f, lu[:, r]))
        # Rank-1 trailing update (Eq. 6-c): rows > r, cols > r.
        row = jnp.where(idx > r, lu[r, :], 0.0)
        return lu - jnp.outer(f, row)

    return jax.lax.fori_loop(0, n - 1, step, a)


def forward_ref(lu, b):
    """Solve ``L y = b`` with the unit lower triangle of packed ``lu``.

    Column-oriented (right-looking): after ``y[j]`` finalizes, apply the
    bi-vector axpy — the paper's Eq. (4-b) reading of the substitution.
    """
    n = lu.shape[0]
    idx = jnp.arange(n)

    def step(j, y):
        yj = y[j]
        col = jnp.where(idx > j, lu[:, j], 0.0)
        return y - col * yj

    return jax.lax.fori_loop(0, n - 1, step, b)


def backward_ref(lu, y):
    """Solve ``U x = y`` with the upper triangle of packed ``lu``."""
    n = lu.shape[0]
    idx = jnp.arange(n)

    def step(k, x):
        i = n - 1 - k
        xi = x[i] / lu[i, i]
        x = x.at[i].set(xi)
        col = jnp.where(idx < i, lu[:, i], 0.0)
        return x - col * xi

    return jax.lax.fori_loop(0, n, step, y)


def lu_solve_ref(a, b):
    """Factor + solve."""
    lu = lu_factor_ref(a)
    return backward_ref(lu, forward_ref(lu, b))


def spmv_ell_ref(values, cols, x):
    """ELL-format SpMV: ``y[i] = sum_k values[i, k] * x[cols[i, k]]``.

    Padding entries use ``cols == -1`` (their value must be 0, but the
    mask makes this robust anyway).
    """
    gathered = x[jnp.clip(cols, 0, x.shape[0] - 1)]
    masked = jnp.where(cols >= 0, values * gathered, 0.0)
    return masked.sum(axis=1)


def fold_permutation(n):
    """The EBV fold: row order ``[0, n-1, 1, n-2, …]``.

    Pairing first with last is the paper's equalization; applying it as
    a permutation makes every *contiguous pair* of rows an equalized
    work unit, so a uniform block partition carries equal work.
    """
    head = jnp.arange((n + 1) // 2)
    tail = n - 1 - head
    inter = jnp.stack([head, tail], axis=1).reshape(-1)
    return inter[:n]
