"""Blocked (panel) LU — the MXU-form of the EBV elimination.

DESIGN.md §Hardware-Adaptation: the paper's per-step rank-1 update is a
VPU-shaped operation (outer product — no MXU utilization). Regrouping
``nb`` consecutive EBV steps into a panel turns the trailing update into
a ``(n-k) × nb @ nb × (n-k)`` **matmul**, which is the shape the TPU's
systolic array wants. On real hardware this kernel is the fast path and
the per-step kernel is the reference; under interpret=True both are
exercised for correctness and the §Perf tables estimate the MXU gain.

Layout per panel iteration (all VMEM-resident at these sizes):

    [ A11 | A12 ]   A11: nb × nb   — unblocked EBV elimination
    [ A21 | A22 ]   A21: (n-k-nb) × nb — column panel (L21)
                    A12: nb × (n-k-nb) — row panel (U12, trsm)
                    A22 -= L21 @ U12   — MXU matmul
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blocked_kernel(a_ref, lu_ref, *, nb):
    n = a_ref.shape[0]
    lu_ref[...] = a_ref[...]
    idx = jax.lax.iota(jnp.int32, n)
    num_panels = (n + nb - 1) // nb

    def panel(p, _):
        k = p * nb
        lu = lu_ref[...]

        # 1. Unblocked EBV elimination inside the panel columns
        #    [k, k+nb), applied to ALL rows below the pivot (computes L21
        #    and the panel part of U) — the paper's per-step scale +
        #    rank-1 update, restricted to panel columns.
        def step(r_local, lu):
            r = k + r_local
            valid = r < n - 1

            def do(lu):
                piv = jax.lax.dynamic_index_in_dim(
                    jax.lax.dynamic_index_in_dim(lu, r, 0, keepdims=False),
                    r, 0, keepdims=False,
                )
                col = jax.lax.dynamic_index_in_dim(lu, r, 1, keepdims=False)
                row = jax.lax.dynamic_index_in_dim(lu, r, 0, keepdims=False)
                below = idx > r
                f = jnp.where(below, col / piv, 0.0)
                lu = jax.lax.dynamic_update_index_in_dim(
                    lu, jnp.where(below, f, col), r, 1
                )
                # Panel-restricted trailing update: columns (r, k+nb).
                in_panel = jnp.logical_and(idx > r, idx < k + nb)
                row_m = jnp.where(in_panel, row, 0.0)
                return lu - jnp.outer(f, row_m)

            return jax.lax.cond(valid, do, lambda lu: lu, lu)

        lu = jax.lax.fori_loop(0, nb, step, lu)

        # 2. U12 := L11⁻¹ A12 — unit-lower triangular solve on the panel
        #    rows, applied to the trailing columns (>= k+nb).
        def trsm_step(r_local, lu):
            r = k + r_local

            def do(lu):
                row_r = jax.lax.dynamic_index_in_dim(lu, r, 0, keepdims=False)

                # Subtract contributions of earlier panel rows.
                def inner(j_local, row_r):
                    j = k + j_local
                    l_rj = jax.lax.dynamic_index_in_dim(row_r, j, 0, keepdims=False)
                    row_j = jax.lax.dynamic_index_in_dim(lu, j, 0, keepdims=False)
                    trail = idx >= k + nb
                    return jnp.where(trail, row_r - l_rj * row_j, row_r)

                row_r = jax.lax.fori_loop(0, r_local, inner, row_r)
                return jax.lax.dynamic_update_index_in_dim(lu, row_r, r, 0)

            # Guard the ragged final panel (r beyond the matrix edge).
            return jax.lax.cond(r < n, do, lambda lu: lu, lu)

        lu = jax.lax.fori_loop(0, nb, trsm_step, lu)

        # 3. A22 -= L21 @ U12 — THE MXU MATMUL. Masked to the trailing
        #    block so the whole-matrix expression stays static-shaped.
        rows_t = (idx >= k + nb).astype(lu.dtype)[:, None]
        cols_p = jnp.logical_and(idx >= k, idx < k + nb).astype(lu.dtype)[None, :]
        l21 = lu * rows_t * cols_p                    # (n, n) masked L21
        u12 = lu * cols_p.T * (idx >= k + nb).astype(lu.dtype)[None, :]
        lu_ref[...] = lu - l21 @ u12
        return 0

    jax.lax.fori_loop(0, num_panels, panel, 0)


@functools.partial(jax.jit, static_argnames=("nb",))
def lu_factor_blocked(a, nb=16):
    """Packed unpivoted LU via panel elimination + matmul updates."""
    n = a.shape[0]
    return pl.pallas_call(
        functools.partial(_blocked_kernel, nb=nb),
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)
