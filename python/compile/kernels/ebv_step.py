"""One elimination step over a fold-paired row grid.

This kernel is the paper's *equalization* made literal in the BlockSpec:

* The matrix rows are first permuted by the EBV fold ``[0, n-1, 1,
  n-2, …]`` (:func:`ref.fold_permutation`). In folded layout, every
  contiguous pair of rows is one of the paper's equalized work units —
  pair `k` holds original rows `k` and `n-1-k`, whose combined trailing
  work is constant across `k`.
* The Pallas grid is then a **uniform** partition: program `k` gets the
  `(2, n)` row-pair block. No program-dependent trip counts, no ragged
  tail — which is exactly the property the paper wants from its "equal
  contributed scheme on threads" (and what a TPU BlockSpec needs for a
  clean HBM→VMEM schedule).

Each program masks its own pair against the pivot index, so already-
retired rows cost a predicated no-op rather than a divergent branch.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _step_kernel(r_ref, pivot_row_ref, orig_idx_ref, pair_ref, out_ref):
    """Process one fold pair (2 rows) of elimination step ``r``."""
    r = r_ref[0]
    pivot_row = pivot_row_ref[...]          # (n,)
    rows = pair_ref[...]                    # (2, n)
    orig = orig_idx_ref[...]                # (2,) original row indices
    n = pivot_row.shape[0]
    piv = jax.lax.dynamic_index_in_dim(pivot_row, r, 0, keepdims=False)
    col_idx = jax.lax.iota(jnp.int32, n)

    # Multipliers for rows strictly below the pivot (in original order).
    active = (orig > r).astype(rows.dtype)[:, None]        # (2, 1)
    a_ir = jax.lax.dynamic_index_in_dim(rows, r, 1)        # (2, 1) column r
    f = active * a_ir / piv
    # Trailing update columns (> r) plus store the multiplier at col r.
    trail = (col_idx > r).astype(rows.dtype)[None, :]
    updated = rows - f * (pivot_row[None, :] * trail)
    keep_col_r = (col_idx == r)[None, :]
    out_ref[...] = jnp.where(
        keep_col_r, rows * (1.0 - active) + f * active, updated
    )


def ebv_step(folded, orig_idx, pivot_row, r):
    """Apply elimination step ``r`` to the fold-permuted matrix.

    Args:
      folded: ``(n, n)`` matrix in EBV-fold row order.
      orig_idx: ``(n,)`` int32 — original row index of each folded row.
      pivot_row: ``(n,)`` — row ``r`` of the matrix (original order).
      r: scalar int32 pivot step.

    Returns the updated folded matrix.
    """
    n = folded.shape[0]
    assert n % 2 == 0, "fold grid needs an even row count (pad odd sizes)"
    pairs = n // 2
    r_arr = jnp.asarray(r, jnp.int32).reshape(1)
    return pl.pallas_call(
        _step_kernel,
        grid=(pairs,),
        in_specs=[
            pl.BlockSpec((1,), lambda k: (0,)),              # step index
            pl.BlockSpec((n,), lambda k: (0,)),              # pivot row
            pl.BlockSpec((2,), lambda k: (k,)),              # pair's orig ids
            pl.BlockSpec((2, n), lambda k: (k, 0)),          # the row pair
        ],
        out_specs=pl.BlockSpec((2, n), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), folded.dtype),
        interpret=True,
    )(r_arr, pivot_row, orig_idx, folded)


def lu_factor_stepped(a):
    """Full factorization by iterating :func:`ebv_step` (small sizes).

    Demonstrates (and tests) that the fold-paired grid computes the same
    factors as the fused kernel; the AOT path uses the fused kernel.
    """
    from . import ref

    n = a.shape[0]
    perm = ref.fold_permutation(n)
    inv = jnp.argsort(perm)
    folded = a[perm, :]
    orig_idx = perm.astype(jnp.int32)

    def body(r, folded):
        # Pivot row r in original order = folded row inv[r].
        pivot_row = folded[inv[r], :]
        return ebv_step(folded, orig_idx, pivot_row, r)

    folded = jax.lax.fori_loop(0, n - 1, body, folded)
    return folded[inv, :]
