"""Bi-vector triangular solves as a Pallas kernel.

The substitution phase *is* the paper's Eq. (4-b/4-c): applying ``A⁻¹``
is a sequence of elementary bi-vector axpys (one per pivot), each a full
VPU-width vector op on the VMEM-resident solution vector. Forward and
backward sweeps are fused into one kernel so the intermediate ``y``
never leaves VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trisolve_kernel(lu_ref, b_ref, x_ref):
    n = lu_ref.shape[0]
    idx = jax.lax.iota(jnp.int32, n)
    lu = lu_ref[...]

    # Forward: L y = b (unit lower). After y[j] is final, subtract the
    # scaled L-column — the bi-vector apply.
    def fwd(j, y):
        yj = jax.lax.dynamic_index_in_dim(y, j, 0, keepdims=False)
        col = jax.lax.dynamic_index_in_dim(lu, j, 1, keepdims=False)
        return y - jnp.where(idx > j, col, 0.0) * yj

    y = jax.lax.fori_loop(0, n - 1, fwd, b_ref[...])

    # Backward: U x = y.
    def bwd(k, x):
        i = n - 1 - k
        num = jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False)
        den = jax.lax.dynamic_index_in_dim(
            jax.lax.dynamic_index_in_dim(lu, i, 0, keepdims=False), i, 0, keepdims=False
        )
        xi = num / den
        x = jax.lax.dynamic_update_index_in_dim(x, xi, i, 0)
        col = jax.lax.dynamic_index_in_dim(lu, i, 1, keepdims=False)
        return x - jnp.where(idx < i, col, 0.0) * xi

    x_ref[...] = jax.lax.fori_loop(0, n, bwd, y)


@jax.jit
def trisolve(lu, b):
    """Solve ``L U x = b`` from a packed factorization."""
    n = lu.shape[0]
    return pl.pallas_call(
        _trisolve_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(lu, b)
