"""ELL-format sparse matrix–vector product as a Pallas kernel.

The sparse substrate of the paper's Table 1 workloads. ELL (fixed
``k`` entries per row, padded with ``col = -1``) is the GPU-friendly
sparse layout of the era — and also the TPU-friendly one: the value and
column blocks are dense ``(bn, k)`` tiles, so a uniform BlockSpec grid
streams them HBM→VMEM while the (small) ``x`` vector stays resident.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    vals = vals_ref[...]                  # (bn, k)
    cols = cols_ref[...]                  # (bn, k)
    x = x_ref[...]                        # (n,)
    gathered = x[jnp.clip(cols, 0, x.shape[0] - 1)]
    y_ref[...] = jnp.where(cols >= 0, vals * gathered, 0.0).sum(axis=1)


def spmv_ell(values, cols, x, block_rows=None):
    """``y = A x`` with ``A`` in ELL format.

    Args:
      values: ``(n, k)`` f32 entries (0 in padding slots).
      cols: ``(n, k)`` int32 column indices (-1 in padding slots).
      x: ``(n,)`` input vector.
      block_rows: rows per grid program (defaults to whole array —
        callers pick 128-row tiles for larger systems).
    """
    n, k = values.shape
    bn = block_rows or n
    assert n % bn == 0, "row count must divide into blocks"
    return pl.pallas_call(
        _spmv_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), values.dtype),
        interpret=True,
    )(values, cols, x)


def csr_to_ell(row_ptr, col_idx, vals, n, k=None):
    """Convert CSR arrays to padded ELL (numpy-side helper for tests)."""
    import numpy as np

    widths = [row_ptr[i + 1] - row_ptr[i] for i in range(n)]
    k = k or (max(widths) if widths else 1)
    values = np.zeros((n, k), dtype=np.float32)
    cols = -np.ones((n, k), dtype=np.int32)
    for i in range(n):
        lo, hi = row_ptr[i], row_ptr[i + 1]
        w = min(hi - lo, k)
        values[i, :w] = vals[lo:lo + w]
        cols[i, :w] = col_idx[lo:lo + w]
    return values, cols
