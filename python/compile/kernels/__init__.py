"""L1 Pallas kernels for the EBV LU solver.

Every kernel is authored for TPU semantics (VMEM-resident blocks,
vector-unit row operations) but lowered with ``interpret=True`` so the
resulting HLO runs on the CPU PJRT client — real-TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute (see DESIGN.md
§Hardware-Adaptation).

Modules:

* :mod:`ref` — pure-jnp oracles; the correctness authority for pytest.
* :mod:`lu_factor` — whole-matrix EBV elimination kernel.
* :mod:`trisolve` — bi-vector (column-oriented) substitution kernel.
* :mod:`ebv_step` — one elimination step over a fold-paired row grid:
  the paper's equalization realized as a data-layout permutation so a
  uniform BlockSpec carries equal work per program.
* :mod:`spmv` — ELL sparse matrix-vector product.
"""

from . import ebv_step, lu_blocked, lu_factor, ref, spmv, trisolve  # noqa: F401
