"""L2 model: the jitted compute graphs the rust runtime executes.

Each function here composes the L1 Pallas kernels into the end-to-end
programs that `aot.py` lowers to HLO text — factor-only, factor+solve,
and the batched multi-RHS variant the coordinator's batcher feeds (the
CFD pattern: one matrix, many right-hand sides).

Python in this package runs at build time only; nothing here is imported
on the rust request path.
"""

import jax
import jax.numpy as jnp

from .kernels import lu_factor as lu_factor_kernel
from .kernels import spmv as spmv_kernel
from .kernels import trisolve as trisolve_kernel


def lu_factor(a):
    """Packed unpivoted LU (Pallas kernel)."""
    return lu_factor_kernel.lu_factor(a)


def lu_solve(a, b):
    """Solve ``A x = b``: one factorization + fused substitutions."""
    lu = lu_factor_kernel.lu_factor(a)
    return trisolve_kernel.trisolve(lu, b)


def lu_solve_batched(a, bs):
    """Solve ``A X = B`` for a batch of RHS (``bs``: ``(k, n)``).

    One factorization amortized over the batch; the substitution is
    vmapped so XLA fuses the per-RHS sweeps into one batched loop.
    """
    lu = lu_factor_kernel.lu_factor(a)
    return jax.vmap(lambda b: trisolve_kernel.trisolve(lu, b))(bs)


def spmv(values, cols, x):
    """ELL SpMV (sparse substrate)."""
    return spmv_kernel.spmv_ell(values, cols, x)


def residual_inf(a, x, b):
    """∞-norm residual — exported so the artifact can self-check."""
    return jnp.max(jnp.abs(a @ x - b))
