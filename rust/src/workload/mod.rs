//! Workload generation for benches and the service examples: request
//! traces with Poisson arrivals over a mix of system sizes/formats,
//! mirroring how a CFD code would hit the solver service.

use crate::matrix::generate::{
    diag_dominant_dense, diag_dominant_sparse, poisson_2d, rhs, GenSeed,
};
use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::rng::Rng;

/// What kind of system a request carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    Dense,
    Sparse,
    Poisson,
}

/// One generated solve job.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub arrival: f64,
    pub kind: SystemKind,
    pub n: usize,
    pub seed: u64,
}

impl Job {
    /// Materialize the dense system for this job (dense jobs only).
    pub fn dense_system(&self) -> (DenseMatrix, Vec<f64>) {
        assert_eq!(self.kind, SystemKind::Dense);
        let a = diag_dominant_dense(self.n, GenSeed(self.seed));
        let b = rhs(self.n, GenSeed(self.seed ^ 1));
        (a, b)
    }

    /// Materialize the sparse system for this job.
    pub fn sparse_system(&self) -> (CsrMatrix, Vec<f64>) {
        let a = match self.kind {
            SystemKind::Sparse => diag_dominant_sparse(self.n, 5, GenSeed(self.seed)),
            SystemKind::Poisson => {
                let g = (self.n as f64).sqrt().round() as usize;
                poisson_2d(g.max(2))
            }
            SystemKind::Dense => panic!("dense job has no sparse system"),
        };
        let b = rhs(a.rows(), GenSeed(self.seed ^ 1));
        (a, b)
    }
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Mean request rate (requests/second).
    pub rate: f64,
    /// Number of requests.
    pub count: usize,
    /// Sizes sampled uniformly per request.
    pub sizes: Vec<usize>,
    /// Mix of kinds, as (kind, weight).
    pub mix: Vec<(SystemKind, f64)>,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            rate: 200.0,
            count: 100,
            sizes: vec![64, 128, 256],
            mix: vec![(SystemKind::Dense, 0.5), (SystemKind::Sparse, 0.5)],
            seed: 0xEB5,
        }
    }
}

/// Generate a Poisson-arrival request trace.
pub fn generate_trace(spec: &TraceSpec) -> Vec<Job> {
    assert!(!spec.sizes.is_empty(), "trace needs at least one size");
    assert!(!spec.mix.is_empty(), "trace needs at least one kind");
    let mut rng = Rng::seed_from(spec.seed);
    let total_w: f64 = spec.mix.iter().map(|(_, w)| w).sum();
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(spec.count);
    for id in 0..spec.count {
        t += rng.exponential(spec.rate.max(1e-9));
        let mut pick = rng.uniform() * total_w;
        let mut kind = spec.mix[0].0;
        for &(k, w) in &spec.mix {
            if pick < w {
                kind = k;
                break;
            }
            pick -= w;
        }
        let n = *spec.sizes.get(rng.below(spec.sizes.len())).unwrap();
        jobs.push(Job { id: id as u64, arrival: t, kind, n, seed: rng.next_u64() });
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let spec = TraceSpec::default();
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        assert_eq!(a.len(), 100);
        assert_eq!(a.iter().map(|j| j.id).collect::<Vec<_>>(),
                   b.iter().map(|j| j.id).collect::<Vec<_>>());
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let spec = TraceSpec { rate: 1000.0, count: 2000, ..Default::default() };
        let jobs = generate_trace(&spec);
        let span = jobs.last().unwrap().arrival;
        let rate = jobs.len() as f64 / span;
        assert!((rate - 1000.0).abs() / 1000.0 < 0.15, "rate={rate}");
    }

    #[test]
    fn mix_respects_weights() {
        let spec = TraceSpec {
            mix: vec![(SystemKind::Dense, 0.9), (SystemKind::Sparse, 0.1)],
            count: 1000,
            ..Default::default()
        };
        let jobs = generate_trace(&spec);
        let dense = jobs.iter().filter(|j| j.kind == SystemKind::Dense).count();
        assert!(dense > 820 && dense < 970, "dense={dense}");
    }

    #[test]
    fn jobs_materialize_consistent_systems() {
        let spec = TraceSpec::default();
        let jobs = generate_trace(&spec);
        let dense_job = jobs.iter().find(|j| j.kind == SystemKind::Dense).unwrap();
        let (a, b) = dense_job.dense_system();
        assert_eq!(a.rows(), dense_job.n);
        assert_eq!(b.len(), dense_job.n);
        let sparse_job = jobs.iter().find(|j| j.kind == SystemKind::Sparse).unwrap();
        let (a, b) = sparse_job.sparse_system();
        assert_eq!(a.rows(), sparse_job.n);
        assert_eq!(b.len(), sparse_job.n);
        assert!(a.is_diag_dominant());
    }

    #[test]
    fn poisson_jobs_square_the_size() {
        let j = Job { id: 0, arrival: 0.0, kind: SystemKind::Poisson, n: 100, seed: 1 };
        let (a, _) = j.sparse_system();
        assert_eq!(a.rows(), 100); // 10x10 grid
    }
}
