//! # EBV-Solve
//!
//! Reproduction of *"Equal bi-Vectorized (EbV) method to high performance
//! on GPU"* (Hashemi, Lahooti, Shirani — CS.DC 2019) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper proposes a parallel LU-decomposition solver built on two
//! ideas: **bi-vectorization** (the `L` and `U` factors are processed as
//! `2(n-1)` elimination vectors) and **equalization** (short and long
//! vectors are paired so every parallel work unit carries the same amount
//! of work). This crate implements that method end to end:
//!
//! * [`matrix`] — dense / CSR / COO / banded storage, generators, I/O;
//! * [`ebv`] — the paper's contribution: bi-vector extraction,
//!   equalization pairing, and the dependency-safe lane schedule;
//! * [`exec`] — the persistent lane engine: a resident, barrier-stepped
//!   worker pool that every parallel factor/substitution/panel path
//!   submits to instead of spawning thread scopes per call — plus the
//!   two-level device-sharded runtime (`exec::DeviceSet`) realizing the
//!   paper's multi-device claim with a staged pivot-row exchange;
//! * [`solver`] — sequential, EBV-parallel, blocked, and sparse LU plus
//!   triangular solves, pivoting and iterative refinement;
//! * [`gpusim`] — GTX280-calibrated cost model used to regenerate the
//!   paper's Tables 1–3 from real schedule op counts;
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`);
//! * [`coordinator`] — the L3 solve service: routing, dynamic batching,
//!   leader/worker lanes, backpressure and metrics;
//! * [`obs`] — span-structured solve tracing plus the measured
//!   lane/device imbalance profiler and its exporters (Prometheus
//!   text, JSONL event log), gated by a zero-overhead profiling flag;
//! * [`wire`] — the L4 serving surface: a streaming NDJSON solve
//!   protocol (`ebv-solve serve`) whose zero-tree scanner ingests
//!   million-float matrix payloads straight into solver buffers and
//!   auto-keys repeat traffic into the factor cache via streaming
//!   FNV-1a content fingerprints;
//! * [`bench`], [`workload`], [`testutil`] — measurement harness,
//!   request-trace generation and a property-testing mini-framework
//!   (offline substitutes for criterion / proptest).
//!
//! Quickstart:
//!
//! ```
//! use ebv_solve::matrix::DenseMatrix;
//! use ebv_solve::matrix::generate::{diag_dominant_dense, GenSeed};
//! use ebv_solve::solver::{EbvLu, LuSolver};
//!
//! let n = 64;
//! let a = diag_dominant_dense(n, GenSeed(7));
//! let b = vec![1.0; n];
//! let x = EbvLu::with_lanes(2).solve(&a, &b).unwrap();
//! let r = a.residual(&x, &b);
//! assert!(r < 1e-8);
//! ```
//!
//! Serving the same solve over the wire protocol (README.md documents
//! the NDJSON session format):
//!
//! ```
//! use ebv_solve::config::ServiceConfig;
//! use ebv_solve::coordinator::SolverService;
//! use ebv_solve::wire::serve_session;
//!
//! let svc = SolverService::start(ServiceConfig::default()).unwrap();
//! let input = "{\"op\":\"solve\",\"rows\":2,\"values\":[4,1,1,3],\"b\":[1,2]}\n\
//!              {\"op\":\"shutdown\"}\n";
//! let mut output = Vec::new();
//! let stats = serve_session(&svc, input.as_bytes(), &mut output).unwrap();
//! assert_eq!(stats.solves, 1);
//! svc.shutdown();
//! ```

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod ebv;
pub mod exec;
pub mod gpusim;
pub mod matrix;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod solver;
pub mod testutil;
pub mod util;
pub mod wire;
pub mod workload;

/// Crate-wide error type (thin wrapper over the module errors).
pub use util::error::{EbvError, Result};

/// Version string baked from Cargo metadata.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
