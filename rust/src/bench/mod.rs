//! Measurement harness (offline substitute for `criterion`).
//!
//! Wall-clock benchmarking with warmup, adaptive iteration counts, and
//! mean/median/p99/stddev statistics; plus report emission as text
//! tables and JSON so `EXPERIMENTS.md` entries are regenerable.
//!
//! **Smoke mode.** CI runs every bench with `EBV_BENCH_SMOKE=1`, which
//! benches honor by shrinking problem sizes/iterations ([`smoke`],
//! [`Bencher::smoke`]) and skipping wall-clock direction assertions
//! (tiny shapes are all timer noise). Smoke runs never write the
//! repo-level `BENCH_*.json` summaries — [`write_repo_summary`] refuses
//! in smoke mode, so a gauntlet run can't clobber real measurements (or
//! the checked-in schema files) with zeros.

use std::time::{Duration, Instant};

use crate::util::fmt;
use crate::util::json::Json;

/// True when the CI gauntlet asks benches for a tiny-size smoke run
/// (`EBV_BENCH_SMOKE` set to anything but `0`/empty).
pub fn smoke() -> bool {
    std::env::var("EBV_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Pick the full-size or smoke-size case list by mode.
pub fn sizes(full: &[usize], tiny: &[usize]) -> Vec<usize> {
    if smoke() {
        tiny.to_vec()
    } else {
        full.to_vec()
    }
}

/// Write a repo-level `BENCH_*.json` summary. In smoke mode nothing is
/// written (returns `Ok(false)`): smoke shapes produce junk timings,
/// and the checked-in schema/measured files must survive a CI gauntlet
/// run byte-for-byte.
pub fn write_repo_summary(path: &std::path::Path, doc: &Json) -> std::io::Result<bool> {
    if smoke() {
        println!("smoke mode: leaving {} untouched", path.display());
        return Ok(false);
    }
    std::fs::write(path, doc.emit_pretty())?;
    Ok(true)
}

/// Statistics of one measured case.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub p99: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    fn from_samples(name: &str, samples: &mut [f64]) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            mean,
            median: samples[n / 2],
            p99: samples[(n * 99 / 100).min(n - 1)],
            stddev: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::from(self.name.clone())),
            ("iters", Json::from(self.iters)),
            ("mean_s", Json::from(self.mean)),
            ("median_s", Json::from(self.median)),
            ("p99_s", Json::from(self.p99)),
            ("stddev_s", Json::from(self.stddev)),
            ("min_s", Json::from(self.min)),
            ("max_s", Json::from(self.max)),
        ])
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum number of timed iterations.
    pub min_iters: usize,
    /// Maximum number of timed iterations.
    pub max_iters: usize,
    /// Target total measurement time per case.
    pub target_time: Duration,
    /// Warmup iterations (not recorded).
    pub warmup_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_millis(800),
            warmup_iters: 2,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive cases (large matrices).
    pub fn quick() -> Self {
        Bencher {
            min_iters: 3,
            max_iters: 20,
            target_time: Duration::from_millis(300),
            warmup_iters: 1,
        }
    }

    /// Minimal profile for CI smoke runs: prove the bench executes, not
    /// that the numbers mean anything.
    pub fn smoke() -> Self {
        Bencher {
            min_iters: 1,
            max_iters: 2,
            target_time: Duration::from_millis(20),
            warmup_iters: 0,
        }
    }

    /// `self` normally, the [`Bencher::smoke`] profile under
    /// `EBV_BENCH_SMOKE=1` — the one-liner every bench main uses.
    pub fn or_smoke(self) -> Self {
        if smoke() {
            Bencher::smoke()
        } else {
            self
        }
    }

    /// Measure `f`, returning timing stats. The closure's return value is
    /// passed through `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.min_iters);
        let started = Instant::now();
        loop {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            let enough_time = started.elapsed() >= self.target_time;
            if samples.len() >= self.max_iters || (samples.len() >= self.min_iters && enough_time)
            {
                break;
            }
        }
        Stats::from_samples(name, &mut samples)
    }
}

/// A collected report: rows of named stats plus free-form table rows,
/// printable and dumpable as JSON (under `target/bench-reports/`).
#[derive(Debug, Default)]
pub struct Report {
    title: String,
    stats: Vec<Stats>,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), ..Default::default() }
    }

    pub fn push_stats(&mut self, s: Stats) {
        self.stats.push(s);
    }

    /// Set the headers of the free-form results table.
    pub fn set_headers(&mut self, headers: &[&str]) {
        self.headers = headers.iter().map(|s| s.to_string()).collect();
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        self.rows.push(row);
    }

    /// Render the report as text (printed by every bench binary).
    pub fn render(&self) -> String {
        let mut out = format!("\n=== {} ===\n", self.title);
        if !self.rows.is_empty() {
            let headers: Vec<&str> = self.headers.iter().map(String::as_str).collect();
            out.push_str(&fmt::table(&headers, &self.rows));
        }
        if !self.stats.is_empty() {
            out.push_str("\nTimings:\n");
            let rows: Vec<Vec<String>> = self
                .stats
                .iter()
                .map(|s| {
                    vec![
                        s.name.clone(),
                        s.iters.to_string(),
                        fmt::secs(s.mean),
                        fmt::secs(s.median),
                        fmt::secs(s.p99),
                        fmt::secs(s.stddev),
                    ]
                })
                .collect();
            out.push_str(&fmt::table(
                &["case", "iters", "mean", "median", "p99", "stddev"],
                &rows,
            ));
        }
        out
    }

    /// Write the report JSON under `target/bench-reports/<slug>.json`.
    pub fn write_json(&self) -> std::io::Result<std::path::PathBuf> {
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
            .collect();
        let dir = std::path::Path::new("target/bench-reports");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{slug}.json"));
        let doc = Json::obj([
            ("title", Json::from(self.title.clone())),
            ("headers", Json::arr(self.headers.iter().map(|h| Json::from(h.clone())))),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::from(c.clone())))),
                ),
            ),
            ("stats", Json::arr(self.stats.iter().map(Stats::to_json))),
        ]);
        std::fs::write(&path, doc.emit_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_produces_sane_stats() {
        let b = Bencher { min_iters: 5, max_iters: 10, ..Bencher::quick() };
        let s = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<usize>())
        });
        assert!(s.iters >= 5 && s.iters <= 10);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mean > 0.0);
        assert!(s.p99 >= s.median);
    }

    #[test]
    fn stats_from_known_samples() {
        let mut samples = vec![3.0, 1.0, 2.0, 4.0, 5.0];
        let s = Stats::from_samples("k", &mut samples);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn report_renders_rows_and_stats() {
        let mut r = Report::new("Table X");
        r.set_headers(&["n", "time"]);
        r.push_row(vec!["500".into(), "1 ms".into()]);
        let b = Bencher::quick();
        r.push_stats(b.run("case", || 1 + 1));
        let text = r.render();
        assert!(text.contains("Table X"));
        assert!(text.contains("500"));
        assert!(text.contains("case"));
    }

    /// `EBV_BENCH_SMOKE` is process-global: the tests that toggle it
    /// serialize on this lock so parallel test threads can't race.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn smoke_flag_reads_env() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        std::env::remove_var("EBV_BENCH_SMOKE");
        assert!(!smoke());
        std::env::set_var("EBV_BENCH_SMOKE", "0");
        assert!(!smoke());
        std::env::set_var("EBV_BENCH_SMOKE", "1");
        assert!(smoke());
        assert_eq!(sizes(&[512, 1024], &[64]), vec![64]);
        let b = Bencher::default().or_smoke();
        assert_eq!(b.max_iters, 2);
        std::env::remove_var("EBV_BENCH_SMOKE");
        assert_eq!(sizes(&[512, 1024], &[64]), vec![512, 1024]);
    }

    #[test]
    fn repo_summary_guard_refuses_smoke_overwrites() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let dir = std::env::temp_dir().join("ebv_bench_guard");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let measured = Json::obj([
            ("bench", Json::from("guard")),
            ("status", Json::from("measured")),
        ]);
        std::env::remove_var("EBV_BENCH_SMOKE");
        assert!(write_repo_summary(&path, &measured).unwrap());
        let before = std::fs::read_to_string(&path).unwrap();

        std::env::set_var("EBV_BENCH_SMOKE", "1");
        let zeros = Json::obj([("status", Json::from("smoke"))]);
        assert!(!write_repo_summary(&path, &zeros).unwrap(), "smoke must not write");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
        // Smoke refuses even when no file exists yet.
        let fresh = dir.join("BENCH_fresh.json");
        let _ = std::fs::remove_file(&fresh);
        assert!(!write_repo_summary(&fresh, &zeros).unwrap());
        assert!(!fresh.exists());
        std::env::remove_var("EBV_BENCH_SMOKE");
    }

    #[test]
    fn report_json_round_trips() {
        let mut r = Report::new("json smoke");
        r.set_headers(&["a"]);
        r.push_row(vec!["1".into()]);
        let path = r.write_json().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("title").unwrap().as_str().unwrap(), "json smoke");
    }
}
