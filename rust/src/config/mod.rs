//! Configuration system (offline substitute for serde+toml).
//!
//! Parses a TOML subset — `[section]` headers, `key = value` with
//! string/int/float/bool values and `#` comments — into typed config
//! structs with defaults, validation and environment overrides
//! (`EBV_<SECTION>_<KEY>`). Used by the service binary and examples.

use std::collections::BTreeMap;
use std::path::Path;

use crate::ebv::schedule::RowDist;
use crate::exec::Schedule;
use crate::solver::kernel::Kernel;
use crate::util::error::{EbvError, Result};

/// Raw parsed config: `section -> key -> value-as-string`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<RawConfig> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = strip_comment(line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(EbvError::Config(format!("line {}: empty section", lineno + 1)));
                }
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(EbvError::Config(format!(
                    "line {}: expected `key = value`, got `{line}`",
                    lineno + 1
                )));
            };
            let key = k.trim().to_string();
            let mut val = v.trim().to_string();
            // Unquote strings.
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            if key.is_empty() {
                return Err(EbvError::Config(format!("line {}: empty key", lineno + 1)));
            }
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<RawConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| EbvError::io(format!("read config {}", path.display()), e))?;
        RawConfig::parse(&text)
    }

    /// Fetch `section.key`, checking env override `EBV_<SECTION>_<KEY>`
    /// first.
    pub fn get(&self, section: &str, key: &str) -> Option<String> {
        let env_key = format!(
            "EBV_{}_{}",
            section.to_ascii_uppercase().replace('-', "_"),
            key.to_ascii_uppercase().replace('-', "_")
        );
        if let Ok(v) = std::env::var(&env_key) {
            return Some(v);
        }
        self.sections.get(section).and_then(|s| s.get(key)).cloned()
    }

    fn get_parsed<T: std::str::FromStr>(&self, section: &str, key: &str, default: T) -> Result<T> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                EbvError::Config(format!("{section}.{key}: cannot parse `{v}`"))
            }),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Typed service configuration with validated defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Worker lanes in the solver pool.
    pub lanes: usize,
    /// Row-distribution strategy for the EBV solver.
    pub dist: RowDist,
    /// Maximum batch size the dynamic batcher will coalesce.
    pub max_batch: usize,
    /// Batching window: how long the batcher waits to fill a batch.
    pub batch_window_us: u64,
    /// Bound on the pending-request queue (backpressure threshold).
    pub queue_capacity: usize,
    /// Resident lanes in the shared execution engine (`0` = size from
    /// `EBV_ENGINE_LANES` / available parallelism). Distinct from
    /// `lanes`, which is the schedule *width* the solvers request —
    /// widths virtualize onto the resident pool.
    pub engine_lanes: usize,
    /// Device shards of the two-level runtime (`exec::DeviceSet`).
    /// `1` (the default) keeps every solve on the flat shared engine;
    /// `D > 1` partitions the resolved engine lanes into `D` device
    /// groups and runs the dense factorization, the sparse numeric
    /// refactorization and the level-scheduled trisolves
    /// device-sharded, with the pivot-row broadcast staged between
    /// steps. Results are bitwise identical for every `D`.
    pub devices: usize,
    /// Panel width `nb` of the blocked dense factorization the workers
    /// run (`1` = column-at-a-time, bit-identical to `SeqLu`).
    pub panel_width: usize,
    /// Trailing-update microkernel of the blocked factorization
    /// (`solver::kernel`): `auto` (the default — `EBV_KERNEL` or
    /// tiled), `unroll4`, `unroll8` or `tiled`. `tiled` and `unroll4`
    /// are bitwise identical; `unroll8` agrees componentwise. The
    /// sparse numeric sweep is bitwise-invariant under every choice.
    pub kernel: Kernel,
    /// Lane scheduling discipline of the parallel factorizations and
    /// sparse trisolves (`exec::Schedule`): `barrier` (the default —
    /// one engine step per column/panel/level) or `dataflow` (per-task
    /// dependency counters, lanes self-schedule inside a single engine
    /// step). Results are bitwise identical either way; device-sharded
    /// (`devices > 1`) and sequential paths always run barrier-style.
    pub schedule: Schedule,
    /// Sparse symbolic/numeric split: factor sparse systems as a cached
    /// pattern analysis plus a level-parallel numeric sweep on the
    /// shared engine (`true`, the default), or the monolithic
    /// sequential Gilbert–Peierls loop (`false`). Either way the
    /// factors are bitwise identical; the split is what lets repeat
    /// same-pattern traffic skip symbolic analysis.
    pub sparse_parallel: bool,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Prefer the PJRT runtime for sizes with compiled artifacts.
    pub use_runtime: bool,
    /// Refine runtime (f32) solutions to f64 accuracy.
    pub refine: bool,
    /// Concurrent-session ceiling of the TCP serving edge
    /// (`serve --listen`); connections past it are shed with a `busy`
    /// error frame. Ignored by the single-session stdio mode.
    pub max_sessions: usize,
    /// Per-request solve deadline in milliseconds for wire sessions
    /// (`0` = none): a request not answered within it gets a
    /// `deadline` error frame and its result is discarded.
    pub deadline_ms: u64,
    /// Span-structured solve tracing and lane/device profiling
    /// (`obs::set_enabled`). Off by default — the observability hooks
    /// then cost one relaxed atomic load per job. Turning it on makes
    /// workers attach a `SolveTrace` to every response and the engine
    /// accumulate per-lane busy/wait nanoseconds.
    pub profiling: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            lanes: 4,
            dist: RowDist::EbvFold,
            max_batch: 16,
            batch_window_us: 200,
            queue_capacity: 1024,
            engine_lanes: 0,
            devices: 1,
            panel_width: crate::solver::lu_ebv::DEFAULT_PANEL_WIDTH,
            kernel: Kernel::Auto,
            schedule: Schedule::Barrier,
            sparse_parallel: true,
            artifacts_dir: "artifacts".to_string(),
            use_runtime: false,
            refine: true,
            max_sessions: 8,
            deadline_ms: 0,
            profiling: false,
        }
    }
}

impl ServiceConfig {
    /// Build from a raw config's `[service]` section (all keys optional).
    pub fn from_raw(raw: &RawConfig) -> Result<ServiceConfig> {
        let d = ServiceConfig::default();
        let dist = match raw.get("service", "dist") {
            None => d.dist,
            Some(name) => RowDist::parse(&name).ok_or_else(|| {
                EbvError::Config(format!("service.dist: unknown strategy `{name}`"))
            })?,
        };
        let kernel = match raw.get("service", "kernel") {
            None => d.kernel,
            Some(name) => Kernel::parse(&name).ok_or_else(|| {
                EbvError::Config(format!("service.kernel: unknown kernel `{name}`"))
            })?,
        };
        let schedule = match raw.get("service", "schedule") {
            None => d.schedule,
            Some(name) => Schedule::parse(&name).ok_or_else(|| {
                EbvError::Config(format!("service.schedule: unknown schedule `{name}`"))
            })?,
        };
        let cfg = ServiceConfig {
            lanes: raw.get_parsed("service", "lanes", d.lanes)?,
            dist,
            max_batch: raw.get_parsed("service", "max_batch", d.max_batch)?,
            batch_window_us: raw.get_parsed("service", "batch_window_us", d.batch_window_us)?,
            queue_capacity: raw.get_parsed("service", "queue_capacity", d.queue_capacity)?,
            engine_lanes: raw.get_parsed("service", "engine_lanes", d.engine_lanes)?,
            devices: raw.get_parsed("service", "devices", d.devices)?,
            panel_width: raw.get_parsed("service", "panel_width", d.panel_width)?,
            kernel,
            schedule,
            sparse_parallel: raw.get_parsed("service", "sparse_parallel", d.sparse_parallel)?,
            artifacts_dir: raw
                .get("service", "artifacts_dir")
                .unwrap_or_else(|| d.artifacts_dir.clone()),
            use_runtime: raw.get_parsed("service", "use_runtime", d.use_runtime)?,
            refine: raw.get_parsed("service", "refine", d.refine)?,
            max_sessions: raw.get_parsed("service", "max_sessions", d.max_sessions)?,
            deadline_ms: raw.get_parsed("service", "deadline_ms", d.deadline_ms)?,
            profiling: raw.get_parsed("service", "profiling", d.profiling)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.lanes == 0 {
            return Err(EbvError::Config("service.lanes must be >= 1".into()));
        }
        if self.max_batch == 0 {
            return Err(EbvError::Config("service.max_batch must be >= 1".into()));
        }
        if self.panel_width == 0 {
            return Err(EbvError::Config("service.panel_width must be >= 1".into()));
        }
        if self.devices == 0 {
            return Err(EbvError::Config("service.devices must be >= 1".into()));
        }
        if self.max_sessions == 0 {
            return Err(EbvError::Config("service.max_sessions must be >= 1".into()));
        }
        if self.queue_capacity < self.max_batch {
            return Err(EbvError::Config(
                "service.queue_capacity must be >= max_batch".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let raw = RawConfig::parse(
            "# top comment\n\
             [service]\n\
             lanes = 8\n\
             dist = \"cyclic\"  # inline comment\n\
             refine = false\n\
             artifacts_dir = \"my/arts\"\n",
        )
        .unwrap();
        let cfg = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.lanes, 8);
        assert_eq!(cfg.dist, RowDist::Cyclic);
        assert!(!cfg.refine);
        assert_eq!(cfg.artifacts_dir, "my/arts");
        // Unspecified keys fall back to defaults.
        assert_eq!(cfg.max_batch, ServiceConfig::default().max_batch);
        assert_eq!(cfg.engine_lanes, 0, "engine auto-sizes by default");
    }

    #[test]
    fn engine_lanes_knob_parses() {
        let raw = RawConfig::parse("[service]\nengine_lanes = 6\n").unwrap();
        let cfg = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.engine_lanes, 6);
        let raw = RawConfig::parse("[service]\nengine_lanes = no\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn panel_width_knob_parses_and_validates() {
        assert_eq!(ServiceConfig::default().panel_width, 64);
        let raw = RawConfig::parse("[service]\npanel_width = 8\n").unwrap();
        assert_eq!(ServiceConfig::from_raw(&raw).unwrap().panel_width, 8);
        let raw = RawConfig::parse("[service]\npanel_width = 1\n").unwrap();
        assert_eq!(ServiceConfig::from_raw(&raw).unwrap().panel_width, 1);
        let raw = RawConfig::parse("[service]\npanel_width = 0\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\npanel_width = wide\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn devices_knob_parses_and_validates() {
        assert_eq!(ServiceConfig::default().devices, 1, "flat engine is the default");
        let raw = RawConfig::parse("[service]\ndevices = 4\n").unwrap();
        assert_eq!(ServiceConfig::from_raw(&raw).unwrap().devices, 4);
        let raw = RawConfig::parse("[service]\ndevices = 0\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\ndevices = many\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn kernel_knob_parses() {
        assert_eq!(ServiceConfig::default().kernel, Kernel::Auto);
        for (name, want) in [
            ("auto", Kernel::Auto),
            ("unroll4", Kernel::Unroll4),
            ("unroll8", Kernel::Unroll8),
            ("tiled", Kernel::Tiled),
        ] {
            let raw = RawConfig::parse(&format!("[service]\nkernel = \"{name}\"\n")).unwrap();
            assert_eq!(ServiceConfig::from_raw(&raw).unwrap().kernel, want, "{name}");
        }
        let raw = RawConfig::parse("[service]\nkernel = \"simd512\"\n").unwrap();
        let err = ServiceConfig::from_raw(&raw).unwrap_err();
        assert!(
            err.to_string().contains("service.kernel: unknown kernel `simd512`"),
            "{err}"
        );
    }

    #[test]
    fn schedule_knob_parses() {
        assert_eq!(ServiceConfig::default().schedule, Schedule::Barrier);
        for (name, want) in [("barrier", Schedule::Barrier), ("dataflow", Schedule::Dataflow)] {
            let raw = RawConfig::parse(&format!("[service]\nschedule = \"{name}\"\n")).unwrap();
            assert_eq!(ServiceConfig::from_raw(&raw).unwrap().schedule, want, "{name}");
        }
        let raw = RawConfig::parse("[service]\nschedule = \"wavefront\"\n").unwrap();
        let err = ServiceConfig::from_raw(&raw).unwrap_err();
        assert!(
            err.to_string().contains("service.schedule: unknown schedule `wavefront`"),
            "{err}"
        );
    }

    #[test]
    fn sparse_parallel_knob_parses() {
        assert!(ServiceConfig::default().sparse_parallel, "split is the default");
        let raw = RawConfig::parse("[service]\nsparse_parallel = false\n").unwrap();
        assert!(!ServiceConfig::from_raw(&raw).unwrap().sparse_parallel);
        let raw = RawConfig::parse("[service]\nsparse_parallel = maybe\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn serving_edge_knobs_parse_and_validate() {
        let d = ServiceConfig::default();
        assert_eq!(d.max_sessions, 8);
        assert_eq!(d.deadline_ms, 0, "no deadline by default");
        let raw = RawConfig::parse("[service]\nmax_sessions = 3\ndeadline_ms = 250\n").unwrap();
        let cfg = ServiceConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.max_sessions, 3);
        assert_eq!(cfg.deadline_ms, 250);
        let raw = RawConfig::parse("[service]\nmax_sessions = 0\n").unwrap();
        let err = ServiceConfig::from_raw(&raw).unwrap_err();
        assert!(err.to_string().contains("max_sessions must be >= 1"), "{err}");
        let raw = RawConfig::parse("[service]\ndeadline_ms = soon\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn profiling_knob_parses() {
        assert!(!ServiceConfig::default().profiling, "profiling is opt-in");
        let raw = RawConfig::parse("[service]\nprofiling = true\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).unwrap().profiling);
        let raw = RawConfig::parse("[service]\nprofiling = sometimes\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn defaults_validate() {
        assert!(ServiceConfig::default().validate().is_ok());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(RawConfig::parse("[]\n").is_err());
        assert!(RawConfig::parse("justtext\n").is_err());
        let raw = RawConfig::parse("[service]\nlanes = banana\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\ndist = \"zigzag\"\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[service]\nlanes = 0\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let raw = RawConfig::parse("[s]\npath = \"a#b\"\n").unwrap();
        assert_eq!(raw.get("s", "path").unwrap(), "a#b");
    }

    #[test]
    fn env_override_wins() {
        let raw = RawConfig::parse("[service]\nlanes = 2\n").unwrap();
        std::env::set_var("EBV_SERVICE_LANES", "6");
        let cfg = ServiceConfig::from_raw(&raw).unwrap();
        std::env::remove_var("EBV_SERVICE_LANES");
        assert_eq!(cfg.lanes, 6);
    }

    #[test]
    fn queue_capacity_must_cover_batch() {
        let raw = RawConfig::parse("[service]\nmax_batch = 64\nqueue_capacity = 8\n").unwrap();
        assert!(ServiceConfig::from_raw(&raw).is_err());
    }
}
