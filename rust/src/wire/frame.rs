//! Wire frame types of the solve protocol.
//!
//! One frame per NDJSON line — or, on a session that negotiated
//! `accept_binary`, one length-prefixed binary frame for the
//! payload-heavy shapes ([`super::binary`]); the typed frames here are
//! encoding-agnostic, which is what makes the two formats provably
//! bit-equivalent. Requests are [`RequestFrame`]s (`solve`,
//! `solve_sparse`, `metrics`, `shutdown`); the server answers each with
//! exactly one [`ResponseFrame`] (`solution`, `metrics`, `error`,
//! `goodbye`). NDJSON encoding/decoding lives in [`super::codec`]; this
//! module holds the typed shapes and the fingerprint/key policy.
//!
//! The `metrics` response carries the full
//! [`MetricsSnapshot`], including the lane-engine counters
//! (`engine_lanes`, `engine_jobs`, `engine_steps`,
//! `engine_barrier_waits`) of the resident pool every parallel solve
//! runs on — see README.md §Execution engine — plus, when the service
//! runs with profiling on, the measured observability fields
//! (per-frame-class latency histograms, `busy_ns`/`wait_ns` lane
//! accumulators, `measured_imbalance` and their device-level
//! counterparts). Unknown fields are skipped on decode, so old clients
//! interoperate with new servers and vice versa.

use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::request::Timings;
use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::wire::fingerprint::{fingerprint_csr, fingerprint_csr_pattern, fingerprint_dense};

/// The coefficient matrix carried by a solve frame.
#[derive(Debug, Clone, PartialEq)]
pub enum WireMatrix {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl WireMatrix {
    pub fn n(&self) -> usize {
        match self {
            WireMatrix::Dense(a) => a.rows(),
            WireMatrix::Sparse(a) => a.rows(),
        }
    }
}

/// A decoded solve request: matrix + RHS + caching directives.
///
/// `fingerprint` is the streaming FNV-1a content hash computed while
/// the payload was scanned (or at construction, for locally built
/// frames). Unless the client pins an explicit `key` or opts out with
/// `no_cache`, the fingerprint becomes the request's `matrix_key`, so
/// repeated same-matrix traffic shares factorizations without clients
/// managing keys.
#[derive(Debug, Clone, PartialEq)]
pub struct WireSolve {
    /// Client-chosen correlation id, echoed in the response. Server
    /// assigns session-sequential ids when absent.
    pub id: Option<u64>,
    pub matrix: WireMatrix,
    pub b: Vec<f64>,
    /// Explicit cache key override.
    pub key: Option<u64>,
    /// Disable factor caching/batching for this request.
    pub no_cache: bool,
    /// Content fingerprint of `matrix`.
    pub fingerprint: u64,
    /// Structure-only fingerprint of `matrix` (sparse frames; `None`
    /// for dense). Same-pattern/different-values requests share it, so
    /// the coordinator can reuse the cached *symbolic analysis* and run
    /// only the level-parallel numeric refactorization even when the
    /// value-keyed factor cache misses.
    pub pattern_fingerprint: Option<u64>,
}

impl WireSolve {
    /// Build a dense solve frame, computing the fingerprint.
    pub fn dense(a: DenseMatrix, b: Vec<f64>) -> WireSolve {
        let fingerprint = fingerprint_dense(a.rows(), a.cols(), a.data());
        WireSolve {
            id: None,
            matrix: WireMatrix::Dense(a),
            b,
            key: None,
            no_cache: false,
            fingerprint,
            pattern_fingerprint: None,
        }
    }

    /// Build a sparse solve frame, computing both fingerprints.
    pub fn sparse(a: CsrMatrix, b: Vec<f64>) -> WireSolve {
        let fingerprint = fingerprint_csr(&a);
        let pattern_fingerprint = Some(fingerprint_csr_pattern(&a));
        WireSolve {
            id: None,
            matrix: WireMatrix::Sparse(a),
            b,
            key: None,
            no_cache: false,
            fingerprint,
            pattern_fingerprint,
        }
    }

    pub fn with_id(mut self, id: u64) -> WireSolve {
        self.id = Some(id);
        self
    }

    /// Pin an explicit cache key. Keys must fit the wire's 53-bit key
    /// space (see [`crate::wire::fingerprint::KEY_MASK`]) — larger
    /// values are rejected when the frame is decoded.
    pub fn with_key(mut self, key: u64) -> WireSolve {
        self.key = Some(key);
        self
    }

    pub fn without_cache(mut self) -> WireSolve {
        self.no_cache = true;
        self
    }

    /// System size.
    pub fn n(&self) -> usize {
        self.matrix.n()
    }

    /// The `matrix_key` this frame submits with: explicit key if given,
    /// else the content fingerprint; `None` when caching is disabled.
    pub fn effective_key(&self) -> Option<u64> {
        if self.no_cache {
            None
        } else {
            self.key.or(Some(self.fingerprint))
        }
    }

    /// The pattern key this frame submits with (sparse frames only).
    /// An explicit `key` override does not touch it — the pattern key
    /// always describes the actual structure — but `no_cache` disables
    /// it along with everything else.
    pub fn effective_pattern_key(&self) -> Option<u64> {
        if self.no_cache {
            None
        } else {
            self.pattern_fingerprint
        }
    }
}

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestFrame {
    /// Dense solve (`op: "solve"`).
    Solve(WireSolve),
    /// Sparse solve (`op: "solve_sparse"`), inline triplets or `mtx_path`.
    SolveSparse(WireSolve),
    /// Metrics snapshot request (`op: "metrics"`).
    Metrics,
    /// Orderly end of session (`op: "shutdown"`).
    Shutdown,
}

/// The solved system sent back for a solve frame.
#[derive(Debug, Clone, PartialEq)]
pub struct WireSolution {
    pub id: u64,
    /// Solution vector, or the failure message.
    pub result: std::result::Result<Vec<f64>, String>,
    /// ∞-norm residual (NaN on failure; encoded as JSON `null`).
    pub residual: f64,
    pub backend: String,
    pub batch_size: usize,
    /// The effective matrix key the request ran under.
    pub matrix_key: Option<u64>,
    pub timings: Timings,
}

/// Machine-readable class of an `error` frame — the taxonomy clients
/// dispatch on (retry vs fix-the-frame vs give-up). The human-readable
/// `message` elaborates; the code is the contract. Documented
/// frame-by-frame in `docs/PROTOCOL.md` §Error frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ErrorCode {
    /// The request line was not a valid frame (bad JSON, unknown `op`,
    /// inconsistent payload). Fix the frame; retrying verbatim fails.
    Decode,
    /// Admission control shed the request (session limit reached or
    /// solve queue full). Transient: back off and retry.
    Busy,
    /// The per-session request deadline elapsed before the solve
    /// finished. The solve may still complete server-side; its result
    /// is discarded.
    Deadline,
    /// The request line exceeded the session's frame-size cap. The rest
    /// of the line was discarded; the session continues.
    Oversized,
    /// Server-side failure outside the client's control (service shut
    /// down mid-request, dropped reply). Also the decode default when a
    /// peer omits `code` (pre-taxonomy servers).
    #[default]
    Internal,
}

impl ErrorCode {
    /// Wire name (the `code` field of an `error` frame).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Decode => "decode",
            ErrorCode::Busy => "busy",
            ErrorCode::Deadline => "deadline",
            ErrorCode::Oversized => "oversized",
            ErrorCode::Internal => "internal",
        }
    }

    /// Parse a wire name; `None` for unknown codes (a decode error —
    /// new codes are a protocol revision, not a silent extension).
    pub fn parse(name: &str) -> Option<ErrorCode> {
        Some(match name {
            "decode" => ErrorCode::Decode,
            "busy" => ErrorCode::Busy,
            "deadline" => ErrorCode::Deadline,
            "oversized" => ErrorCode::Oversized,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }

    /// All codes, for doc/spec exhaustiveness tests.
    pub const ALL: [ErrorCode; 5] = [
        ErrorCode::Decode,
        ErrorCode::Busy,
        ErrorCode::Deadline,
        ErrorCode::Oversized,
        ErrorCode::Internal,
    ];
}

/// A response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseFrame {
    Solution(WireSolution),
    Metrics(MetricsSnapshot),
    /// Frame-level failure (decode error, rejected request, expired
    /// deadline). The session continues after an error frame.
    Error { code: ErrorCode, message: String },
    /// Acknowledges `shutdown` (or a server-initiated drain); last
    /// frame of a session.
    Goodbye { served: u64 },
}

impl ResponseFrame {
    /// Build an error frame.
    pub fn error(code: ErrorCode, message: impl Into<String>) -> ResponseFrame {
        ResponseFrame::Error { code, message: message.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};

    #[test]
    fn error_codes_round_trip_their_names() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.name()), Some(code), "{code:?}");
        }
        assert_eq!(ErrorCode::parse("transient"), None);
        // Peers that predate the taxonomy omit `code`; the decode
        // default must be the catch-all class.
        assert_eq!(ErrorCode::default(), ErrorCode::Internal);
        let f = ResponseFrame::error(ErrorCode::Busy, "try later");
        assert_eq!(
            f,
            ResponseFrame::Error { code: ErrorCode::Busy, message: "try later".into() }
        );
    }

    #[test]
    fn effective_key_prefers_explicit_then_fingerprint() {
        let a = diag_dominant_dense(4, GenSeed(1));
        let ws = WireSolve::dense(a.clone(), vec![1.0; 4]);
        assert_eq!(ws.effective_key(), Some(ws.fingerprint));
        let pinned = WireSolve::dense(a.clone(), vec![1.0; 4]).with_key(99);
        assert_eq!(pinned.effective_key(), Some(99));
        let uncached = WireSolve::dense(a, vec![1.0; 4]).without_cache();
        assert_eq!(uncached.effective_key(), None);
    }

    #[test]
    fn same_matrix_same_fingerprint_across_frames() {
        let a = diag_dominant_dense(6, GenSeed(2));
        let f1 = WireSolve::dense(a.clone(), vec![1.0; 6]).fingerprint;
        let f2 = WireSolve::dense(a, vec![2.0; 6]).fingerprint;
        // The RHS is not part of the matrix identity.
        assert_eq!(f1, f2);
    }

    #[test]
    fn sparse_frames_fingerprint_csr_content() {
        let a = diag_dominant_sparse(8, 3, GenSeed(3));
        let ws = WireSolve::sparse(a.clone(), vec![1.0; 8]);
        assert_eq!(ws.fingerprint, crate::wire::fingerprint::fingerprint_csr(&a));
        assert_eq!(
            ws.pattern_fingerprint,
            Some(crate::wire::fingerprint::fingerprint_csr_pattern(&a))
        );
        assert_eq!(ws.effective_pattern_key(), ws.pattern_fingerprint);
        assert_eq!(ws.n(), 8);
        // An explicit key override leaves the pattern key alone, but
        // no_cache disables both; dense frames never carry one.
        let pinned = WireSolve::sparse(a.clone(), vec![1.0; 8]).with_key(7);
        assert_eq!(pinned.effective_pattern_key(), pinned.pattern_fingerprint);
        let uncached = WireSolve::sparse(a, vec![1.0; 8]).without_cache();
        assert_eq!(uncached.effective_pattern_key(), None);
        let dense = WireSolve::dense(diag_dominant_dense(4, GenSeed(9)), vec![1.0; 4]);
        assert_eq!(dense.effective_pattern_key(), None);
    }
}
