//! NDJSON frame codec: one JSON object per line, scanned — never
//! tree-parsed — on the way in.
//!
//! Decoding pulls [`Scanner`](super::scanner::Scanner) events and
//! routes `values`/`row`/`col`/`val`/`b` arrays straight into flat
//! `Vec` buffers, hashing matrix content with FNV-1a as the numbers
//! stream by (see [`super::fingerprint`]). Field order on the wire is
//! free (JSON objects are unordered) and unknown fields are skipped,
//! so the protocol is forward-extensible.
//!
//! Request schema (`op` selects the frame):
//!
//! ```text
//! {"op":"solve",        "rows":N,["cols":N,] "values":[row-major f64...],
//!                       "b":[f64...], ["id":u64,] ["key":u64,] ["no_cache":bool]}
//! {"op":"solve_sparse", "rows":N,"cols":N, "row":[i...],"col":[j...],"val":[v...],
//!                       "b":[f64...], ...}               // COO triplets, any order
//! {"op":"solve_sparse", "mtx_path":"path.mtx", "b":[f64...], ...}
//! {"op":"metrics"}
//! {"op":"shutdown"}
//! ```
//!
//! Response schema mirrors [`ResponseFrame`]. The full field-by-field
//! contract lives in `docs/PROTOCOL.md` (spot-checked against this
//! codec by `tests/protocol_doc.rs`); see `README.md` for a
//! copy-pasteable session.

use std::fmt::Write as _;
use std::io::{BufRead, Write};

use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::request::Timings;
use crate::matrix::{io as matrix_io, CooMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};
use crate::util::json::emit_str;
use crate::wire::fingerprint::{combine_dense, fingerprint_csr, fingerprint_csr_pattern, Fnv1a};
use crate::wire::frame::{ErrorCode, RequestFrame, ResponseFrame, WireMatrix, WireSolve, WireSolution};
use crate::wire::scanner::{Event, Scanner};

// ---- decoding --------------------------------------------------------------

fn jerr(msg: impl Into<String>) -> EbvError {
    EbvError::Json(msg.into())
}

/// Convert a JSON number to a non-negative integer field.
fn as_index(x: f64, field: &str) -> Result<u64> {
    if x >= 0.0 && x.fract() == 0.0 && x <= 2f64.powi(53) {
        Ok(x as u64)
    } else {
        Err(jerr(format!("field `{field}`: expected a non-negative integer, got {x}")))
    }
}

/// Pull events for one member value and discard them (unknown field).
fn skip_value<R: BufRead>(sc: &mut Scanner<R>) -> Result<()> {
    let mut depth = 0usize;
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::ObjectStart | Event::ArrayStart => depth += 1,
            Event::ObjectEnd | Event::ArrayEnd => {
                depth -= 1;
                if depth == 0 {
                    return Ok(());
                }
            }
            _ => {
                if depth == 0 {
                    return Ok(());
                }
            }
        }
    }
}

/// Stream a `[f64...]` member into `out`, hashing each element.
fn read_f64_array<R: BufRead>(
    sc: &mut Scanner<R>,
    out: &mut Vec<f64>,
    hash: &mut Fnv1a,
    field: &str,
) -> Result<()> {
    match sc.next_event()? {
        Some(Event::ArrayStart) => {}
        _ => return Err(jerr(format!("field `{field}`: expected an array"))),
    }
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::Num(x) => {
                hash.write_f64(x);
                out.push(x);
            }
            Event::ArrayEnd => return Ok(()),
            other => {
                return Err(jerr(format!("field `{field}`: expected numbers, got {other:?}")))
            }
        }
    }
}

/// Stream a `[usize...]` member into `out`.
fn read_index_array<R: BufRead>(
    sc: &mut Scanner<R>,
    out: &mut Vec<usize>,
    field: &str,
) -> Result<()> {
    match sc.next_event()? {
        Some(Event::ArrayStart) => {}
        _ => return Err(jerr(format!("field `{field}`: expected an array"))),
    }
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::Num(x) => out.push(as_index(x, field)? as usize),
            Event::ArrayEnd => return Ok(()),
            other => {
                return Err(jerr(format!("field `{field}`: expected indices, got {other:?}")))
            }
        }
    }
}

fn expect_num<R: BufRead>(sc: &mut Scanner<R>, field: &str) -> Result<f64> {
    match sc.next_event()? {
        Some(Event::Num(x)) => Ok(x),
        other => Err(jerr(format!("field `{field}`: expected a number, got {other:?}"))),
    }
}

fn expect_str<R: BufRead>(sc: &mut Scanner<R>, field: &str) -> Result<String> {
    match sc.next_event()? {
        Some(Event::Str(s)) => Ok(s),
        other => Err(jerr(format!("field `{field}`: expected a string, got {other:?}"))),
    }
}

fn expect_bool<R: BufRead>(sc: &mut Scanner<R>, field: &str) -> Result<bool> {
    match sc.next_event()? {
        Some(Event::Bool(b)) => Ok(b),
        other => Err(jerr(format!("field `{field}`: expected a bool, got {other:?}"))),
    }
}

/// Session-negotiation members that ride alongside a frame, outside
/// the frame payload proper (`docs/PROTOCOL.md` §Binary frames).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrameExt {
    /// The peer offered (on a request) or acknowledged (on a response)
    /// the binary frame encoding for the rest of the session.
    pub accept_binary: bool,
}

/// Decode-time policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DecodeOptions {
    /// Permit `mtx_path` references, which make the decoder read a
    /// server-local file named by the client. Off by default (the
    /// derived `Default`): only enable when every session peer is
    /// trusted with the server's filesystem (the CLI exposes
    /// `--allow-mtx-path`).
    pub allow_mtx_path: bool,
}

/// Accumulated request fields; arrays land here directly from the scan.
#[derive(Default)]
struct ReqAcc {
    op: Option<String>,
    id: Option<u64>,
    rows: Option<usize>,
    cols: Option<usize>,
    values: Option<Vec<f64>>,
    row: Option<Vec<usize>>,
    col: Option<Vec<usize>>,
    val: Option<Vec<f64>>,
    b: Option<Vec<f64>>,
    key: Option<u64>,
    no_cache: bool,
    mtx_path: Option<String>,
    accept_binary: bool,
    /// Streaming hash of `values` in arrival (row-major) order.
    values_hash: Fnv1a,
}

/// Decode one request line with default (restrictive) options.
pub fn decode_request(line: &str) -> Result<RequestFrame> {
    decode_request_with(line, &DecodeOptions::default())
}

/// Decode one request line. The scanner runs over the raw bytes; large
/// payload arrays are ingested without constructing a `Json` tree.
pub fn decode_request_with(line: &str, opts: &DecodeOptions) -> Result<RequestFrame> {
    decode_request_ext(line, opts).map(|(frame, _)| frame)
}

/// Decode one request line, surfacing the session-negotiation members
/// (`accept_binary`) alongside the frame.
pub fn decode_request_ext(line: &str, opts: &DecodeOptions) -> Result<(RequestFrame, FrameExt)> {
    let mut sc = Scanner::new(line.as_bytes());
    match sc.next_event()? {
        Some(Event::ObjectStart) => {}
        _ => return Err(jerr("request frame must be a JSON object")),
    }

    let mut acc = ReqAcc::default();
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::ObjectEnd => break,
            Event::Key(k) => match k.as_str() {
                "op" => acc.op = Some(expect_str(&mut sc, "op")?),
                "id" => acc.id = Some(as_index(expect_num(&mut sc, "id")?, "id")?),
                "rows" => {
                    acc.rows = Some(as_index(expect_num(&mut sc, "rows")?, "rows")? as usize)
                }
                "cols" => {
                    acc.cols = Some(as_index(expect_num(&mut sc, "cols")?, "cols")? as usize)
                }
                "key" => acc.key = Some(as_index(expect_num(&mut sc, "key")?, "key")?),
                "no_cache" => acc.no_cache = expect_bool(&mut sc, "no_cache")?,
                "accept_binary" => acc.accept_binary = expect_bool(&mut sc, "accept_binary")?,
                "mtx_path" => acc.mtx_path = Some(expect_str(&mut sc, "mtx_path")?),
                "values" => {
                    // Last duplicate member wins (matching the tree
                    // parser); restart the hash so the fingerprint
                    // always describes the values actually kept.
                    acc.values_hash = Fnv1a::new();
                    let mut v = Vec::new();
                    read_f64_array(&mut sc, &mut v, &mut acc.values_hash, "values")?;
                    acc.values = Some(v);
                }
                "row" => {
                    let mut v = Vec::new();
                    read_index_array(&mut sc, &mut v, "row")?;
                    acc.row = Some(v);
                }
                "col" => {
                    let mut v = Vec::new();
                    read_index_array(&mut sc, &mut v, "col")?;
                    acc.col = Some(v);
                }
                "val" => {
                    let mut v = Vec::new();
                    let mut scratch = Fnv1a::new();
                    read_f64_array(&mut sc, &mut v, &mut scratch, "val")?;
                    acc.val = Some(v);
                }
                "b" => {
                    let mut v = Vec::new();
                    let mut scratch = Fnv1a::new();
                    read_f64_array(&mut sc, &mut v, &mut scratch, "b")?;
                    acc.b = Some(v);
                }
                _ => skip_value(&mut sc)?,
            },
            other => return Err(jerr(format!("malformed request frame: {other:?}"))),
        }
    }
    sc.finish()?;

    let ext = FrameExt { accept_binary: acc.accept_binary };
    let frame = match acc.op.as_deref() {
        Some("metrics") => RequestFrame::Metrics,
        Some("shutdown") => RequestFrame::Shutdown,
        Some("solve") => RequestFrame::Solve(build_dense(acc)?),
        Some("solve_sparse") => RequestFrame::SolveSparse(build_sparse(acc, opts)?),
        Some(other) => return Err(jerr(format!("unknown op `{other}`"))),
        None => return Err(jerr("request frame missing `op`")),
    };
    Ok((frame, ext))
}

fn require<T>(v: Option<T>, field: &str) -> Result<T> {
    v.ok_or_else(|| jerr(format!("missing required field `{field}`")))
}

fn build_dense(acc: ReqAcc) -> Result<WireSolve> {
    let rows = require(acc.rows, "rows")?;
    let cols = acc.cols.unwrap_or(rows);
    let values = require(acc.values, "values")?;
    let b = require(acc.b, "b")?;
    // Checked: `rows`/`cols` are wire-supplied, and a wrapped multiply
    // would let an absurd shape slip past the length check.
    let expected = rows
        .checked_mul(cols)
        .ok_or_else(|| jerr(format!("rows*cols overflows: {rows}x{cols}")))?;
    if values.len() != expected {
        return Err(jerr(format!(
            "`values` has {} elements, expected rows*cols = {expected}",
            values.len(),
        )));
    }
    if b.len() != rows {
        return Err(jerr(format!("`b` has {} elements, expected rows = {rows}", b.len())));
    }
    // The hash streamed through during the `values` scan; combining it
    // with the shape here matches `fingerprint_dense` exactly.
    let fingerprint = combine_dense(rows, cols, acc.values_hash.finish());
    let a = DenseMatrix::from_vec(rows, cols, values)
        .map_err(|e| jerr(format!("dense payload: {e}")))?;
    Ok(WireSolve {
        id: acc.id,
        matrix: WireMatrix::Dense(a),
        b,
        key: acc.key,
        no_cache: acc.no_cache,
        fingerprint,
        pattern_fingerprint: None,
    })
}

fn build_sparse(acc: ReqAcc, opts: &DecodeOptions) -> Result<WireSolve> {
    let b = require(acc.b, "b")?;
    let a = if let Some(path) = &acc.mtx_path {
        if !opts.allow_mtx_path {
            return Err(jerr(
                "`mtx_path` is disabled on this server (start with --allow-mtx-path)".to_string(),
            ));
        }
        matrix_io::read_matrix_market(std::path::Path::new(path))?
    } else {
        let rows = require(acc.rows, "rows")?;
        let cols = acc.cols.unwrap_or(rows);
        // `rows` sizes the CSR row_ptr allocation; tie it to the inline
        // `b` payload *before* assembly so one absurd frame can't
        // allocate the server to death.
        if b.len() != rows {
            return Err(jerr(format!(
                "`b` has {} elements, expected rows = {rows}",
                b.len(),
            )));
        }
        let ri = require(acc.row, "row")?;
        let ci = require(acc.col, "col")?;
        let vv = require(acc.val, "val")?;
        if ri.len() != ci.len() || ri.len() != vv.len() {
            return Err(jerr(format!(
                "triplet arrays disagree: row={} col={} val={}",
                ri.len(),
                ci.len(),
                vv.len()
            )));
        }
        let mut coo = CooMatrix::new(rows, cols);
        for ((i, j), v) in ri.into_iter().zip(ci).zip(vv) {
            coo.push(i, j, v).map_err(|e| jerr(format!("triplet payload: {e}")))?;
        }
        coo.to_csr()
    };
    if b.len() != a.rows() {
        return Err(jerr(format!(
            "`b` has {} elements, expected rows = {}",
            b.len(),
            a.rows()
        )));
    }
    // Hash the assembled CSR so triplet order on the wire cannot split
    // the cache key for the same matrix; the structure-only pattern key
    // additionally survives value changes, keying the cached symbolic
    // analysis for same-pattern refactorizations.
    let fingerprint = fingerprint_csr(&a);
    let pattern_fingerprint = Some(fingerprint_csr_pattern(&a));
    Ok(WireSolve {
        id: acc.id,
        matrix: WireMatrix::Sparse(a),
        b,
        key: acc.key,
        no_cache: acc.no_cache,
        fingerprint,
        pattern_fingerprint,
    })
}

// ---- encoding --------------------------------------------------------------

/// Emit an f64 the same way `util::json` does: integral values without a
/// fraction, everything else via Rust's shortest round-trip formatting.
/// Non-finite values become `null` (only `residual` can legally be NaN).
fn push_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null");
    }
}

fn push_f64_array(out: &mut String, xs: &[f64]) {
    out.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_num(out, x);
    }
    out.push(']');
}

fn push_usize_array(out: &mut String, xs: impl IntoIterator<Item = usize>) {
    out.push('[');
    for (i, x) in xs.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{x}");
    }
    out.push(']');
}

fn push_solve_common(out: &mut String, ws: &WireSolve) {
    if let Some(id) = ws.id {
        let _ = write!(out, ",\"id\":{id}");
    }
    out.push_str(",\"b\":");
    push_f64_array(out, &ws.b);
    if let Some(key) = ws.key {
        let _ = write!(out, ",\"key\":{key}");
    }
    if ws.no_cache {
        out.push_str(",\"no_cache\":true");
    }
}

/// Encode a request frame as one NDJSON line (no trailing newline).
/// Matrices are written element-by-element — no intermediate `Json`
/// tree even for megabyte payloads.
pub fn encode_request(frame: &RequestFrame) -> String {
    let mut out = String::new();
    match frame {
        RequestFrame::Metrics => out.push_str("{\"op\":\"metrics\"}"),
        RequestFrame::Shutdown => out.push_str("{\"op\":\"shutdown\"}"),
        RequestFrame::Solve(ws) => {
            let WireMatrix::Dense(a) = &ws.matrix else {
                // Constructed only through WireSolve::dense/sparse, which
                // keep op and matrix variant consistent.
                unreachable!("Solve frame carries a dense matrix");
            };
            let _ = write!(out, "{{\"op\":\"solve\",\"rows\":{},\"cols\":{}", a.rows(), a.cols());
            out.push_str(",\"values\":");
            push_f64_array(&mut out, a.data());
            push_solve_common(&mut out, ws);
            out.push('}');
        }
        RequestFrame::SolveSparse(ws) => {
            let WireMatrix::Sparse(a) = &ws.matrix else {
                unreachable!("SolveSparse frame carries a CSR matrix");
            };
            let _ = write!(
                out,
                "{{\"op\":\"solve_sparse\",\"rows\":{},\"cols\":{}",
                a.rows(),
                a.cols()
            );
            out.push_str(",\"row\":");
            push_usize_array(
                &mut out,
                (0..a.rows()).flat_map(|r| {
                    let count = a.row_ptr()[r + 1] - a.row_ptr()[r];
                    std::iter::repeat_n(r, count)
                }),
            );
            out.push_str(",\"col\":");
            push_usize_array(&mut out, a.col_idx().iter().copied());
            out.push_str(",\"val\":");
            push_f64_array(&mut out, a.values());
            push_solve_common(&mut out, ws);
            out.push('}');
        }
    }
    out
}

/// Stamp the negotiation member onto an already-encoded NDJSON frame.
/// Member order on the wire is free, so the offer/ack simply goes
/// first: `{"accept_binary":true,<rest of the frame>`.
fn splice_accept_binary(line: &str) -> String {
    debug_assert!(line.starts_with('{'), "frames are JSON objects: {line}");
    format!("{{\"accept_binary\":true,{}", &line[1..])
}

/// Encode a request line that also offers the binary encoding for the
/// rest of the session (`docs/PROTOCOL.md` §Binary frames). Works for
/// any request frame — the offer commonly rides on the first solve.
pub fn encode_request_negotiating(frame: &RequestFrame) -> String {
    splice_accept_binary(&encode_request(frame))
}

/// Encode a response frame as one NDJSON line (no trailing newline).
pub fn encode_response(frame: &ResponseFrame) -> String {
    let mut out = String::new();
    match frame {
        ResponseFrame::Error { code, message } => {
            // Code names are lowercase identifiers — no escaping needed.
            let _ = write!(out, "{{\"op\":\"error\",\"code\":\"{}\",\"error\":", code.name());
            emit_str(message, &mut out);
            out.push('}');
        }
        ResponseFrame::Goodbye { served } => {
            let _ = write!(out, "{{\"op\":\"goodbye\",\"served\":{served}}}");
        }
        ResponseFrame::Metrics(m) => {
            let _ = write!(
                out,
                "{{\"op\":\"metrics\",\"submitted\":{},\"rejected\":{},\"completed\":{},\
                 \"failed\":{},\"batches\":{},\"batched_requests\":{},\"factor_hits\":{},\
                 \"factor_misses\":{}",
                m.submitted,
                m.rejected,
                m.completed,
                m.failed,
                m.batches,
                m.batched_requests,
                m.factor_hits,
                m.factor_misses
            );
            let _ = write!(
                out,
                ",\"symbolic_reuse\":{},\"numeric_refactor\":{}",
                m.symbolic_reuse, m.numeric_refactor
            );
            let _ = write!(
                out,
                ",\"engine_lanes\":{},\"engine_jobs\":{},\"engine_steps\":{},\
                 \"engine_barrier_waits\":{},\"panel_width\":{}",
                m.engine_lanes,
                m.engine_jobs,
                m.engine_steps,
                m.engine_barrier_waits,
                m.panel_width
            );
            // Kernel and schedule names are lowercase identifiers — no
            // JSON escaping needed (`auto|unroll4|unroll8|tiled`,
            // `barrier|dataflow`).
            let _ = write!(out, ",\"kernel\":\"{}\"", m.kernel.name());
            let _ = write!(out, ",\"schedule\":\"{}\"", m.schedule.name());
            let _ = write!(
                out,
                ",\"devices\":{},\"device_lanes\":{},\"device_jobs\":{},\
                 \"exchange_steps\":{},\"exchange_elems\":{}",
                m.devices,
                m.device_lanes,
                m.device_jobs,
                m.exchange_steps,
                m.exchange_elems
            );
            out.push_str(",\"mean_batch\":");
            push_num(&mut out, m.mean_batch);
            out.push_str(",\"lat_mean_s\":");
            push_num(&mut out, m.lat_mean_s);
            out.push_str(",\"lat_p50_s\":");
            push_num(&mut out, m.lat_p50_s);
            out.push_str(",\"lat_p99_s\":");
            push_num(&mut out, m.lat_p99_s);
            let _ = write!(
                out,
                ",\"dense_solves\":{},\"sparse_solves\":{}",
                m.dense_solves, m.sparse_solves
            );
            out.push_str(",\"dense_lat_mean_s\":");
            push_num(&mut out, m.dense_lat_mean_s);
            out.push_str(",\"dense_lat_p99_s\":");
            push_num(&mut out, m.dense_lat_p99_s);
            out.push_str(",\"sparse_lat_mean_s\":");
            push_num(&mut out, m.sparse_lat_mean_s);
            out.push_str(",\"sparse_lat_p99_s\":");
            push_num(&mut out, m.sparse_lat_p99_s);
            let _ = write!(
                out,
                ",\"busy_ns\":{},\"wait_ns\":{},\"profiled_jobs\":{}",
                m.busy_ns, m.wait_ns, m.profiled_jobs
            );
            out.push_str(",\"measured_imbalance\":");
            push_num(&mut out, m.measured_imbalance);
            let _ = write!(
                out,
                ",\"device_busy_ns\":{},\"exchange_ns\":{}",
                m.device_busy_ns, m.exchange_ns
            );
            out.push_str(",\"device_measured_imbalance\":");
            push_num(&mut out, m.device_measured_imbalance);
            let _ = write!(
                out,
                ",\"sessions_total\":{},\"active_sessions\":{},\"peak_sessions\":{},\
                 \"sessions_shed\":{}",
                m.sessions_total, m.active_sessions, m.peak_sessions, m.sessions_shed
            );
            let _ = write!(
                out,
                ",\"wire_frames\":{},\"wire_solves\":{},\"wire_errors\":{},\
                 \"wire_ingest_ns\":{},\"wire_encode_ns\":{}",
                m.wire_frames, m.wire_solves, m.wire_errors, m.wire_ingest_ns, m.wire_encode_ns
            );
            let _ = write!(
                out,
                ",\"binary_sessions\":{},\"wire_bytes_in\":{},\"wire_bytes_out\":{}",
                m.binary_sessions, m.wire_bytes_in, m.wire_bytes_out
            );
            out.push('}');
        }
        ResponseFrame::Solution(s) => match &s.result {
            Ok(x) => {
                push_solution_head(&mut out, s);
                for (i, &v) in x.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    push_num(&mut out, v);
                }
                push_solution_tail(&mut out, s);
            }
            Err(e) => {
                let _ = write!(out, "{{\"op\":\"solution\",\"id\":{}", s.id);
                out.push_str(",\"ok\":false,\"error\":");
                emit_str(e, &mut out);
                push_solution_meta(&mut out, s);
                out.push('}');
            }
        },
    }
    out
}

/// Everything of an ok-solution line before the `x` elements. Shared
/// between `encode_response` and the chunked [`ResponseWriter`] so the
/// streamed emission is byte-identical to the one-shot encoding by
/// construction.
fn push_solution_head(out: &mut String, s: &WireSolution) {
    let _ = write!(out, "{{\"op\":\"solution\",\"id\":{}", s.id);
    out.push_str(",\"ok\":true,\"x\":[");
}

/// Everything of an ok-solution line after the `x` elements.
fn push_solution_tail(out: &mut String, s: &WireSolution) {
    out.push(']');
    push_solution_meta(out, s);
    out.push('}');
}

/// The trailing metadata members every solution line carries.
fn push_solution_meta(out: &mut String, s: &WireSolution) {
    out.push_str(",\"residual\":");
    push_num(out, s.residual);
    out.push_str(",\"backend\":");
    emit_str(&s.backend, out);
    let _ = write!(out, ",\"batch_size\":{}", s.batch_size);
    if let Some(k) = s.matrix_key {
        let _ = write!(out, ",\"matrix_key\":{k}");
    }
    let _ = write!(
        out,
        ",\"timings\":{{\"queue_secs\":{},\"batch_secs\":{},\"exec_secs\":{}}}",
        fmt_num(s.timings.queue_secs),
        fmt_num(s.timings.batch_secs),
        fmt_num(s.timings.exec_secs)
    );
}

fn fmt_num(x: f64) -> String {
    let mut s = String::new();
    push_num(&mut s, x);
    s
}

// ---- streaming response emission --------------------------------------------

/// Solution vectors are streamed in chunks of this many elements, so
/// the emitter's scratch stays bounded no matter how large `x` is.
pub const WRITE_CHUNK: usize = 4096;

/// Streaming response emitter: the serve loop's replacement for
/// building each response as one full in-memory `String`.
///
/// Solution vectors — the only payload that scales with the problem —
/// are written to the transport in [`WRITE_CHUNK`]-element chunks:
/// verbatim `f64::to_le_bytes` columns once the session has negotiated
/// binary ([`ResponseWriter::enable_binary`]), shortest-round-trip
/// decimal otherwise. The NDJSON byte stream is identical to
/// [`encode_response`]'s by construction (both build from
/// `push_solution_head`/`push_solution_tail`). Control frames and
/// failed solutions are small and stay on the one-shot NDJSON path.
///
/// Every frame is flushed before the call returns, preserving the
/// write-and-flush-before-next-read session contract, and every byte
/// is counted toward [`ResponseWriter::bytes_out`].
pub struct ResponseWriter<W: Write> {
    out: W,
    binary: bool,
    /// The next frame must carry the `accept_binary` ack (either as a
    /// spliced NDJSON member or by itself being a binary frame).
    ack_pending: bool,
    bytes_out: u64,
    /// Reused text scratch — holds at most a head/tail or one chunk.
    scratch: String,
    /// Reused byte scratch for binary chunks.
    buf: Vec<u8>,
}

impl<W: Write> ResponseWriter<W> {
    pub fn new(out: W) -> ResponseWriter<W> {
        ResponseWriter {
            out,
            binary: false,
            ack_pending: false,
            bytes_out: 0,
            scratch: String::new(),
            buf: Vec::new(),
        }
    }

    /// Switch the session to binary solution emission (the peer sent
    /// `accept_binary`). The next frame written carries the ack.
    pub fn enable_binary(&mut self) {
        if !self.binary {
            self.binary = true;
            self.ack_pending = true;
        }
    }

    /// Has the session negotiated binary emission?
    pub fn is_binary(&self) -> bool {
        self.binary
    }

    /// Total bytes written to the transport so far.
    pub fn bytes_out(&self) -> u64 {
        self.bytes_out
    }

    /// Write one response frame and flush it. The whole emission —
    /// encode and transport write — runs under the `encode` span, so
    /// the PR-6 phase taxonomy keeps measuring response cost.
    pub fn write_frame(&mut self, frame: &ResponseFrame) -> Result<()> {
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Encode);
        let wrote = match frame {
            ResponseFrame::Solution(s) if s.result.is_ok() => {
                if self.binary {
                    self.write_solution_binary(s)
                } else {
                    self.write_solution_ndjson(s)
                }
            }
            other => {
                let line = encode_response(other);
                let line =
                    if self.ack_pending { splice_accept_binary(&line) } else { line };
                self.scratch.clear();
                self.scratch.push_str(&line);
                self.scratch.push('\n');
                self.put_scratch()
            }
        };
        self.ack_pending = false;
        wrote
            .and_then(|()| self.out.flush())
            .map_err(|e| EbvError::io("wire session: write", e))
    }

    fn put_scratch(&mut self) -> std::io::Result<()> {
        self.out.write_all(self.scratch.as_bytes())?;
        self.bytes_out += self.scratch.len() as u64;
        self.scratch.clear();
        Ok(())
    }

    fn put_buf(&mut self) -> std::io::Result<()> {
        self.out.write_all(&self.buf)?;
        self.bytes_out += self.buf.len() as u64;
        self.buf.clear();
        Ok(())
    }

    fn write_solution_ndjson(&mut self, s: &WireSolution) -> std::io::Result<()> {
        let x = s.result.as_ref().expect("caller checked result.is_ok()");
        self.scratch.clear();
        if self.ack_pending {
            // Unreachable in practice (an ack-pending session emits
            // binary solutions), but kept correct: splice the ack.
            let mut head = String::new();
            push_solution_head(&mut head, s);
            self.scratch.push_str(&splice_accept_binary(&head));
        } else {
            push_solution_head(&mut self.scratch, s);
        }
        self.put_scratch()?;
        for (c, chunk) in x.chunks(WRITE_CHUNK).enumerate() {
            for (i, &v) in chunk.iter().enumerate() {
                if c > 0 || i > 0 {
                    self.scratch.push(',');
                }
                push_num(&mut self.scratch, v);
            }
            self.put_scratch()?;
        }
        push_solution_tail(&mut self.scratch, s);
        self.scratch.push('\n');
        self.put_scratch()
    }

    fn write_solution_binary(&mut self, s: &WireSolution) -> std::io::Result<()> {
        let x = s.result.as_ref().expect("caller checked result.is_ok()");
        self.buf.clear();
        super::binary::push_solution_prefix(&mut self.buf, s)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string()))?;
        self.put_buf()?;
        for chunk in x.chunks(WRITE_CHUNK) {
            self.buf.reserve(8 * chunk.len());
            for &v in chunk {
                self.buf.extend_from_slice(&v.to_le_bytes());
            }
            self.put_buf()?;
        }
        Ok(())
    }
}

// ---- response decoding (client side / round-trip tests) --------------------

#[derive(Default)]
struct RespAcc {
    op: Option<String>,
    id: Option<u64>,
    ok: Option<bool>,
    x: Option<Vec<f64>>,
    error: Option<String>,
    code: Option<ErrorCode>,
    residual: Option<f64>,
    backend: Option<String>,
    batch_size: Option<usize>,
    matrix_key: Option<u64>,
    timings: Timings,
    served: Option<u64>,
    accept_binary: bool,
    metrics: MetricsSnapshot,
}

/// Decode one response line (the client half of the protocol).
pub fn decode_response(line: &str) -> Result<ResponseFrame> {
    decode_response_ext(line).map(|(frame, _)| frame)
}

/// Decode one response line, surfacing the session-negotiation members
/// (the server's `accept_binary` ack) alongside the frame.
pub fn decode_response_ext(line: &str) -> Result<(ResponseFrame, FrameExt)> {
    let mut sc = Scanner::new(line.as_bytes());
    match sc.next_event()? {
        Some(Event::ObjectStart) => {}
        _ => return Err(jerr("response frame must be a JSON object")),
    }

    let mut acc = RespAcc::default();
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::ObjectEnd => break,
            Event::Key(k) => match k.as_str() {
                "op" => acc.op = Some(expect_str(&mut sc, "op")?),
                "id" => acc.id = Some(as_index(expect_num(&mut sc, "id")?, "id")?),
                "ok" => acc.ok = Some(expect_bool(&mut sc, "ok")?),
                "error" => acc.error = Some(expect_str(&mut sc, "error")?),
                "code" => {
                    let name = expect_str(&mut sc, "code")?;
                    acc.code = Some(ErrorCode::parse(&name).ok_or_else(|| {
                        jerr(format!("field `code`: unknown error code `{name}`"))
                    })?);
                }
                "backend" => acc.backend = Some(expect_str(&mut sc, "backend")?),
                "accept_binary" => {
                    acc.accept_binary = expect_bool(&mut sc, "accept_binary")?
                }
                "served" => acc.served = Some(as_index(expect_num(&mut sc, "served")?, "served")?),
                "batch_size" => {
                    acc.batch_size =
                        Some(as_index(expect_num(&mut sc, "batch_size")?, "batch_size")? as usize)
                }
                "matrix_key" => {
                    acc.matrix_key = Some(as_index(expect_num(&mut sc, "matrix_key")?, "matrix_key")?)
                }
                "x" => {
                    let mut v = Vec::new();
                    let mut scratch = Fnv1a::new();
                    read_f64_array(&mut sc, &mut v, &mut scratch, "x")?;
                    acc.x = Some(v);
                }
                "residual" => {
                    acc.residual = Some(match sc.next_event()? {
                        Some(Event::Num(v)) => v,
                        Some(Event::Null) => f64::NAN,
                        other => {
                            return Err(jerr(format!("field `residual`: unexpected {other:?}")))
                        }
                    })
                }
                "timings" => acc.timings = decode_timings(&mut sc)?,
                "submitted" => acc.metrics.submitted = as_index(expect_num(&mut sc, &k)?, &k)?,
                "rejected" => acc.metrics.rejected = as_index(expect_num(&mut sc, &k)?, &k)?,
                "completed" => acc.metrics.completed = as_index(expect_num(&mut sc, &k)?, &k)?,
                "failed" => acc.metrics.failed = as_index(expect_num(&mut sc, &k)?, &k)?,
                "batches" => acc.metrics.batches = as_index(expect_num(&mut sc, &k)?, &k)?,
                "batched_requests" => {
                    acc.metrics.batched_requests = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "factor_hits" => acc.metrics.factor_hits = as_index(expect_num(&mut sc, &k)?, &k)?,
                "factor_misses" => {
                    acc.metrics.factor_misses = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "symbolic_reuse" => {
                    acc.metrics.symbolic_reuse = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "numeric_refactor" => {
                    acc.metrics.numeric_refactor = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "engine_lanes" => acc.metrics.engine_lanes = as_index(expect_num(&mut sc, &k)?, &k)?,
                "engine_jobs" => acc.metrics.engine_jobs = as_index(expect_num(&mut sc, &k)?, &k)?,
                "engine_steps" => acc.metrics.engine_steps = as_index(expect_num(&mut sc, &k)?, &k)?,
                "engine_barrier_waits" => {
                    acc.metrics.engine_barrier_waits = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "panel_width" => acc.metrics.panel_width = as_index(expect_num(&mut sc, &k)?, &k)?,
                "kernel" => {
                    let name = expect_str(&mut sc, &k)?;
                    acc.metrics.kernel = crate::solver::Kernel::parse(&name)
                        .ok_or_else(|| jerr(format!("field `kernel`: unknown kernel `{name}`")))?;
                }
                "schedule" => {
                    let name = expect_str(&mut sc, &k)?;
                    acc.metrics.schedule = crate::exec::Schedule::parse(&name).ok_or_else(
                        || jerr(format!("field `schedule`: unknown schedule `{name}`")),
                    )?;
                }
                "devices" => acc.metrics.devices = as_index(expect_num(&mut sc, &k)?, &k)?,
                "device_lanes" => {
                    acc.metrics.device_lanes = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "device_jobs" => {
                    acc.metrics.device_jobs = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "exchange_steps" => {
                    acc.metrics.exchange_steps = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "exchange_elems" => {
                    acc.metrics.exchange_elems = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "mean_batch" => acc.metrics.mean_batch = expect_num(&mut sc, &k)?,
                "lat_mean_s" => acc.metrics.lat_mean_s = expect_num(&mut sc, &k)?,
                "lat_p50_s" => acc.metrics.lat_p50_s = expect_num(&mut sc, &k)?,
                "lat_p99_s" => acc.metrics.lat_p99_s = expect_num(&mut sc, &k)?,
                "dense_solves" => acc.metrics.dense_solves = as_index(expect_num(&mut sc, &k)?, &k)?,
                "sparse_solves" => {
                    acc.metrics.sparse_solves = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "dense_lat_mean_s" => acc.metrics.dense_lat_mean_s = expect_num(&mut sc, &k)?,
                "dense_lat_p99_s" => acc.metrics.dense_lat_p99_s = expect_num(&mut sc, &k)?,
                "sparse_lat_mean_s" => acc.metrics.sparse_lat_mean_s = expect_num(&mut sc, &k)?,
                "sparse_lat_p99_s" => acc.metrics.sparse_lat_p99_s = expect_num(&mut sc, &k)?,
                "busy_ns" => acc.metrics.busy_ns = as_index(expect_num(&mut sc, &k)?, &k)?,
                "wait_ns" => acc.metrics.wait_ns = as_index(expect_num(&mut sc, &k)?, &k)?,
                "profiled_jobs" => {
                    acc.metrics.profiled_jobs = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "measured_imbalance" => acc.metrics.measured_imbalance = expect_num(&mut sc, &k)?,
                "device_busy_ns" => {
                    acc.metrics.device_busy_ns = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "exchange_ns" => acc.metrics.exchange_ns = as_index(expect_num(&mut sc, &k)?, &k)?,
                "device_measured_imbalance" => {
                    acc.metrics.device_measured_imbalance = expect_num(&mut sc, &k)?
                }
                "sessions_total" => {
                    acc.metrics.sessions_total = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "active_sessions" => {
                    acc.metrics.active_sessions = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "peak_sessions" => {
                    acc.metrics.peak_sessions = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "sessions_shed" => {
                    acc.metrics.sessions_shed = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "wire_frames" => acc.metrics.wire_frames = as_index(expect_num(&mut sc, &k)?, &k)?,
                "wire_solves" => acc.metrics.wire_solves = as_index(expect_num(&mut sc, &k)?, &k)?,
                "wire_errors" => acc.metrics.wire_errors = as_index(expect_num(&mut sc, &k)?, &k)?,
                "wire_ingest_ns" => {
                    acc.metrics.wire_ingest_ns = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "wire_encode_ns" => {
                    acc.metrics.wire_encode_ns = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "binary_sessions" => {
                    acc.metrics.binary_sessions = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "wire_bytes_in" => {
                    acc.metrics.wire_bytes_in = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                "wire_bytes_out" => {
                    acc.metrics.wire_bytes_out = as_index(expect_num(&mut sc, &k)?, &k)?
                }
                _ => skip_value(&mut sc)?,
            },
            other => return Err(jerr(format!("malformed response frame: {other:?}"))),
        }
    }
    sc.finish()?;

    let ext = FrameExt { accept_binary: acc.accept_binary };
    let frame = match acc.op.as_deref() {
        Some("goodbye") => ResponseFrame::Goodbye { served: require(acc.served, "served")? },
        Some("error") => ResponseFrame::Error {
            // Absent on pre-taxonomy peers: classify as `internal`.
            code: acc.code.unwrap_or_default(),
            message: require(acc.error, "error")?,
        },
        Some("metrics") => ResponseFrame::Metrics(acc.metrics),
        Some("solution") => {
            let ok = require(acc.ok, "ok")?;
            let result = if ok {
                Ok(require(acc.x, "x")?)
            } else {
                Err(require(acc.error, "error")?)
            };
            ResponseFrame::Solution(WireSolution {
                id: require(acc.id, "id")?,
                result,
                residual: acc.residual.unwrap_or(f64::NAN),
                backend: acc.backend.unwrap_or_default(),
                batch_size: acc.batch_size.unwrap_or(1),
                matrix_key: acc.matrix_key,
                timings: acc.timings,
            })
        }
        Some(other) => return Err(jerr(format!("unknown response op `{other}`"))),
        None => return Err(jerr("response frame missing `op`")),
    };
    Ok((frame, ext))
}

fn decode_timings<R: BufRead>(sc: &mut Scanner<R>) -> Result<Timings> {
    match sc.next_event()? {
        Some(Event::ObjectStart) => {}
        _ => return Err(jerr("field `timings`: expected an object")),
    }
    let mut t = Timings::default();
    loop {
        match sc.next_event()?.ok_or_else(|| jerr("unexpected end of frame"))? {
            Event::ObjectEnd => return Ok(t),
            Event::Key(k) => {
                let v = expect_num(sc, &k)?;
                match k.as_str() {
                    "queue_secs" => t.queue_secs = v,
                    "batch_secs" => t.batch_secs = v,
                    "exec_secs" => t.exec_secs = v,
                    _ => {}
                }
            }
            other => return Err(jerr(format!("malformed timings: {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};
    use crate::wire::fingerprint::fingerprint_dense;

    #[test]
    fn dense_request_round_trips() {
        let a = diag_dominant_dense(5, GenSeed(11));
        let ws = WireSolve::dense(a, vec![1.0, 2.0, 3.0, 4.0, 5.0]).with_id(7).with_key(42);
        let frame = RequestFrame::Solve(ws.clone());
        let line = encode_request(&frame);
        let back = decode_request(&line).unwrap();
        assert_eq!(back, frame);
        // Decoding recomputed the identical fingerprint.
        let RequestFrame::Solve(dec) = back else { unreachable!() };
        assert_eq!(dec.fingerprint, ws.fingerprint);
    }

    #[test]
    fn sparse_request_round_trips() {
        let a = diag_dominant_sparse(12, 4, GenSeed(12));
        let ws = WireSolve::sparse(a, vec![0.5; 12]);
        let frame = RequestFrame::SolveSparse(ws);
        let line = encode_request(&frame);
        assert_eq!(decode_request(&line).unwrap(), frame);
    }

    #[test]
    fn control_requests_round_trip() {
        for frame in [RequestFrame::Metrics, RequestFrame::Shutdown] {
            assert_eq!(decode_request(&encode_request(&frame)).unwrap(), frame);
        }
    }

    #[test]
    fn decode_accepts_any_field_order_and_unknown_fields() {
        let line = r#"{"b":[1,2],"future_field":{"nested":[1,2,3]},"values":[4,1,1,3],"op":"solve","rows":2}"#;
        let RequestFrame::Solve(ws) = decode_request(line).unwrap() else {
            panic!("expected solve frame")
        };
        assert_eq!(ws.n(), 2);
        assert_eq!(ws.b, vec![1.0, 2.0]);
        assert_eq!(ws.fingerprint, fingerprint_dense(2, 2, &[4.0, 1.0, 1.0, 3.0]));
    }

    #[test]
    fn streaming_fingerprint_matches_slice_fingerprint() {
        let a = diag_dominant_dense(9, GenSeed(13));
        let expected = fingerprint_dense(9, 9, a.data());
        let line = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 9])));
        let RequestFrame::Solve(ws) = decode_request(&line).unwrap() else { unreachable!() };
        assert_eq!(ws.fingerprint, expected);
    }

    #[test]
    fn triplet_order_does_not_change_fingerprint() {
        let fwd = r#"{"op":"solve_sparse","rows":2,"cols":2,"row":[0,0,1],"col":[0,1,1],"val":[4,-1,3],"b":[1,2]}"#;
        let rev = r#"{"op":"solve_sparse","rows":2,"cols":2,"row":[1,0,0],"col":[1,1,0],"val":[3,-1,4],"b":[1,2]}"#;
        let RequestFrame::SolveSparse(a) = decode_request(fwd).unwrap() else { unreachable!() };
        let RequestFrame::SolveSparse(b) = decode_request(rev).unwrap() else { unreachable!() };
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.matrix, b.matrix);
    }

    #[test]
    fn decode_rejects_inconsistent_payloads() {
        // values length mismatch
        assert!(decode_request(r#"{"op":"solve","rows":2,"values":[1,2,3],"b":[1,2]}"#).is_err());
        // rhs length mismatch
        assert!(
            decode_request(r#"{"op":"solve","rows":2,"values":[1,0,0,1],"b":[1]}"#).is_err()
        );
        // triplet arrays disagree
        assert!(decode_request(
            r#"{"op":"solve_sparse","rows":2,"cols":2,"row":[0],"col":[0,1],"val":[1],"b":[1,2]}"#
        )
        .is_err());
        // out-of-bounds triplet
        assert!(decode_request(
            r#"{"op":"solve_sparse","rows":2,"cols":2,"row":[5],"col":[0],"val":[1],"b":[1,2]}"#
        )
        .is_err());
        // unknown / missing op
        assert!(decode_request(r#"{"op":"fly"}"#).is_err());
        assert!(decode_request(r#"{"rows":2}"#).is_err());
        // non-integer index fields
        assert!(decode_request(r#"{"op":"solve","rows":2.5,"values":[],"b":[]}"#).is_err());
        // not an object
        assert!(decode_request("[1,2,3]").is_err());
        // trailing garbage
        assert!(decode_request(r#"{"op":"metrics"} extra"#).is_err());
    }

    #[test]
    fn hostile_shapes_are_rejected_before_allocation() {
        // rows*cols wraps u64 — must error, not bypass the length check.
        let overflow = format!(
            r#"{{"op":"solve","rows":2048,"cols":{},"values":[],"b":[{}]}}"#,
            1u64 << 53, // passes the integer-field check; 2048 * 2^53 wraps u64
            vec!["1"; 2048].join(",")
        );
        let err = decode_request(&overflow).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // Absurd sparse `rows` with a tiny payload: caught by the b/rows
        // tie *before* the CSR row_ptr allocation would happen.
        let huge = r#"{"op":"solve_sparse","rows":4503599627370496,"cols":1,"row":[],"col":[],"val":[],"b":[]}"#;
        assert!(decode_request(huge).is_err());
    }

    #[test]
    fn mtx_path_requires_opt_in() {
        let line = r#"{"op":"solve_sparse","mtx_path":"/etc/hostname","b":[1]}"#;
        let err = decode_request(line).unwrap_err();
        assert!(err.to_string().contains("mtx_path"), "{err}");
        // With the option set, the failure becomes an ordinary I/O or
        // parse error from actually resolving the file.
        let opts = DecodeOptions { allow_mtx_path: true };
        let err = decode_request_with(
            r#"{"op":"solve_sparse","mtx_path":"/nonexistent.mtx","b":[1]}"#,
            &opts,
        )
        .unwrap_err();
        assert!(!err.to_string().contains("disabled"), "{err}");
    }

    #[test]
    fn duplicate_values_member_keeps_fingerprint_of_kept_array() {
        // Last duplicate wins (tree-parser semantics) — and the
        // fingerprint must describe the kept array, not both.
        let line = r#"{"op":"solve","rows":2,"values":[9,9,9,9],"values":[4,1,1,3],"b":[1,2]}"#;
        let RequestFrame::Solve(ws) = decode_request(line).unwrap() else { unreachable!() };
        assert_eq!(ws.fingerprint, fingerprint_dense(2, 2, &[4.0, 1.0, 1.0, 3.0]));
    }

    #[test]
    fn solution_responses_round_trip() {
        let ok = ResponseFrame::Solution(WireSolution {
            id: 3,
            result: Ok(vec![1.0, -2.5, 3.25]),
            residual: 1.25e-12,
            backend: "native-ebv".into(),
            batch_size: 4,
            matrix_key: Some(0xdead_beef),
            timings: Timings { queue_secs: 0.5, batch_secs: 0.25, exec_secs: 0.125 },
        });
        assert_eq!(decode_response(&encode_response(&ok)).unwrap(), ok);

        let failed = ResponseFrame::Solution(WireSolution {
            id: 4,
            result: Err("singular pivot at step 1: |0| < 0.0000000001".into()),
            residual: f64::NAN,
            backend: "native-ebv".into(),
            batch_size: 1,
            matrix_key: None,
            timings: Timings::default(),
        });
        // NaN != NaN, so compare the decoded pieces.
        let ResponseFrame::Solution(dec) = decode_response(&encode_response(&failed)).unwrap()
        else {
            panic!("expected solution")
        };
        assert_eq!(dec.id, 4);
        assert!(dec.result.is_err());
        assert!(dec.residual.is_nan());
    }

    #[test]
    fn metrics_error_goodbye_round_trip() {
        let m = ResponseFrame::Metrics(MetricsSnapshot {
            submitted: 10,
            rejected: 1,
            completed: 9,
            failed: 0,
            batches: 5,
            batched_requests: 9,
            factor_hits: 6,
            factor_misses: 3,
            symbolic_reuse: 2,
            numeric_refactor: 3,
            mean_batch: 1.8,
            lat_mean_s: 0.001,
            lat_p50_s: 0.00075,
            lat_p99_s: 0.0042,
            engine_lanes: 4,
            engine_jobs: 5,
            engine_steps: 620,
            engine_barrier_waits: 2480,
            panel_width: 64,
            kernel: crate::solver::Kernel::Tiled,
            schedule: crate::exec::Schedule::Dataflow,
            devices: 2,
            device_lanes: 2,
            device_jobs: 7,
            exchange_steps: 310,
            exchange_elems: 52_000,
            ..MetricsSnapshot::default()
        });
        assert_eq!(decode_response(&encode_response(&m)).unwrap(), m);

        let e = ResponseFrame::Error {
            code: ErrorCode::Decode,
            message: "json: bad \"frame\"\nwith newline".into(),
        };
        assert_eq!(decode_response(&encode_response(&e)).unwrap(), e);

        let g = ResponseFrame::Goodbye { served: 17 };
        assert_eq!(decode_response(&encode_response(&g)).unwrap(), g);
    }

    #[test]
    fn every_error_code_survives_the_wire() {
        for code in ErrorCode::ALL {
            let e = ResponseFrame::error(code, format!("class {}", code.name()));
            let line = encode_response(&e);
            assert!(
                line.contains(&format!("\"code\":\"{}\"", code.name())),
                "{line}"
            );
            assert_eq!(decode_response(&line).unwrap(), e);
        }
        // Unknown code names are a decode error (new codes are a
        // protocol revision), while an absent `code` — pre-taxonomy
        // servers — classifies as `internal`.
        let err =
            decode_response(r#"{"op":"error","code":"transient","error":"x"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown error code `transient`"), "{err}");
        let legacy = decode_response(r#"{"op":"error","error":"x"}"#).unwrap();
        assert_eq!(legacy, ResponseFrame::error(ErrorCode::Internal, "x"));
    }

    /// Field-drift guard: every `MetricsSnapshot` field distinct, exact
    /// equality after the wire round trip. Adding a snapshot field
    /// without teaching both `encode_response` and `decode_response`
    /// about it fails this test (the missing field decodes as its
    /// default, which never equals its distinct value here).
    #[test]
    fn every_metrics_field_survives_the_wire() {
        let m = MetricsSnapshot {
            submitted: 1,
            rejected: 2,
            completed: 3,
            failed: 4,
            batches: 5,
            batched_requests: 6,
            factor_hits: 7,
            factor_misses: 8,
            symbolic_reuse: 9,
            numeric_refactor: 10,
            mean_batch: 11.5,
            lat_mean_s: 12.5,
            lat_p50_s: 13.5,
            lat_p99_s: 14.5,
            engine_lanes: 15,
            engine_jobs: 16,
            engine_steps: 17,
            engine_barrier_waits: 18,
            panel_width: 19,
            kernel: crate::solver::Kernel::Unroll8,
            schedule: crate::exec::Schedule::Dataflow,
            devices: 20,
            device_lanes: 21,
            device_jobs: 22,
            exchange_steps: 23,
            exchange_elems: 24,
            dense_solves: 25,
            sparse_solves: 26,
            dense_lat_mean_s: 27.5,
            dense_lat_p99_s: 28.5,
            sparse_lat_mean_s: 29.5,
            sparse_lat_p99_s: 30.5,
            busy_ns: 31,
            wait_ns: 32,
            profiled_jobs: 33,
            measured_imbalance: 34.5,
            device_busy_ns: 35,
            exchange_ns: 36,
            device_measured_imbalance: 37.5,
            sessions_total: 38,
            active_sessions: 39,
            peak_sessions: 40,
            sessions_shed: 41,
            wire_frames: 42,
            wire_solves: 43,
            wire_errors: 44,
            wire_ingest_ns: 45,
            wire_encode_ns: 46,
            binary_sessions: 47,
            wire_bytes_in: 48,
            wire_bytes_out: 49,
        };
        let frame = ResponseFrame::Metrics(m);
        assert_eq!(decode_response(&encode_response(&frame)).unwrap(), frame);
    }

    #[test]
    fn unknown_kernel_name_is_a_decode_error() {
        let line = encode_response(&ResponseFrame::Metrics(MetricsSnapshot::default()));
        let line = line.replace("\"kernel\":\"auto\"", "\"kernel\":\"simd512\"");
        let err = decode_response(&line).unwrap_err();
        assert!(err.to_string().contains("unknown kernel `simd512`"), "{err}");
    }

    #[test]
    fn unknown_schedule_name_is_a_decode_error() {
        let line = encode_response(&ResponseFrame::Metrics(MetricsSnapshot::default()));
        assert!(line.contains("\"schedule\":\"barrier\""), "{line}");
        let line = line.replace("\"schedule\":\"barrier\"", "\"schedule\":\"wavefront\"");
        let err = decode_response(&line).unwrap_err();
        assert!(err.to_string().contains("unknown schedule `wavefront`"), "{err}");
    }

    #[test]
    fn negotiation_member_rides_any_frame_in_both_directions() {
        // Request side: the offer is an ordinary boolean member.
        let line = encode_request_negotiating(&RequestFrame::Metrics);
        assert_eq!(line, r#"{"accept_binary":true,"op":"metrics"}"#);
        let (frame, ext) = decode_request_ext(&line, &DecodeOptions::default()).unwrap();
        assert_eq!(frame, RequestFrame::Metrics);
        assert!(ext.accept_binary);
        // ...including on a payload-carrying solve.
        let a = diag_dominant_dense(3, GenSeed(21));
        let solve = RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 3]));
        let (frame, ext) =
            decode_request_ext(&encode_request_negotiating(&solve), &DecodeOptions::default())
                .unwrap();
        assert_eq!(frame, solve);
        assert!(ext.accept_binary);
        // Plain frames carry no offer.
        let (_, ext) =
            decode_request_ext(&encode_request(&solve), &DecodeOptions::default()).unwrap();
        assert!(!ext.accept_binary);
        // Response side: the ack is surfaced the same way, and peers
        // that predate the member never see a behavior change (unknown
        // members were always skipped).
        let (frame, ext) =
            decode_response_ext(r#"{"accept_binary":true,"op":"goodbye","served":2}"#).unwrap();
        assert_eq!(frame, ResponseFrame::Goodbye { served: 2 });
        assert!(ext.accept_binary);
    }

    #[test]
    fn streamed_ndjson_solution_is_byte_identical_to_encode_response() {
        // Cross the chunk boundary so head/chunk/tail seams are covered.
        let n = WRITE_CHUNK + 3;
        let x: Vec<f64> = (0..n).map(|i| (i as f64).sin() * 1e3).collect();
        let frame = ResponseFrame::Solution(WireSolution {
            id: 12,
            result: Ok(x),
            residual: 3.5e-14,
            backend: "native-ebv".into(),
            batch_size: 2,
            matrix_key: Some(99),
            timings: Timings { queue_secs: 0.001, batch_secs: 0.002, exec_secs: 0.003 },
        });
        let mut streamed = Vec::new();
        let mut w = ResponseWriter::new(&mut streamed);
        w.write_frame(&frame).unwrap();
        let bytes = w.bytes_out();
        let oneshot = encode_response(&frame) + "\n";
        assert_eq!(streamed, oneshot.as_bytes());
        assert_eq!(bytes, oneshot.len() as u64);
        // Control frames too.
        let goodbye = ResponseFrame::Goodbye { served: 1 };
        let mut streamed = Vec::new();
        ResponseWriter::new(&mut streamed).write_frame(&goodbye).unwrap();
        assert_eq!(streamed, (encode_response(&goodbye) + "\n").as_bytes());
    }

    #[test]
    fn binary_writer_acks_then_streams_verbatim_bits() {
        let sol = WireSolution {
            id: 7,
            result: Ok((0..WRITE_CHUNK * 2 + 5).map(|i| i as f64 * 0.1).collect()),
            residual: 1e-15,
            backend: "native-ebv".into(),
            batch_size: 1,
            matrix_key: None,
            timings: Timings::default(),
        };
        let mut out = Vec::new();
        let mut w = ResponseWriter::new(&mut out);
        w.enable_binary();
        assert!(w.is_binary());
        // An NDJSON control frame written while the ack is pending
        // carries the spliced member...
        w.write_frame(&ResponseFrame::Metrics(MetricsSnapshot::default())).unwrap();
        // ...and the ok-solution goes out as one binary frame.
        w.write_frame(&ResponseFrame::Solution(sol.clone())).unwrap();
        // Failed solutions stay NDJSON even on a binary session.
        let failed = ResponseFrame::Solution(WireSolution {
            result: Err("zero pivot".into()),
            ..sol.clone()
        });
        w.write_frame(&failed).unwrap();
        let total = w.bytes_out();
        assert_eq!(total, out.len() as u64);
        let frames = super::super::binary::decode_response_stream(&out).unwrap();
        assert_eq!(frames.len(), 3);
        assert!(frames[0].1.accept_binary, "ack on the first frame: {frames:?}");
        let ResponseFrame::Solution(back) = &frames[1].0 else { panic!("{frames:?}") };
        let (xb, xs) = (back.result.as_ref().unwrap(), sol.result.as_ref().unwrap());
        assert!(xb.iter().zip(xs).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(matches!(&frames[2].0, ResponseFrame::Solution(s) if s.result.is_err()));
    }

    #[test]
    fn encoded_frames_are_single_lines() {
        let a = diag_dominant_dense(3, GenSeed(14));
        let line = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 3])));
        assert!(!line.contains('\n'));
        let resp =
            encode_response(&ResponseFrame::error(ErrorCode::Decode, "multi\nline"));
        assert!(!resp.contains('\n'), "escapes keep frames single-line: {resp}");
    }
}
