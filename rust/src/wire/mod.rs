//! L4 wire layer: the streaming NDJSON solve protocol.
//!
//! Turns the in-process [`coordinator`](crate::coordinator) service
//! into a servable system: clients speak newline-delimited JSON frames
//! over any byte stream — `stdin`/`stdout` via `ebv-solve serve`, or
//! concurrent TCP sessions via `serve --listen ADDR` ([`listener`]);
//! the session loop itself is transport-agnostic. The protocol is
//! specified frame-by-frame in `docs/PROTOCOL.md` — framing, every
//! request/response field, fingerprint/cache-key semantics, the
//! [`ErrorCode`] taxonomy, and session lifecycle.
//!
//! Why a bespoke layer instead of tree-parsing requests with
//! [`util::json`](crate::util::json): a solve request carries the
//! matrix *inline* — `values` arrays of potentially millions of floats.
//! A `Json` tree holds every element as a boxed enum node before the
//! ingest code ever sees it; the [`scanner`] instead pulls SAX-style
//! events off the reader and the [`codec`] routes numbers directly into
//! `DenseMatrix`/`CooMatrix` buffers, hashing content with streaming
//! FNV-1a ([`fingerprint`]) along the way. That hash auto-populates
//! `matrix_key`, so a client replaying the same system against fresh
//! right-hand sides (the CFD time-stepping pattern, and the GLU3.0
//! observation that same-pattern repeat traffic is where serving wins
//! live) hits the worker `FactorCache` with zero key management.
//!
//! Module map:
//! * [`scanner`] — incremental zero-tree JSON event scanner;
//! * [`fingerprint`] — streaming FNV-1a matrix content hashes;
//! * [`frame`] — typed request/response frames;
//! * [`codec`] — NDJSON line encode/decode + streaming [`ResponseWriter`];
//! * [`binary`] — negotiated length-prefixed binary frames (verbatim
//!   f64le columns for solve payloads and ok-solutions);
//! * [`server`] — the blocking per-session loop;
//! * [`listener`] — TCP accept loop, admission control, drain.
//!
//! A complete session transcript lives in `README.md`; see
//! `examples/wire_session.rs` for the programmatic equivalent.

pub mod binary;
pub mod codec;
pub mod fingerprint;
pub mod frame;
pub mod listener;
pub mod scanner;
pub mod server;

pub use codec::{
    decode_request, decode_request_ext, decode_request_with, decode_response,
    decode_response_ext, encode_request, encode_request_negotiating, encode_response,
    DecodeOptions, FrameExt, ResponseWriter, WRITE_CHUNK,
};
pub use fingerprint::{
    fingerprint_csr, fingerprint_csr_pattern, fingerprint_dense, Fnv1a, KEY_MASK,
};
pub use frame::{ErrorCode, RequestFrame, ResponseFrame, WireMatrix, WireSolution, WireSolve};
pub use listener::{
    install_sigint_handler, ListenOptions, ListenerStats, ServerControl, WireServer,
};
pub use scanner::{parse_via_events, Event, Scanner};
pub use server::{serve_session, serve_session_with, SessionOptions, SessionStats};
