//! Streaming FNV-1a content fingerprints for wire payloads.
//!
//! The serving win GLU3.0-style systems get from repeated same-pattern
//! traffic (Peng & Tan, 2019) requires recognising "same matrix"
//! cheaply at ingest. The wire codec hashes matrix content *while the
//! bytes stream through the scanner* and uses the result as the
//! request's `matrix_key`, so the coordinator's `FactorCache` and the
//! batcher's key-grouping kick in without callers managing keys.
//!
//! Properties:
//! * dense fingerprints are computed incrementally from the `values`
//!   array in row-major arrival order — no second pass over a payload
//!   that may hold millions of floats;
//! * sparse fingerprints are computed from the *assembled CSR* (canonical
//!   row-sorted, duplicate-summed form), so the same matrix produces the
//!   same key regardless of triplet order on the wire;
//! * dense and sparse domains are tag-separated so a dense and a sparse
//!   matrix can never alias each other's cache entries.
//!
//! Keys are 53-bit so they survive every f64 JSON number path
//! unchanged (the wire carries numbers as f64; integers above 2^53 are
//! not exactly representable and would corrupt on decode).
//!
//! Trust boundary: FNV-1a is *not* collision-resistant, and the worker
//! `FactorCache` trusts keys without re-checking matrix identity — a
//! key collision (accidental or crafted, including via the explicit
//! `key` override) makes the colliding request reuse the other
//! matrix's factors and return a wrong solution, detectable only
//! through the reported residual. All clients of one service therefore
//! share a trust domain; do not expose a shared service to mutually
//! untrusting parties without disabling caching (`no_cache`) or adding
//! an authenticated keying layer.

use crate::matrix::CsrMatrix;

/// Wire keys are truncated to 53 bits (see module docs).
pub const KEY_MASK: u64 = (1 << 53) - 1;

/// Incremental 64-bit FNV-1a hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Fnv1a {
    pub const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    pub const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Fnv1a {
        Fnv1a(Self::OFFSET_BASIS)
    }

    /// Absorb raw bytes.
    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(Self::PRIME);
        }
        self.0 = h;
    }

    /// Absorb a `u64` (little-endian).
    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }

    /// Absorb an `f64` by bit pattern. Bit-level hashing means `-0.0`
    /// and `0.0` get different keys — a harmless false cache miss, never
    /// a false hit.
    #[inline]
    pub fn write_f64(&mut self, v: f64) {
        self.update(&v.to_bits().to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

/// Combine a dense shape with a pre-computed hash of the row-major
/// values (as produced by streaming `write_f64` calls during scan).
/// Truncated to [`KEY_MASK`] like every wire key.
pub fn combine_dense(rows: usize, cols: usize, values_hash: u64) -> u64 {
    let mut h = Fnv1a::new();
    h.update(b"EBV:dense");
    h.write_u64(rows as u64);
    h.write_u64(cols as u64);
    h.write_u64(values_hash);
    h.finish() & KEY_MASK
}

/// Fingerprint a dense matrix given its row-major values in one slice.
/// Identical to the streaming path: `combine_dense` over a `write_f64`
/// fold of the values.
pub fn fingerprint_dense(rows: usize, cols: usize, values: &[f64]) -> u64 {
    let mut hv = Fnv1a::new();
    for &v in values {
        hv.write_f64(v);
    }
    combine_dense(rows, cols, hv.finish())
}

/// Fingerprint an assembled CSR matrix (canonical sparse form).
/// Truncated to [`KEY_MASK`] like every wire key.
pub fn fingerprint_csr(m: &CsrMatrix) -> u64 {
    let mut h = Fnv1a::new();
    h.update(b"EBV:csr");
    h.write_u64(m.rows() as u64);
    h.write_u64(m.cols() as u64);
    for &p in m.row_ptr() {
        h.write_u64(p as u64);
    }
    for &j in m.col_idx() {
        h.write_u64(j as u64);
    }
    for &v in m.values() {
        h.write_f64(v);
    }
    h.finish() & KEY_MASK
}

/// Fingerprint only the *structure* of an assembled CSR matrix: shape,
/// row pointers and column indices — values excluded. Two same-pattern
/// matrices with different values share this key while their
/// [`fingerprint_csr`] value keys differ; that split is what lets the
/// coordinator cache sparse *symbolic analyses* (fill pattern, level
/// DAG) across refactorizations where full-factor caching misses. The
/// domain tag keeps pattern keys from ever aliasing value keys.
/// Truncated to [`KEY_MASK`] like every wire key.
pub fn fingerprint_csr_pattern(m: &CsrMatrix) -> u64 {
    let mut h = Fnv1a::new();
    h.update(b"EBV:csr-pattern");
    h.write_u64(m.rows() as u64);
    h.write_u64(m.cols() as u64);
    for &p in m.row_ptr() {
        h.write_u64(p as u64);
    }
    for &j in m.col_idx() {
        h.write_u64(j as u64);
    }
    h.finish() & KEY_MASK
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_sparse, GenSeed};
    use crate::matrix::CooMatrix;

    #[test]
    fn matches_known_fnv1a_vectors() {
        // Reference values from the FNV spec (64-bit FNV-1a).
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv1a::new();
        h.update(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv1a::new();
        h.update(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn keys_fit_in_53_bits_for_f64_json_transport() {
        for seed in 0..32u64 {
            let m = diag_dominant_sparse(8, 3, GenSeed(seed));
            let k = fingerprint_csr(&m);
            assert!(k <= KEY_MASK);
            // Round-trips through f64 exactly — the wire invariant.
            assert_eq!(k as f64 as u64, k);
            let d = m.to_dense();
            let kd = fingerprint_dense(d.rows(), d.cols(), d.data());
            assert!(kd <= KEY_MASK);
        }
    }

    #[test]
    fn dense_fingerprint_is_order_and_shape_sensitive() {
        let v = [1.0, 2.0, 3.0, 4.0];
        let swapped = [2.0, 1.0, 3.0, 4.0];
        assert_eq!(fingerprint_dense(2, 2, &v), fingerprint_dense(2, 2, &v));
        assert_ne!(fingerprint_dense(2, 2, &v), fingerprint_dense(2, 2, &swapped));
        assert_ne!(fingerprint_dense(2, 2, &v), fingerprint_dense(1, 4, &v));
    }

    #[test]
    fn streaming_and_slice_dense_paths_agree() {
        let v = [0.5, -3.25, 1e300, 0.0];
        let mut hv = Fnv1a::new();
        for &x in &v {
            hv.write_f64(x);
        }
        assert_eq!(combine_dense(2, 2, hv.finish()), fingerprint_dense(2, 2, &v));
    }

    #[test]
    fn csr_fingerprint_is_triplet_order_independent() {
        let mut a = CooMatrix::new(3, 3);
        a.push(0, 0, 2.0).unwrap();
        a.push(2, 1, -1.0).unwrap();
        a.push(1, 1, 3.0).unwrap();
        let mut b = CooMatrix::new(3, 3);
        b.push(1, 1, 3.0).unwrap();
        b.push(0, 0, 2.0).unwrap();
        b.push(2, 1, -1.0).unwrap();
        assert_eq!(fingerprint_csr(&a.to_csr()), fingerprint_csr(&b.to_csr()));
    }

    #[test]
    fn dense_and_sparse_domains_never_alias() {
        let m = diag_dominant_sparse(8, 3, GenSeed(3));
        let dense = m.to_dense();
        assert_ne!(
            fingerprint_csr(&m),
            fingerprint_dense(dense.rows(), dense.cols(), dense.data())
        );
    }

    #[test]
    fn different_matrices_get_different_keys() {
        let a = diag_dominant_sparse(16, 4, GenSeed(1));
        let b = diag_dominant_sparse(16, 4, GenSeed(2));
        assert_ne!(fingerprint_csr(&a), fingerprint_csr(&b));
    }

    #[test]
    fn pattern_key_ignores_values_but_not_structure() {
        let a = diag_dominant_sparse(16, 4, GenSeed(4));
        let rescaled = CsrMatrix::from_raw(
            a.rows(),
            a.cols(),
            a.row_ptr().to_vec(),
            a.col_idx().to_vec(),
            a.values().iter().map(|&v| v * 3.5).collect(),
        )
        .unwrap();
        // Same structure, different values: pattern keys agree, value
        // keys split.
        assert_eq!(fingerprint_csr_pattern(&a), fingerprint_csr_pattern(&rescaled));
        assert_ne!(fingerprint_csr(&a), fingerprint_csr(&rescaled));
        // Different structure: pattern keys split too.
        let other = diag_dominant_sparse(16, 4, GenSeed(5));
        assert_ne!(fingerprint_csr_pattern(&a), fingerprint_csr_pattern(&other));
        // Pattern and value domains never alias (distinct tags).
        assert_ne!(fingerprint_csr_pattern(&a), fingerprint_csr(&a));
        // 53-bit transport invariant holds for pattern keys too.
        let k = fingerprint_csr_pattern(&a);
        assert!(k <= KEY_MASK);
        assert_eq!(k as f64 as u64, k);
    }
}
