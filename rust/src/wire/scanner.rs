//! Incremental, zero-tree JSON scanner (SAX-style event pull).
//!
//! [`Scanner`] yields a stream of [`Event`]s over any `BufRead` without
//! materialising a [`Json`](crate::util::json::Json) tree. A dense
//! `values: [...]` array of a million floats costs one `Vec<f64>` in
//! the consumer and nothing here — compare `Json::parse`, which builds
//! a million boxed `Json::Num` nodes first. The wire codec feeds
//! matrix payloads straight from scanner events into
//! `DenseMatrix`/`CooMatrix` buffers (see [`super::codec`]).
//!
//! Grammar and escape handling deliberately mirror `util::json`'s tree
//! parser — the two are differential-tested against each other in
//! `rust/tests/prop_wire.rs` on arbitrary valid documents.

use std::io::BufRead;

use crate::util::error::{EbvError, Result};
use crate::util::json::Json;

/// Can `byte` legally begin a JSON document? Whitespace, the two
/// container openers, strings, numbers (including a leading minus), and
/// the three literals — nothing else. The binary wire magic
/// ([`super::binary::MAGIC`]) is chosen outside this set, which is what
/// lets a session reader dispatch NDJSON-vs-binary on one peeked byte;
/// `super::binary` pins that disjointness at compile time.
pub const fn can_start_json(byte: u8) -> bool {
    matches!(
        byte,
        b' ' | b'\t' | b'\r' | b'\n' | b'{' | b'[' | b'"' | b'-' | b'0'..=b'9' | b't' | b'f'
            | b'n'
    )
}

/// One scanner event. Container contents are delivered between the
/// matching `*Start`/`*End` pair; object members arrive as a `Key`
/// event followed by the member value's event(s).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    ObjectStart,
    ObjectEnd,
    ArrayStart,
    ArrayEnd,
    Key(String),
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

/// Where the scanner is inside the current innermost container.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Ctx {
    /// Inside `[`, no element consumed yet.
    ArrFirst,
    /// Inside `[`, after an element (expect `,` or `]`).
    ArrNext,
    /// Inside `{`, no member consumed yet.
    ObjFirstKey,
    /// Inside `{`, after a member value (expect `,` or `}`).
    ObjNextKey,
    /// Inside `{`, after a `key:` (expect the member value).
    ObjValue,
}

/// Pull scanner over a byte stream. One JSON document per scanner; use
/// [`Scanner::finish`] to assert nothing but whitespace remains (NDJSON
/// framing feeds one line per document).
pub struct Scanner<R> {
    src: R,
    /// Byte offset consumed so far, for error messages.
    pos: u64,
    stack: Vec<Ctx>,
    /// Top-level value fully consumed.
    done: bool,
    /// Scratch for number tokens (reused across events).
    scratch: Vec<u8>,
}

impl<R: BufRead> Scanner<R> {
    pub fn new(src: R) -> Scanner<R> {
        Scanner { src, pos: 0, stack: Vec::new(), done: false, scratch: Vec::new() }
    }

    /// Current nesting depth (containers opened and not yet closed).
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    fn err(&self, msg: &str) -> EbvError {
        EbvError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&mut self) -> Result<Option<u8>> {
        let buf = self.src.fill_buf().map_err(|e| EbvError::io("wire scan: read", e))?;
        Ok(buf.first().copied())
    }

    fn bump(&mut self) -> Result<Option<u8>> {
        let b = self.peek()?;
        if b.is_some() {
            self.src.consume(1);
            self.pos += 1;
        }
        Ok(b)
    }

    fn skip_ws(&mut self) -> Result<()> {
        while matches!(self.peek()?, Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.src.consume(1);
            self.pos += 1;
        }
        Ok(())
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        match self.bump()? {
            Some(b) if b == want => Ok(()),
            _ => Err(self.err(&format!("expected `{}`", want as char))),
        }
    }

    /// Consume a literal word whose first byte is already peeked.
    fn literal(&mut self, word: &'static str) -> Result<()> {
        for &w in word.as_bytes() {
            match self.bump()? {
                Some(b) if b == w => {}
                _ => return Err(self.err(&format!("expected `{word}`"))),
            }
        }
        Ok(())
    }

    /// Bookkeeping after a complete value (scalar or closed container).
    fn after_value(&mut self) {
        match self.stack.last_mut() {
            None => self.done = true,
            Some(c @ (Ctx::ArrFirst | Ctx::ArrNext)) => *c = Ctx::ArrNext,
            Some(c @ (Ctx::ObjValue | Ctx::ObjFirstKey | Ctx::ObjNextKey)) => *c = Ctx::ObjNextKey,
        }
    }

    /// Parse the start of a value at the current position.
    fn value_event(&mut self) -> Result<Event> {
        match self.peek()? {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => {
                self.bump()?;
                self.stack.push(Ctx::ObjFirstKey);
                Ok(Event::ObjectStart)
            }
            Some(b'[') => {
                self.bump()?;
                self.stack.push(Ctx::ArrFirst);
                Ok(Event::ArrayStart)
            }
            Some(b'"') => {
                let s = self.string()?;
                self.after_value();
                Ok(Event::Str(s))
            }
            Some(b'n') => {
                self.literal("null")?;
                self.after_value();
                Ok(Event::Null)
            }
            Some(b't') => {
                self.literal("true")?;
                self.after_value();
                Ok(Event::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                self.after_value();
                Ok(Event::Bool(false))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let x = self.number()?;
                self.after_value();
                Ok(Event::Num(x))
            }
            Some(_) => Err(self.err("expected a JSON value")),
        }
    }

    /// Read a `"key":` prefix (cursor on the opening quote), leaving the
    /// cursor at the start of the member value.
    fn key_event(&mut self) -> Result<Event> {
        let key = self.string()?;
        self.skip_ws()?;
        self.expect(b':')?;
        self.skip_ws()?;
        *self.stack.last_mut().expect("key inside object") = Ctx::ObjValue;
        Ok(Event::Key(key))
    }

    /// Next event, or `None` once the document is fully consumed.
    /// Trailing non-whitespace after the document is an error.
    pub fn next_event(&mut self) -> Result<Option<Event>> {
        self.skip_ws()?;
        if self.done {
            return match self.peek()? {
                None => Ok(None),
                Some(_) => Err(self.err("trailing garbage after document")),
            };
        }
        match self.stack.last().copied() {
            // Top-level value start.
            None => self.value_event().map(Some),
            Some(Ctx::ArrFirst) => {
                if self.peek()? == Some(b']') {
                    self.bump()?;
                    self.stack.pop();
                    self.after_value();
                    Ok(Some(Event::ArrayEnd))
                } else {
                    self.value_event().map(Some)
                }
            }
            Some(Ctx::ArrNext) => match self.bump()? {
                Some(b',') => {
                    self.skip_ws()?;
                    self.value_event().map(Some)
                }
                Some(b']') => {
                    self.stack.pop();
                    self.after_value();
                    Ok(Some(Event::ArrayEnd))
                }
                _ => Err(self.err("expected `,` or `]`")),
            },
            Some(Ctx::ObjFirstKey) => {
                if self.peek()? == Some(b'}') {
                    self.bump()?;
                    self.stack.pop();
                    self.after_value();
                    Ok(Some(Event::ObjectEnd))
                } else {
                    self.key_event().map(Some)
                }
            }
            Some(Ctx::ObjNextKey) => match self.bump()? {
                Some(b',') => {
                    self.skip_ws()?;
                    self.key_event().map(Some)
                }
                Some(b'}') => {
                    self.stack.pop();
                    self.after_value();
                    Ok(Some(Event::ObjectEnd))
                }
                _ => Err(self.err("expected `,` or `}`")),
            },
            Some(Ctx::ObjValue) => self.value_event().map(Some),
        }
    }

    /// Assert the document is complete and only whitespace remains.
    pub fn finish(&mut self) -> Result<()> {
        if !self.done || !self.stack.is_empty() {
            return Err(self.err("document incomplete"));
        }
        self.skip_ws()?;
        match self.peek()? {
            None => Ok(()),
            Some(_) => Err(self.err("trailing garbage after document")),
        }
    }

    // ---- token readers ---------------------------------------------------

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump()? {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: collect the full sequence and decode.
                    let len = utf8_len(b);
                    self.scratch.clear();
                    self.scratch.push(b);
                    for _ in 1..len {
                        let nb =
                            self.bump()?.ok_or_else(|| self.err("truncated UTF-8"))?;
                        self.scratch.push(nb);
                    }
                    let chunk = std::str::from_utf8(&self.scratch)
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump()?.ok_or_else(|| self.err("truncated \\u escape"))?;
            let d =
                (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<f64> {
        self.scratch.clear();
        if self.peek()? == Some(b'-') {
            self.scratch.push(b'-');
            self.bump()?;
        }
        while matches!(self.peek()?, Some(c) if c.is_ascii_digit()) {
            let b = self.bump()?.unwrap();
            self.scratch.push(b);
        }
        if self.peek()? == Some(b'.') {
            self.scratch.push(b'.');
            self.bump()?;
            while matches!(self.peek()?, Some(c) if c.is_ascii_digit()) {
                let b = self.bump()?.unwrap();
                self.scratch.push(b);
            }
        }
        if matches!(self.peek()?, Some(b'e' | b'E')) {
            let b = self.bump()?.unwrap();
            self.scratch.push(b);
            if matches!(self.peek()?, Some(b'+' | b'-')) {
                let b = self.bump()?.unwrap();
                self.scratch.push(b);
            }
            while matches!(self.peek()?, Some(c) if c.is_ascii_digit()) {
                let b = self.bump()?.unwrap();
                self.scratch.push(b);
            }
        }
        let text = std::str::from_utf8(&self.scratch).expect("number bytes are ASCII");
        text.parse::<f64>().map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Build a [`Json`] tree from scanner events. Exists for differential
/// testing against `Json::parse` and as a migration aid — production
/// ingest paths consume events directly and never call this.
pub fn parse_via_events<R: BufRead>(src: R) -> Result<Json> {
    let mut sc = Scanner::new(src);
    let ev = sc
        .next_event()?
        .ok_or_else(|| EbvError::Json("empty document".into()))?;
    let v = build_value(&mut sc, ev)?;
    sc.finish()?;
    Ok(v)
}

fn build_value<R: BufRead>(sc: &mut Scanner<R>, ev: Event) -> Result<Json> {
    match ev {
        Event::Null => Ok(Json::Null),
        Event::Bool(b) => Ok(Json::Bool(b)),
        Event::Num(x) => Ok(Json::Num(x)),
        Event::Str(s) => Ok(Json::Str(s)),
        Event::ArrayStart => {
            let mut items = Vec::new();
            loop {
                match sc.next_event()? {
                    Some(Event::ArrayEnd) => return Ok(Json::Arr(items)),
                    Some(ev) => items.push(build_value(sc, ev)?),
                    None => return Err(EbvError::Json("unterminated array".into())),
                }
            }
        }
        Event::ObjectStart => {
            let mut map = std::collections::BTreeMap::new();
            loop {
                match sc.next_event()? {
                    Some(Event::ObjectEnd) => return Ok(Json::Obj(map)),
                    Some(Event::Key(k)) => {
                        let ev = sc
                            .next_event()?
                            .ok_or_else(|| EbvError::Json("missing member value".into()))?;
                        map.insert(k, build_value(sc, ev)?);
                    }
                    _ => return Err(EbvError::Json("malformed object".into())),
                }
            }
        }
        Event::Key(_) | Event::ArrayEnd | Event::ObjectEnd => {
            Err(EbvError::Json("unexpected structural event".into()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(text: &str) -> Vec<Event> {
        let mut sc = Scanner::new(text.as_bytes());
        let mut out = Vec::new();
        while let Some(ev) = sc.next_event().unwrap() {
            out.push(ev);
        }
        sc.finish().unwrap();
        out
    }

    #[test]
    fn json_start_set_is_exact_and_excludes_the_binary_magic() {
        for b in [b'{', b'[', b'"', b'-', b'0', b'9', b't', b'f', b'n', b' ', b'\t'] {
            assert!(can_start_json(b), "{}", b as char);
        }
        for b in [0xEBu8, 0xFF, b'}', b']', b'x', b'+', b'\''] {
            assert!(!can_start_json(b), "{b:#04x}");
        }
        assert!(!can_start_json(crate::wire::binary::MAGIC[0]));
    }

    #[test]
    fn scalar_documents() {
        assert_eq!(events("null"), vec![Event::Null]);
        assert_eq!(events(" true "), vec![Event::Bool(true)]);
        assert_eq!(events("-1.5e3"), vec![Event::Num(-1500.0)]);
        assert_eq!(events("\"hi\\n\""), vec![Event::Str("hi\n".into())]);
    }

    #[test]
    fn nested_structure_event_order() {
        let evs = events(r#"{"a": [1, {"b": null}], "c": true}"#);
        assert_eq!(
            evs,
            vec![
                Event::ObjectStart,
                Event::Key("a".into()),
                Event::ArrayStart,
                Event::Num(1.0),
                Event::ObjectStart,
                Event::Key("b".into()),
                Event::Null,
                Event::ObjectEnd,
                Event::ArrayEnd,
                Event::Key("c".into()),
                Event::Bool(true),
                Event::ObjectEnd,
            ]
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(events("[]"), vec![Event::ArrayStart, Event::ArrayEnd]);
        assert_eq!(events("{}"), vec![Event::ObjectStart, Event::ObjectEnd]);
        assert_eq!(
            events("[[],{}]"),
            vec![
                Event::ArrayStart,
                Event::ArrayStart,
                Event::ArrayEnd,
                Event::ObjectStart,
                Event::ObjectEnd,
                Event::ArrayEnd,
            ]
        );
    }

    #[test]
    fn long_numeric_array_streams_without_tree() {
        let doc = format!("[{}]", (0..10_000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let mut sc = Scanner::new(doc.as_bytes());
        assert_eq!(sc.next_event().unwrap(), Some(Event::ArrayStart));
        let mut sum = 0.0;
        loop {
            match sc.next_event().unwrap().unwrap() {
                Event::Num(x) => sum += x,
                Event::ArrayEnd => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        sc.finish().unwrap();
        assert_eq!(sum, (0..10_000).sum::<i64>() as f64);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["{", "[1,]", "[1 2]", "{\"a\" 1}", "nul", "\"open", "1 2", "[1],"] {
            let mut sc = Scanner::new(bad.as_bytes());
            let mut failed = false;
            loop {
                match sc.next_event() {
                    Err(_) => {
                        failed = true;
                        break;
                    }
                    Ok(None) => break,
                    Ok(Some(_)) => {}
                }
            }
            if !failed {
                failed = sc.finish().is_err();
            }
            assert!(failed, "accepted malformed input {bad:?}");
        }
    }

    #[test]
    fn unicode_strings_and_escapes() {
        assert_eq!(events(r#""é😀""#), vec![Event::Str("é😀".into())]);
        assert_eq!(events(r#""😀""#), vec![Event::Str("😀".into())]);
        assert_eq!(events(r#""é""#), vec![Event::Str("é".into())]);
    }

    #[test]
    fn finish_rejects_trailing_garbage() {
        let mut sc = Scanner::new("{} x".as_bytes());
        assert_eq!(sc.next_event().unwrap(), Some(Event::ObjectStart));
        assert_eq!(sc.next_event().unwrap(), Some(Event::ObjectEnd));
        assert!(sc.finish().is_err());
    }

    #[test]
    fn parse_via_events_matches_tree_parser() {
        for doc in [
            "null",
            "[1,2,3]",
            r#"{"a":{"b":[true,false,null]},"c":"x\ty"}"#,
            r#"[{"deep":[[[1.25]]]}]"#,
        ] {
            assert_eq!(parse_via_events(doc.as_bytes()).unwrap(), Json::parse(doc).unwrap());
        }
    }

    #[test]
    fn errors_carry_byte_positions() {
        let mut sc = Scanner::new("[1,,]".as_bytes());
        sc.next_event().unwrap();
        sc.next_event().unwrap();
        let err = sc.next_event().unwrap_err();
        assert!(err.to_string().contains("byte"), "{err}");
    }
}
