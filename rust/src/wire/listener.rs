//! TCP front end: accept loop, admission control, graceful drain.
//!
//! [`WireServer`] owns a non-blocking [`TcpListener`] and runs the
//! serving edge of `ebv-solve serve --listen ADDR`: each accepted
//! connection gets its own named thread running the transport-generic
//! [`serve_session_with`] loop against the shared [`ServiceHandle`],
//! so concurrent sessions share one warmed-up coordinator — factor
//! cache, symbolic-analysis cache, and execution engine included.
//! Layering follows the protocol-edge/core split in DESIGN.md
//! §Serving edge: this module owns sockets and admission, `server`
//! owns framing and the session state machine, and the coordinator
//! never learns what a socket is.
//!
//! Admission control is strict and cheap: when `max_sessions` sessions
//! are active, a new connection is answered with a single `busy` error
//! frame and closed — shed load fails fast instead of queueing unread
//! sockets (see `docs/PROTOCOL.md` §Error frames). Graceful shutdown
//! ([`ServerControl::stop`] or, when enabled, SIGINT) stops the accept
//! loop, trips every session's drain flag, and joins the session
//! threads; each session answers its in-flight request, writes
//! `goodbye`, and closes.

use std::io::{BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::service::ServiceHandle;
use crate::util::error::{EbvError, Result};
use crate::wire::codec::encode_response;
use crate::wire::frame::{ErrorCode, ResponseFrame};
use crate::wire::server::{serve_session_with, SessionOptions};

/// How often the accept loop polls for new connections and the stop
/// flag; also the per-session socket read timeout, which bounds how
/// long a drain waits for an idle session to notice the flag.
const POLL_TICK: Duration = Duration::from_millis(50);

/// Listener policy.
#[derive(Debug, Clone)]
pub struct ListenOptions {
    /// Concurrent-session ceiling; connection `max_sessions + 1` is
    /// shed with a `busy` error frame.
    pub max_sessions: usize,
    /// Also treat a delivered SIGINT (see [`install_sigint_handler`])
    /// as a stop request. Off by default so tests and embedders are
    /// unaffected by process-global signal state.
    pub watch_sigint: bool,
    /// Per-session policy. The listener overrides
    /// [`SessionOptions::stop`] with its own drain flag.
    pub session: SessionOptions,
}

impl Default for ListenOptions {
    fn default() -> Self {
        ListenOptions {
            max_sessions: 8,
            watch_sigint: false,
            session: SessionOptions::default(),
        }
    }
}

/// What one [`WireServer::run`] served, for the final log line.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ListenerStats {
    /// Connections admitted to a session thread.
    pub sessions: u64,
    /// Connections shed with a `busy` frame.
    pub shed: u64,
}

/// Handle for requesting a graceful drain from another thread (or a
/// signal handler's watcher). Cloneable; all clones share one flag.
#[derive(Debug, Clone)]
pub struct ServerControl {
    stop: Arc<AtomicBool>,
}

impl ServerControl {
    /// Request drain: stop accepting, finish in-flight requests, say
    /// goodbye on every session. Idempotent.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

/// A bound, not-yet-running TCP serving edge.
#[derive(Debug)]
pub struct WireServer {
    listener: TcpListener,
    opts: ListenOptions,
    stop: Arc<AtomicBool>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:7070`, or port `0` for an
    /// OS-assigned port — read it back with [`local_addr`]).
    ///
    /// [`local_addr`]: WireServer::local_addr
    pub fn bind<A: ToSocketAddrs + std::fmt::Debug>(
        addr: A,
        opts: ListenOptions,
    ) -> Result<WireServer> {
        let listener = TcpListener::bind(&addr)
            .map_err(|e| EbvError::io(format!("wire listener: bind {addr:?}"), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| EbvError::io("wire listener: set_nonblocking", e))?;
        Ok(WireServer { listener, opts, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(|e| EbvError::io("wire listener: local_addr", e))
    }

    /// A stop handle for this server. Grab it before [`run`], hand it
    /// to whoever decides when to drain.
    ///
    /// [`run`]: WireServer::run
    pub fn control(&self) -> ServerControl {
        ServerControl { stop: Arc::clone(&self.stop) }
    }

    /// Accept and serve until stopped. Blocks the calling thread;
    /// session threads are scoped to this call and all joined before
    /// it returns, so the returned [`ListenerStats`] and the service's
    /// merged metrics are final. Single-shot: after a drain the stop
    /// flag stays set and a second `run` returns immediately.
    pub fn run(&self, svc: &ServiceHandle) -> Result<ListenerStats> {
        let active = AtomicUsize::new(0);
        let mut stats = ListenerStats::default();
        let mut accept_err = None;

        std::thread::scope(|scope| {
            loop {
                if self.opts.watch_sigint && sigint_tripped() {
                    log::info!(target: "wire", "SIGINT: draining");
                    self.stop.store(true, Ordering::Relaxed);
                }
                if self.stop.load(Ordering::Relaxed) {
                    break;
                }
                let (stream, peer) = match self.listener.accept() {
                    Ok(accepted) => accepted,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(POLL_TICK);
                        continue;
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        accept_err = Some(EbvError::io("wire listener: accept", e));
                        break;
                    }
                };
                if active.load(Ordering::Relaxed) >= self.opts.max_sessions {
                    stats.shed += 1;
                    svc.metrics().sessions_shed.fetch_add(1, Ordering::Relaxed);
                    log::info!(target: "wire", "shed {peer}: at max_sessions ({})", self.opts.max_sessions);
                    shed_busy(stream, self.opts.max_sessions);
                    continue;
                }
                stats.sessions += 1;
                // Count the admission here, not in the session thread:
                // the gate must see every admitted-but-not-yet-started
                // session or a burst could overshoot the ceiling.
                active.fetch_add(1, Ordering::Relaxed);
                let opts = SessionOptions {
                    stop: Some(Arc::clone(&self.stop)),
                    ..self.opts.session.clone()
                };
                let session_no = stats.sessions;
                let spawned = std::thread::Builder::new()
                    .name(format!("wire-session-{session_no}"))
                    .spawn_scoped(scope, {
                        let active = &active;
                        move || {
                            let _guard = ActiveGuard(active);
                            run_session(svc, stream, peer, session_no, opts);
                        }
                    });
                if let Err(e) = spawned {
                    // Couldn't start the thread; undo the admission.
                    active.fetch_sub(1, Ordering::Relaxed);
                    stats.sessions -= 1;
                    log::warn!(target: "wire", "spawn for {peer} failed: {e}");
                }
            }
            // Drain: no more accepts; trip every session's flag. The
            // scope joins the session threads on exit.
            self.stop.store(true, Ordering::Relaxed);
        });

        match accept_err {
            Some(e) => Err(e),
            None => Ok(stats),
        }
    }
}

/// Decrements the active-session gate when the session thread ends,
/// however it ends.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One admitted connection: read-timeout so the drain flag is polled,
/// split the stream, run the session loop, print the close summary.
fn run_session(
    svc: &ServiceHandle,
    stream: TcpStream,
    peer: SocketAddr,
    session_no: u64,
    opts: SessionOptions,
) {
    // The read timeout is what lets an idle session notice the drain
    // flag; without it we still serve, but drain waits on the client.
    if let Err(e) = stream.set_read_timeout(Some(POLL_TICK)) {
        log::warn!(target: "wire", "session {session_no} ({peer}): set_read_timeout failed: {e}");
    }
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            eprintln!("[wire] session {session_no} ({peer}): split failed: {e}");
            return;
        }
    };
    match serve_session_with(svc, BufReader::new(stream), writer, opts) {
        // Byte totals ride along so shed/deadline decisions can be
        // correlated with payload size straight from the log.
        Ok(stats) => eprintln!(
            "[wire] session {session_no} ({peer}) closed: frames={} solves={} errors={} \
             bytes_in={} bytes_out={}",
            stats.frames, stats.solves, stats.errors, stats.bytes_in, stats.bytes_out
        ),
        Err(e) => eprintln!("[wire] session {session_no} ({peer}) ended with error: {e}"),
    }
}

/// Answer a shed connection with one `busy` frame and close it.
fn shed_busy(mut stream: TcpStream, max_sessions: usize) {
    let frame = ResponseFrame::error(
        ErrorCode::Busy,
        format!("server is at max_sessions ({max_sessions}); retry later"),
    );
    let mut line = encode_response(&frame);
    line.push('\n');
    // Best effort: the peer may already be gone, and a shed path must
    // never block the acceptor.
    let _ = stream.set_write_timeout(Some(POLL_TICK));
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.flush();
}

static SIGINT_TRIPPED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigint(_signum: i32) {
    // Async-signal-safe: a single relaxed store, nothing else.
    SIGINT_TRIPPED.store(true, Ordering::Relaxed);
}

/// Install a SIGINT handler that trips the flag
/// [`ListenOptions::watch_sigint`] watches. Process-global; call once
/// from `main` before [`WireServer::run`]. No-op off Unix.
#[cfg(unix)]
pub fn install_sigint_handler() {
    extern "C" {
        // `signal(2)` from the platform libc — the one C symbol the
        // no-dependency rule lets us lean on. The handler registration
        // itself is `sighandler_t signal(int, sighandler_t)`.
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigint_handler() {}

/// Whether SIGINT has been delivered since the handler was installed.
pub fn sigint_tripped() -> bool {
    SIGINT_TRIPPED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::coordinator::SolverService;

    fn test_service() -> ServiceHandle {
        SolverService::start(ServiceConfig {
            lanes: 2,
            max_batch: 4,
            batch_window_us: 100,
            queue_capacity: 64,
            engine_lanes: 2,
            use_runtime: false,
            ..ServiceConfig::default()
        })
        .unwrap()
    }

    #[test]
    fn bind_ephemeral_and_stop_with_no_traffic() {
        let svc = test_service();
        let server = WireServer::bind("127.0.0.1:0", ListenOptions::default()).unwrap();
        let addr = server.local_addr().unwrap();
        assert_ne!(addr.port(), 0, "the OS resolved the ephemeral port");
        let control = server.control();
        assert!(!control.is_stopped());
        let stats = std::thread::scope(|scope| {
            let handle = scope.spawn(|| server.run(&svc));
            control.stop();
            handle.join().unwrap()
        })
        .unwrap();
        assert!(control.is_stopped());
        assert_eq!(stats, ListenerStats::default());
        // Single-shot: a drained server exits immediately on rerun.
        assert_eq!(server.run(&svc).unwrap(), ListenerStats::default());
        svc.shutdown();
    }

    #[test]
    fn stopped_control_is_idempotent_and_shared() {
        let server = WireServer::bind("127.0.0.1:0", ListenOptions::default()).unwrap();
        let a = server.control();
        let b = a.clone();
        a.stop();
        a.stop();
        assert!(b.is_stopped(), "clones share the flag");
    }
}
