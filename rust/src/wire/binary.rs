//! Binary wire frames: the negotiated fast path for payload-heavy
//! frames (`docs/PROTOCOL.md` §Binary frames).
//!
//! NDJSON stays the session default and the only control-plane
//! encoding — `metrics`, `error`, `goodbye`, and failed solutions are
//! always text. What moves to binary, once a session negotiates it
//! with `accept_binary` (see [`super::codec::FrameExt`]), are the
//! frames that carry megabyte float columns: dense/sparse solve
//! requests and ok-solutions. Those columns travel as verbatim
//! little-endian `f64` bits (index arrays as `u32le`), so a binary
//! round trip is bit-identical by construction — no decimal parse on
//! ingest, no decimal format on emit — and every bit-identity ledger
//! guarantee is format-inert (`rust/tests/wire_binary.rs` pins
//! NDJSON ≡ binary with `to_bits`).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! header (12 bytes):  magic 0xEB 0x56 | version u8 | kind u8 | payload_len u64le
//! kind 0x01 solve:        flags u8 | rows u32 | cols u32 | [id u64] | [key u64]
//!                         | values f64le × rows*cols | b f64le × rows
//! kind 0x02 solve_sparse: flags u8 | rows u32 | cols u32 | nnz u32 | [id u64] | [key u64]
//!                         | row u32le × nnz | col u32le × nnz | val f64le × nnz
//!                         | b f64le × rows
//! kind 0x03 solution:     flags u8 | id u64 | batch_size u32 | n u32 | [matrix_key u64]
//!                         | residual f64le | queue_secs f64le | batch_secs f64le
//!                         | exec_secs f64le | backend_len u8 | backend utf-8
//!                         | x f64le × n
//! ```
//!
//! The magic's first byte (`0xEB`) can never begin a JSON document
//! (compile-time pinned against [`super::scanner::can_start_json`]),
//! so the session reader dispatches per frame on one peeked byte and
//! mixed NDJSON/binary sessions are unambiguous. `payload_len` is
//! declared up front and checked against the session's
//! `max_frame_bytes` cap *before* any payload allocation — an absurd
//! declaration costs an `oversized` error frame and a streaming
//! discard, never memory.

use crate::coordinator::request::Timings;
use crate::matrix::{CooMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};
use crate::wire::codec::{decode_response_ext, FrameExt};
use crate::wire::fingerprint::{combine_dense, fingerprint_csr, fingerprint_csr_pattern, Fnv1a};
use crate::wire::frame::{RequestFrame, ResponseFrame, WireMatrix, WireSolution, WireSolve};
use crate::wire::scanner::can_start_json;

/// Frame magic: `0xEB 0x56` ("EBV"). The first byte is deliberately
/// outside the set of bytes that can start a JSON document.
pub const MAGIC: [u8; 2] = [0xEB, 0x56];

/// Binary framing version; a bump is a protocol revision.
pub const VERSION: u8 = 1;

/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 12;

/// Dense solve request (`op: "solve"` equivalent).
pub const KIND_SOLVE_DENSE: u8 = 0x01;
/// Sparse solve request (`op: "solve_sparse"` equivalent, COO triplets).
pub const KIND_SOLVE_SPARSE: u8 = 0x02;
/// Ok-solution response (`op: "solution"`, `ok: true` equivalent).
pub const KIND_SOLUTION: u8 = 0x03;

// The whole dispatch scheme rests on this byte being un-confusable
// with the start of an NDJSON frame.
const _: () = assert!(!can_start_json(MAGIC[0]), "binary magic must not start JSON");

/// Request flags (kinds 0x01/0x02).
const FLAG_ID: u8 = 0x01;
const FLAG_KEY: u8 = 0x02;
const FLAG_NO_CACHE: u8 = 0x04;
/// Solution flags (kind 0x03).
const FLAG_MATRIX_KEY: u8 = 0x01;

/// Ids and keys share the NDJSON integer range (53-bit JSON-safe, see
/// [`super::fingerprint::KEY_MASK`] docs) so a value that decodes from
/// one format always decodes from the other.
const MAX_WIRE_INT: u64 = 1 << 53;

fn berr(msg: impl Into<String>) -> EbvError {
    EbvError::Json(format!("binary frame: {}", msg.into()))
}

/// Does this byte open a binary frame? The session reader peeks one
/// byte per frame and dispatches on this.
pub fn is_magic(byte: u8) -> bool {
    byte == MAGIC[0]
}

/// A parsed frame header: the kind byte and the declared payload
/// length. The length is a *claim* — validate it against the session
/// cap before allocating anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: u8,
    pub payload_len: u64,
}

/// Encode a frame header.
pub fn encode_header(kind: u8, payload_len: u64) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0] = MAGIC[0];
    h[1] = MAGIC[1];
    h[2] = VERSION;
    h[3] = kind;
    h[4..12].copy_from_slice(&payload_len.to_le_bytes());
    h
}

/// Parse and validate a frame header (magic + version; the kind byte is
/// passed through so the payload decoder can reject unknown kinds
/// *after* the declared payload has been consumed — framing stays in
/// sync across the error).
pub fn parse_header(bytes: &[u8; HEADER_LEN]) -> Result<FrameHeader> {
    if bytes[0] != MAGIC[0] || bytes[1] != MAGIC[1] {
        return Err(berr(format!("bad magic {:#04x} {:#04x}", bytes[0], bytes[1])));
    }
    if bytes[2] != VERSION {
        return Err(berr(format!("unsupported version {} (this peer speaks {VERSION})", bytes[2])));
    }
    let payload_len = u64::from_le_bytes(bytes[4..12].try_into().expect("8 bytes"));
    Ok(FrameHeader { kind: bytes[3], payload_len })
}

// ---- little-endian cursor ---------------------------------------------------

struct Cursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, at: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&end| end <= self.bytes.len())
            .ok_or_else(|| berr(format!("payload truncated reading {what}")))?;
        let s = &self.bytes[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn done(&self, kind: &str) -> Result<()> {
        if self.at == self.bytes.len() {
            Ok(())
        } else {
            Err(berr(format!(
                "{kind} payload length mismatch: {} bytes declared, {} consumed",
                self.bytes.len(),
                self.at
            )))
        }
    }
}

fn push_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, x: f64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn as_u32(n: usize, what: &str) -> Result<u32> {
    u32::try_from(n).map_err(|_| berr(format!("{what} = {n} exceeds the u32 wire range")))
}

fn wire_int(x: u64, what: &str) -> Result<u64> {
    if x <= MAX_WIRE_INT {
        Ok(x)
    } else {
        Err(berr(format!("{what} = {x} exceeds the 53-bit wire integer range")))
    }
}

// ---- requests ---------------------------------------------------------------

fn request_flags(ws: &WireSolve) -> u8 {
    let mut flags = 0u8;
    if ws.id.is_some() {
        flags |= FLAG_ID;
    }
    if ws.key.is_some() {
        flags |= FLAG_KEY;
    }
    if ws.no_cache {
        flags |= FLAG_NO_CACHE;
    }
    flags
}

fn push_request_common(out: &mut Vec<u8>, ws: &WireSolve) -> Result<()> {
    if let Some(id) = ws.id {
        push_u64(out, wire_int(id, "id")?);
    }
    if let Some(key) = ws.key {
        push_u64(out, wire_int(key, "key")?);
    }
    Ok(())
}

/// Encode a solve request as one complete binary frame (header +
/// payload). Control frames (`metrics`/`shutdown`) have no binary form
/// — they are NDJSON by specification — and are refused here.
pub fn encode_request_binary(frame: &RequestFrame) -> Result<Vec<u8>> {
    let (kind, ws) = match frame {
        RequestFrame::Solve(ws) => (KIND_SOLVE_DENSE, ws),
        RequestFrame::SolveSparse(ws) => (KIND_SOLVE_SPARSE, ws),
        RequestFrame::Metrics | RequestFrame::Shutdown => {
            return Err(berr("control frames are NDJSON-only"));
        }
    };
    let mut payload = Vec::new();
    payload.push(request_flags(ws));
    match (&ws.matrix, kind) {
        (WireMatrix::Dense(a), KIND_SOLVE_DENSE) => {
            push_u32(&mut payload, as_u32(a.rows(), "rows")?);
            push_u32(&mut payload, as_u32(a.cols(), "cols")?);
            push_request_common(&mut payload, ws)?;
            payload.reserve(8 * (a.data().len() + ws.b.len()));
            for &v in a.data() {
                push_f64(&mut payload, v);
            }
            for &v in &ws.b {
                push_f64(&mut payload, v);
            }
        }
        (WireMatrix::Sparse(a), KIND_SOLVE_SPARSE) => {
            push_u32(&mut payload, as_u32(a.rows(), "rows")?);
            push_u32(&mut payload, as_u32(a.cols(), "cols")?);
            push_u32(&mut payload, as_u32(a.nnz(), "nnz")?);
            push_request_common(&mut payload, ws)?;
            payload.reserve(8 * (2 * a.nnz() + ws.b.len()));
            // Expand CSR back to COO rows, exactly like the NDJSON
            // `row` member.
            for r in 0..a.rows() {
                for _ in a.row_ptr()[r]..a.row_ptr()[r + 1] {
                    push_u32(&mut payload, r as u32);
                }
            }
            for &j in a.col_idx() {
                push_u32(&mut payload, as_u32(j, "col index")?);
            }
            for &v in a.values() {
                push_f64(&mut payload, v);
            }
            for &v in &ws.b {
                push_f64(&mut payload, v);
            }
        }
        _ => unreachable!("frame kind and matrix variant are kept consistent"),
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&encode_header(kind, payload.len() as u64));
    out.extend_from_slice(&payload);
    Ok(out)
}

fn read_request_common(cur: &mut Cursor, flags: u8) -> Result<(Option<u64>, Option<u64>, bool)> {
    let id = if flags & FLAG_ID != 0 { Some(wire_int(cur.u64("id")?, "id")?) } else { None };
    let key = if flags & FLAG_KEY != 0 { Some(wire_int(cur.u64("key")?, "key")?) } else { None };
    Ok((id, key, flags & FLAG_NO_CACHE != 0))
}

/// Exact payload size a dense/sparse request header block implies —
/// checked against the declared length before the column vectors are
/// materialised, so a length/payload mismatch is a typed error.
fn expect_len(kind: &str, declared: usize, fixed: u128, elems: u128) -> Result<()> {
    let want = fixed + 8 * elems;
    if declared as u128 != want {
        return Err(berr(format!(
            "{kind} payload length mismatch: {declared} bytes declared, {want} implied by shape"
        )));
    }
    Ok(())
}

fn decode_dense_payload(payload: &[u8]) -> Result<WireSolve> {
    let mut cur = Cursor::new(payload);
    let flags = cur.u8("flags")?;
    let rows = cur.u32("rows")? as usize;
    let cols = cur.u32("cols")? as usize;
    let (id, key, no_cache) = read_request_common(&mut cur, flags)?;
    let fixed = cur.at as u128;
    let cells = rows as u128 * cols as u128;
    expect_len("solve", payload.len(), fixed, cells + rows as u128)?;

    // Hash in row-major stream order — identical to the NDJSON scan, so
    // the auto-key is format-independent.
    let mut hash = Fnv1a::new();
    let mut values = Vec::with_capacity(rows * cols);
    for _ in 0..rows * cols {
        let v = cur.f64("values")?;
        hash.write_f64(v);
        values.push(v);
    }
    let mut b = Vec::with_capacity(rows);
    for _ in 0..rows {
        b.push(cur.f64("b")?);
    }
    cur.done("solve")?;
    let fingerprint = combine_dense(rows, cols, hash.finish());
    let a = DenseMatrix::from_vec(rows, cols, values)
        .map_err(|e| berr(format!("dense payload: {e}")))?;
    Ok(WireSolve {
        id,
        matrix: WireMatrix::Dense(a),
        b,
        key,
        no_cache,
        fingerprint,
        pattern_fingerprint: None,
    })
}

fn decode_sparse_payload(payload: &[u8]) -> Result<WireSolve> {
    let mut cur = Cursor::new(payload);
    let flags = cur.u8("flags")?;
    let rows = cur.u32("rows")? as usize;
    let cols = cur.u32("cols")? as usize;
    let nnz = cur.u32("nnz")? as usize;
    let (id, key, no_cache) = read_request_common(&mut cur, flags)?;
    let fixed = cur.at as u128 + 8 * nnz as u128; // row + col arrays are u32
    expect_len("solve_sparse", payload.len(), fixed, nnz as u128 + rows as u128)?;

    let mut coo = CooMatrix::new(rows, cols);
    let mut ri = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        ri.push(cur.u32("row")? as usize);
    }
    let mut ci = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        ci.push(cur.u32("col")? as usize);
    }
    for (&i, &j) in ri.iter().zip(&ci) {
        let v = cur.f64("val")?;
        coo.push(i, j, v).map_err(|e| berr(format!("triplet payload: {e}")))?;
    }
    let mut b = Vec::with_capacity(rows);
    for _ in 0..rows {
        b.push(cur.f64("b")?);
    }
    cur.done("solve_sparse")?;
    // Identical to the NDJSON path: fingerprint the assembled CSR so
    // triplet order (and wire format) cannot split the cache key.
    let a = coo.to_csr();
    let fingerprint = fingerprint_csr(&a);
    let pattern_fingerprint = Some(fingerprint_csr_pattern(&a));
    Ok(WireSolve {
        id,
        matrix: WireMatrix::Sparse(a),
        b,
        key,
        no_cache,
        fingerprint,
        pattern_fingerprint,
    })
}

/// Decode a binary request payload. The solution kind is refused in
/// this direction; unknown kinds are a decode error (new kinds are a
/// protocol revision, not a silent extension).
pub fn decode_request_payload(kind: u8, payload: &[u8]) -> Result<RequestFrame> {
    match kind {
        KIND_SOLVE_DENSE => decode_dense_payload(payload).map(RequestFrame::Solve),
        KIND_SOLVE_SPARSE => decode_sparse_payload(payload).map(RequestFrame::SolveSparse),
        KIND_SOLUTION => Err(berr("kind 0x03 (solution) is a response frame")),
        other => Err(berr(format!("unknown frame kind {other:#04x}"))),
    }
}

// ---- solutions --------------------------------------------------------------

/// Header + everything before the `x` column block of an ok-solution
/// frame, appended to `out`. The emitter streams the columns after
/// this prefix in bounded chunks (see
/// [`super::codec::ResponseWriter`]); `encode_solution_binary` is the
/// one-shot convenience for tests and benches.
pub fn push_solution_prefix(out: &mut Vec<u8>, s: &WireSolution) -> Result<()> {
    let x = s.result.as_ref().map_err(|_| berr("failed solutions are NDJSON-only"))?;
    let n = as_u32(x.len(), "solution length")?;
    let backend = s.backend.as_bytes();
    let backend_len =
        u8::try_from(backend.len()).map_err(|_| berr("backend name exceeds 255 bytes"))?;
    let flags = if s.matrix_key.is_some() { FLAG_MATRIX_KEY } else { 0 };
    let fixed = 1 + 8 + 4 + 4
        + if s.matrix_key.is_some() { 8 } else { 0 }
        + 4 * 8
        + 1
        + backend.len();
    let payload_len = fixed as u64 + 8 * x.len() as u64;

    out.extend_from_slice(&encode_header(KIND_SOLUTION, payload_len));
    out.push(flags);
    push_u64(out, wire_int(s.id, "id")?);
    push_u32(out, as_u32(s.batch_size, "batch_size")?);
    push_u32(out, n);
    if let Some(k) = s.matrix_key {
        push_u64(out, wire_int(k, "matrix_key")?);
    }
    // Raw bits: unlike NDJSON (which canonicalises non-finite values to
    // `null`), binary preserves the exact residual bit pattern.
    push_f64(out, s.residual);
    push_f64(out, s.timings.queue_secs);
    push_f64(out, s.timings.batch_secs);
    push_f64(out, s.timings.exec_secs);
    out.push(backend_len);
    out.extend_from_slice(backend);
    Ok(())
}

/// One-shot binary encoding of an ok-solution (header + payload).
pub fn encode_solution_binary(s: &WireSolution) -> Result<Vec<u8>> {
    let x = s.result.as_ref().map_err(|_| berr("failed solutions are NDJSON-only"))?;
    let mut out = Vec::new();
    push_solution_prefix(&mut out, s)?;
    out.reserve(8 * x.len());
    for &v in x {
        push_f64(&mut out, v);
    }
    Ok(out)
}

/// Decode a solution payload (the client half).
pub fn decode_solution_payload(payload: &[u8]) -> Result<WireSolution> {
    let mut cur = Cursor::new(payload);
    let flags = cur.u8("flags")?;
    let id = wire_int(cur.u64("id")?, "id")?;
    let batch_size = cur.u32("batch_size")? as usize;
    let n = cur.u32("n")? as usize;
    let matrix_key = if flags & FLAG_MATRIX_KEY != 0 {
        Some(wire_int(cur.u64("matrix_key")?, "matrix_key")?)
    } else {
        None
    };
    let residual = cur.f64("residual")?;
    let timings = Timings {
        queue_secs: cur.f64("queue_secs")?,
        batch_secs: cur.f64("batch_secs")?,
        exec_secs: cur.f64("exec_secs")?,
    };
    let backend_len = cur.u8("backend_len")? as usize;
    let backend = std::str::from_utf8(cur.take(backend_len, "backend")?)
        .map_err(|_| berr("backend name is not UTF-8"))?
        .to_string();
    if payload.len() - cur.at != 8 * n {
        return Err(berr(format!(
            "solution payload length mismatch: {} column bytes, {} implied by n",
            payload.len() - cur.at,
            8 * n
        )));
    }
    let mut x = Vec::with_capacity(n);
    for _ in 0..n {
        x.push(cur.f64("x")?);
    }
    cur.done("solution")?;
    Ok(WireSolution { id, result: Ok(x), residual, backend, batch_size, matrix_key, timings })
}

// ---- client-side stream splitting -------------------------------------------

/// Split a mixed NDJSON/binary response byte stream into decoded
/// frames — the client half of a negotiated session. Binary frames
/// (always ok-solutions in this direction) report a default
/// [`FrameExt`]; NDJSON frames surface the server's `accept_binary`
/// ack through theirs.
pub fn decode_response_stream(bytes: &[u8]) -> Result<Vec<(ResponseFrame, FrameExt)>> {
    let mut out = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        if is_magic(bytes[at]) {
            let header: &[u8; HEADER_LEN] = bytes
                .get(at..at + HEADER_LEN)
                .and_then(|h| h.try_into().ok())
                .ok_or_else(|| berr("truncated header"))?;
            let hdr = parse_header(header)?;
            let len = usize::try_from(hdr.payload_len)
                .map_err(|_| berr("declared payload exceeds this platform"))?;
            let payload = bytes
                .get(at + HEADER_LEN..at + HEADER_LEN + len)
                .ok_or_else(|| berr("truncated payload"))?;
            if hdr.kind != KIND_SOLUTION {
                return Err(berr(format!("unexpected response kind {:#04x}", hdr.kind)));
            }
            out.push((
                ResponseFrame::Solution(decode_solution_payload(payload)?),
                FrameExt::default(),
            ));
            at += HEADER_LEN + len;
        } else {
            let end = bytes[at..]
                .iter()
                .position(|&b| b == b'\n')
                .map_or(bytes.len(), |p| at + p);
            let line = std::str::from_utf8(&bytes[at..end])
                .map_err(|_| berr("response line is not UTF-8"))?
                .trim();
            if !line.is_empty() {
                out.push(decode_response_ext(line)?);
            }
            at = end + 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};
    use crate::wire::codec::{decode_request, encode_request};

    fn bits(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn header_round_trips_and_is_stable() {
        let h = encode_header(KIND_SOLVE_DENSE, 16);
        assert_eq!(h, [0xEB, 0x56, 0x01, 0x01, 0x10, 0, 0, 0, 0, 0, 0, 0]);
        let parsed = parse_header(&h).unwrap();
        assert_eq!(parsed, FrameHeader { kind: KIND_SOLVE_DENSE, payload_len: 16 });
        // Unknown kinds pass the header (so the payload can be skipped
        // in sync); bad magic/version do not.
        assert_eq!(parse_header(&encode_header(0x7F, 0)).unwrap().kind, 0x7F);
        let mut bad = h;
        bad[1] = 0x00;
        assert!(parse_header(&bad).is_err());
        let mut bad = h;
        bad[2] = 9;
        assert!(parse_header(&bad).unwrap_err().to_string().contains("version"), "{bad:?}");
    }

    #[test]
    fn dense_request_decodes_bitwise_identical_to_ndjson() {
        let a = diag_dominant_dense(7, GenSeed(31));
        let ws = WireSolve::dense(a, vec![0.25, -1.5, 3.0, 0.125, 9.0, -2.0, 1.0])
            .with_id(5)
            .with_key(77);
        let frame = RequestFrame::Solve(ws);
        let text = decode_request(&encode_request(&frame)).unwrap();
        let bin = encode_request_binary(&frame).unwrap();
        let hdr = parse_header(bin[..HEADER_LEN].try_into().unwrap()).unwrap();
        assert_eq!(hdr.payload_len as usize, bin.len() - HEADER_LEN);
        let back = decode_request_payload(hdr.kind, &bin[HEADER_LEN..]).unwrap();
        assert_eq!(back, text);
        let (RequestFrame::Solve(t), RequestFrame::Solve(b)) = (&text, &back) else {
            unreachable!()
        };
        assert_eq!(t.fingerprint, b.fingerprint, "auto-key is format-independent");
        let (WireMatrix::Dense(ta), WireMatrix::Dense(ba)) = (&t.matrix, &b.matrix) else {
            unreachable!()
        };
        assert_eq!(bits(ta.data()), bits(ba.data()));
        assert_eq!(bits(&t.b), bits(&b.b));
    }

    #[test]
    fn sparse_request_decodes_bitwise_identical_to_ndjson() {
        let a = diag_dominant_sparse(10, 3, GenSeed(32));
        let ws = WireSolve::sparse(a, vec![0.5; 10]).without_cache();
        let frame = RequestFrame::SolveSparse(ws);
        let text = decode_request(&encode_request(&frame)).unwrap();
        let bin = encode_request_binary(&frame).unwrap();
        let hdr = parse_header(bin[..HEADER_LEN].try_into().unwrap()).unwrap();
        let back = decode_request_payload(hdr.kind, &bin[HEADER_LEN..]).unwrap();
        assert_eq!(back, text);
        let (RequestFrame::SolveSparse(t), RequestFrame::SolveSparse(b)) = (&text, &back) else {
            unreachable!()
        };
        assert_eq!(t.fingerprint, b.fingerprint);
        assert_eq!(t.pattern_fingerprint, b.pattern_fingerprint);
        assert!(b.no_cache);
    }

    #[test]
    fn solution_round_trips_with_exact_bits() {
        let s = WireSolution {
            id: 9,
            result: Ok(vec![1.0, -0.0, 0.1 + 0.2, f64::MIN_POSITIVE]),
            residual: f64::NAN,
            backend: "native-ebv".into(),
            batch_size: 3,
            matrix_key: Some(0xABCDEF),
            timings: Timings { queue_secs: 0.125, batch_secs: 0.25, exec_secs: 0.5 },
        };
        let bin = encode_solution_binary(&s).unwrap();
        let hdr = parse_header(bin[..HEADER_LEN].try_into().unwrap()).unwrap();
        assert_eq!(hdr.kind, KIND_SOLUTION);
        let back = decode_solution_payload(&bin[HEADER_LEN..]).unwrap();
        assert_eq!(bits(back.result.as_ref().unwrap()), bits(s.result.as_ref().unwrap()));
        // Binary keeps the exact NaN pattern; -0.0 keeps its sign bit.
        assert_eq!(back.residual.to_bits(), s.residual.to_bits());
        assert_eq!(back.result.as_ref().unwrap()[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!((back.id, back.batch_size, back.matrix_key), (9, 3, Some(0xABCDEF)));
        assert_eq!(back.backend, "native-ebv");
        assert_eq!(back.timings, s.timings);
    }

    #[test]
    fn control_frames_and_failed_solutions_have_no_binary_form() {
        assert!(encode_request_binary(&RequestFrame::Metrics).is_err());
        assert!(encode_request_binary(&RequestFrame::Shutdown).is_err());
        let failed = WireSolution {
            id: 1,
            result: Err("zero pivot".into()),
            residual: f64::NAN,
            backend: "native-ebv".into(),
            batch_size: 1,
            matrix_key: None,
            timings: Timings::default(),
        };
        assert!(encode_solution_binary(&failed).is_err());
    }

    #[test]
    fn length_payload_mismatch_is_a_decode_error() {
        let a = diag_dominant_dense(3, GenSeed(33));
        let frame = RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 3]));
        let bin = encode_request_binary(&frame).unwrap();
        // Truncate one column byte: the shape now implies more bytes
        // than the payload carries.
        let payload = &bin[HEADER_LEN..bin.len() - 1];
        let err = decode_request_payload(KIND_SOLVE_DENSE, payload).unwrap_err();
        assert!(err.to_string().contains("length mismatch"), "{err}");
        // Same in the other direction: extra bytes are refused too.
        let mut fat = bin[HEADER_LEN..].to_vec();
        fat.push(0);
        assert!(decode_request_payload(KIND_SOLVE_DENSE, &fat).is_err());
    }

    #[test]
    fn hostile_kinds_and_out_of_range_fields_are_refused() {
        assert!(decode_request_payload(KIND_SOLUTION, &[]).is_err());
        assert!(decode_request_payload(0x5A, &[]).is_err());
        // Out-of-bounds triplet indices fail assembly, like NDJSON.
        let mut payload = vec![0u8]; // flags
        push_u32(&mut payload, 2); // rows
        push_u32(&mut payload, 2); // cols
        push_u32(&mut payload, 1); // nnz
        push_u32(&mut payload, 7); // row index out of bounds
        push_u32(&mut payload, 0);
        push_f64(&mut payload, 1.0);
        push_f64(&mut payload, 1.0);
        push_f64(&mut payload, 2.0);
        let err = decode_request_payload(KIND_SOLVE_SPARSE, &payload).unwrap_err();
        assert!(err.to_string().contains("triplet"), "{err}");
        // A key outside the 53-bit wire range is refused on decode,
        // mirroring the NDJSON integer rule.
        let a = diag_dominant_dense(2, GenSeed(34));
        let mut ws = WireSolve::dense(a, vec![1.0; 2]);
        ws.key = Some(u64::MAX);
        assert!(encode_request_binary(&RequestFrame::Solve(ws)).is_err());
    }

    #[test]
    fn response_stream_splits_mixed_formats() {
        let sol = WireSolution {
            id: 2,
            result: Ok(vec![4.0, 5.0]),
            residual: 1e-13,
            backend: "native-ebv".into(),
            batch_size: 1,
            matrix_key: None,
            timings: Timings::default(),
        };
        let mut stream = Vec::new();
        stream.extend_from_slice(b"{\"op\":\"error\",\"code\":\"busy\",\"error\":\"later\"}\n");
        stream.extend_from_slice(&encode_solution_binary(&sol).unwrap());
        stream.extend_from_slice(b"{\"op\":\"goodbye\",\"served\":1}\n");
        let frames = decode_response_stream(&stream).unwrap();
        assert_eq!(frames.len(), 3);
        assert!(matches!(&frames[0].0, ResponseFrame::Error { .. }));
        let ResponseFrame::Solution(s) = &frames[1].0 else { panic!("{frames:?}") };
        assert_eq!(bits(s.result.as_ref().unwrap()), bits(&[4.0, 5.0]));
        assert_eq!(frames[2].0, ResponseFrame::Goodbye { served: 1 });
        // Truncation mid-frame is an error, not a silent drop.
        assert!(decode_response_stream(&stream[..stream.len() - 30]).is_err());
    }
}
