//! Blocking wire session loop over the solve service.
//!
//! [`serve_session`] is generic over `BufRead`/`Write`, so the same
//! loop serves `stdin`/`stdout` behind `ebv-solve serve`, in-memory
//! buffers in tests, and accepted sockets behind
//! [`super::listener::WireServer`]. Framing is one JSON object per
//! line (see `docs/PROTOCOL.md`) — or, once a session has offered
//! `accept_binary`, length-prefixed binary frames ([`super::binary`])
//! interleaved freely with NDJSON lines; the reader dispatches per
//! frame on one peeked byte. Every request frame produces exactly one
//! response frame, written through the chunked
//! [`ResponseWriter`](super::codec::ResponseWriter) and flushed before
//! the next read, so a pipe client can drive the session synchronously.
//!
//! Error containment: a malformed or oversized frame — text or binary
//! — produces a typed `error` frame (see [`ErrorCode`]) and the
//! session continues; one bad request in a long-lived pipe must not
//! tear down the connection. A binary frame's declared length is
//! checked against the cap *before* any payload allocation. Only I/O
//! failure (peer gone), a `shutdown` frame, or the server's
//! cooperative [`SessionOptions::stop`] drain flag ends the loop.
//!
//! Each session folds its [`SessionStats`] and, with profiling on
//! (`service.profiling` / `serve --profile`), its wire-side span time
//! (`ingest` around request decode, `encode` around response write)
//! into the shared [`ServiceMetrics`] — the `sessions_*`/`wire_*`
//! fields of the metrics frame aggregate across all sessions a service
//! ever ran.
//!
//! [`ServiceMetrics`]: crate::coordinator::metrics::ServiceMetrics

use std::io::{BufRead, ErrorKind, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::service::ServiceHandle;
use crate::util::error::{EbvError, Result};
use crate::wire::binary;
use crate::wire::codec::{decode_request_ext, DecodeOptions, FrameExt, ResponseWriter};
use crate::wire::frame::{
    ErrorCode, RequestFrame, ResponseFrame, WireMatrix, WireSolution, WireSolve,
};

/// Counters of one wire session.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionStats {
    /// Non-empty request frames read (oversized frames count — they
    /// consumed a frame slot even though their payload was discarded).
    pub frames: u64,
    /// Solve frames that produced a solution frame (ok or failed);
    /// rejected/undeliverable submissions count as `errors` instead.
    pub solves: u64,
    /// Error frames written (decode failures, rejected submissions,
    /// expired deadlines, oversized frames).
    pub errors: u64,
    /// Transport bytes consumed from the peer (both formats, including
    /// discarded oversized payloads).
    pub bytes_in: u64,
    /// Transport bytes written to the peer (both formats).
    pub bytes_out: u64,
}

/// Per-session policy. `Default` is the permissive stdio posture: no
/// deadline, no frame-size cap, no stop flag, restrictive decode.
#[derive(Debug, Clone, Default)]
pub struct SessionOptions {
    pub decode: DecodeOptions,
    /// Per-request solve deadline. When the coordinator has not
    /// answered within it, the session writes a `deadline` error frame
    /// and moves on; the solve may still finish server-side, its
    /// result discarded. `None` waits indefinitely.
    pub deadline: Option<Duration>,
    /// Hard cap on one request line's byte length. An over-cap line is
    /// discarded (to the newline) and answered with an `oversized`
    /// error frame; the session continues. `None` is unbounded.
    pub max_frame_bytes: Option<usize>,
    /// Cooperative drain flag, polled between reads. Once set, the
    /// session writes `goodbye` and ends as if the client had sent
    /// `shutdown`. Only effective when the reader yields periodically
    /// (e.g. a socket with a read timeout) — a reader parked in a
    /// blocking `read` is released at its next timeout or byte.
    pub stop: Option<Arc<AtomicBool>>,
}

/// Run one session with default (stdio) options; see
/// [`serve_session_with`].
pub fn serve_session<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    input: R,
    output: W,
) -> Result<SessionStats> {
    serve_session_with(svc, input, output, SessionOptions::default())
}

/// Run one session: read NDJSON request frames from `input`, answer
/// each on `output`, until `shutdown`, EOF, drain, or an I/O error.
/// The service handle is borrowed — the caller owns service lifetime
/// and can serve sequential or concurrent sessions on one warmed-up
/// service (keeping the `FactorCache` across sessions is the point of
/// the fingerprint key).
///
/// Session accounting (`sessions_total`, `active_sessions`,
/// `peak_sessions`, and the folded `wire_*` totals) is recorded on the
/// service metrics even when the session ends in an I/O error.
pub fn serve_session_with<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    mut input: R,
    mut output: W,
    opts: SessionOptions,
) -> Result<SessionStats> {
    svc.metrics().session_opened();
    let outcome = session_loop(svc, &mut input, &mut output, &opts);
    let stats = match &outcome {
        Ok(stats) => *stats,
        Err((stats, _)) => *stats,
    };
    svc.metrics().session_closed(
        stats.frames,
        stats.solves,
        stats.errors,
        stats.bytes_in,
        stats.bytes_out,
    );
    if crate::obs::enabled() {
        eprintln!("{}", crate::obs::summary_line(&svc.metrics_snapshot()));
    }
    outcome.map(|_| stats).map_err(|(_, e)| e)
}

/// What one bounded frame read produced.
enum ReadOutcome {
    /// A complete NDJSON request line is in the buffer (newline
    /// stripped).
    Line,
    Eof,
    /// The line blew past `max_frame_bytes`; its remainder was
    /// discarded up to the newline (or EOF).
    Oversized,
    /// A complete binary payload of this frame kind is in the buffer.
    Binary(u8),
    /// A binary header arrived but did not parse (wrong magic tail or
    /// version); framing sync is lost until the peer resynchronises.
    BinaryBad(String),
    /// A binary frame declared more payload bytes than the cap; the
    /// payload was discarded from the stream without being held.
    BinaryOversized(u64),
    /// The drain flag tripped while waiting for input.
    Stopped,
}

/// Read one request frame into `buf` — an `\n`-terminated NDJSON line,
/// or (dispatched on the first byte of the frame being the binary
/// magic, which can never start JSON) one length-prefixed binary
/// frame. Enforces the frame-size cap and polls the drain flag
/// whenever the underlying reader yields (`WouldBlock`/`TimedOut`, as
/// sockets with a read timeout do). A partial line buffered at EOF is
/// returned as a final `Line` — a client that writes a frame and
/// half-closes without the trailing newline still gets its answer.
/// Every byte consumed is counted into `bytes_in`.
fn read_frame<R: BufRead>(
    input: &mut R,
    buf: &mut Vec<u8>,
    max_bytes: Option<usize>,
    stop: Option<&AtomicBool>,
    bytes_in: &mut u64,
) -> std::io::Result<ReadOutcome> {
    buf.clear();
    let cap = max_bytes.unwrap_or(usize::MAX);
    let mut over = false;
    loop {
        // Drain wins even mid-line: a half-written frame at drain time
        // is dropped, never half-parsed — shutdown must not be
        // stallable by a client that withholds its newline.
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            return Ok(ReadOutcome::Stopped);
        }
        let chunk = match input.fill_buf() {
            Ok(chunk) => chunk,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                // Read-timeout tick (or EINTR): loop back to poll the
                // drain flag, then park in the next fill_buf.
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(if over {
                ReadOutcome::Oversized
            } else if buf.is_empty() {
                ReadOutcome::Eof
            } else {
                ReadOutcome::Line
            });
        }
        // Binary dispatch happens only at a frame boundary: nothing of
        // a text line buffered yet and not mid-discard. A magic byte
        // inside a text line is that line's payload, not a frame start.
        if buf.is_empty() && !over && binary::is_magic(chunk[0]) {
            return read_binary_frame(input, buf, cap, stop, bytes_in);
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if !over && buf.len().saturating_add(pos) > cap {
                    over = true;
                    buf.clear();
                } else if !over {
                    buf.extend_from_slice(&chunk[..pos]);
                }
                input.consume(pos + 1);
                *bytes_in += pos as u64 + 1;
                return Ok(if over { ReadOutcome::Oversized } else { ReadOutcome::Line });
            }
            None => {
                let len = chunk.len();
                if !over && buf.len().saturating_add(len) > cap {
                    over = true;
                    buf.clear(); // don't hold a frame we already rejected
                } else if !over {
                    buf.extend_from_slice(chunk);
                }
                input.consume(len);
                *bytes_in += len as u64;
            }
        }
    }
}

/// How an exact-count read ended.
enum Filled {
    Yes,
    Eof,
    Stopped,
}

/// `read_exact` with drain-flag polling and byte accounting.
fn fill_exact<R: BufRead>(
    input: &mut R,
    out: &mut [u8],
    stop: Option<&AtomicBool>,
    bytes_in: &mut u64,
) -> std::io::Result<Filled> {
    let mut at = 0usize;
    while at < out.len() {
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            return Ok(Filled::Stopped);
        }
        let chunk = match input.fill_buf() {
            Ok(chunk) => chunk,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(Filled::Eof);
        }
        let n = chunk.len().min(out.len() - at);
        out[at..at + n].copy_from_slice(&chunk[..n]);
        input.consume(n);
        *bytes_in += n as u64;
        at += n;
    }
    Ok(Filled::Yes)
}

/// Consume and drop `remaining` bytes — the streaming skip for an
/// over-cap binary payload, which must never be buffered.
fn discard_exact<R: BufRead>(
    input: &mut R,
    mut remaining: u64,
    stop: Option<&AtomicBool>,
    bytes_in: &mut u64,
) -> std::io::Result<Filled> {
    while remaining > 0 {
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            return Ok(Filled::Stopped);
        }
        let chunk = match input.fill_buf() {
            Ok(chunk) => chunk,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(Filled::Eof);
        }
        let n = (chunk.len() as u64).min(remaining) as usize;
        input.consume(n);
        *bytes_in += n as u64;
        remaining -= n as u64;
    }
    Ok(Filled::Yes)
}

/// Read one binary frame whose magic byte is next on the stream. The
/// declared payload length is validated against the cap *before* any
/// allocation; an over-cap payload is discarded in a streaming skip. A
/// peer disconnecting mid-frame ends the session quietly (`Eof`), like
/// a text client hanging up mid-stream.
fn read_binary_frame<R: BufRead>(
    input: &mut R,
    buf: &mut Vec<u8>,
    cap: usize,
    stop: Option<&AtomicBool>,
    bytes_in: &mut u64,
) -> std::io::Result<ReadOutcome> {
    let mut header = [0u8; binary::HEADER_LEN];
    match fill_exact(input, &mut header, stop, bytes_in)? {
        Filled::Stopped => return Ok(ReadOutcome::Stopped),
        Filled::Eof => return Ok(ReadOutcome::Eof),
        Filled::Yes => {}
    }
    let hdr = match binary::parse_header(&header) {
        Ok(hdr) => hdr,
        Err(e) => return Ok(ReadOutcome::BinaryBad(e.to_string())),
    };
    if hdr.payload_len > cap as u64 {
        match discard_exact(input, hdr.payload_len, stop, bytes_in)? {
            Filled::Stopped => return Ok(ReadOutcome::Stopped),
            Filled::Eof => return Ok(ReadOutcome::Eof),
            Filled::Yes => {}
        }
        return Ok(ReadOutcome::BinaryOversized(hdr.payload_len));
    }
    // Allocation strictly after the cap check.
    buf.resize(hdr.payload_len as usize, 0);
    match fill_exact(input, buf, stop, bytes_in)? {
        Filled::Stopped => Ok(ReadOutcome::Stopped),
        Filled::Eof => Ok(ReadOutcome::Eof),
        Filled::Yes => Ok(ReadOutcome::Binary(hdr.kind)),
    }
}

/// What handling one decoded request frame asks of the loop.
enum Handled {
    Reply(ResponseFrame),
    Shutdown,
}

/// Route one decoded request (either format) to its response. Solve
/// accounting: `served` promises produced solutions; a rejected or
/// dropped submission is an error, not a serve.
fn handle_decoded(
    svc: &ServiceHandle,
    opts: &SessionOptions,
    stats: &mut SessionStats,
    next_id: &mut u64,
    decoded: Result<RequestFrame>,
) -> Handled {
    match decoded {
        Err(e) => {
            stats.errors += 1;
            Handled::Reply(ResponseFrame::error(ErrorCode::Decode, e.to_string()))
        }
        Ok(RequestFrame::Shutdown) => Handled::Shutdown,
        Ok(RequestFrame::Metrics) => {
            Handled::Reply(ResponseFrame::Metrics(svc.metrics_snapshot()))
        }
        Ok(RequestFrame::Solve(ws)) | Ok(RequestFrame::SolveSparse(ws)) => {
            // Session-sequential fallback ids for unnumbered requests.
            let id = ws.id.unwrap_or(*next_id);
            *next_id = (*next_id).max(id) + 1;
            let resp = run_solve(svc, id, ws, opts.deadline);
            match &resp {
                ResponseFrame::Solution(_) => stats.solves += 1,
                ResponseFrame::Error { .. } => stats.errors += 1,
                _ => {}
            }
            Handled::Reply(resp)
        }
    }
}

fn session_loop<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    input: &mut R,
    output: &mut W,
    opts: &SessionOptions,
) -> std::result::Result<SessionStats, (SessionStats, EbvError)> {
    let mut stats = SessionStats::default();
    let mut buf = Vec::new();
    let mut next_id: u64 = 0;
    let mut writer = ResponseWriter::new(output);

    // Write one frame through the chunked emitter, keeping the byte
    // counter coherent even when the write fails partway.
    macro_rules! send {
        ($frame:expr) => {{
            let wrote = writer.write_frame($frame);
            stats.bytes_out = writer.bytes_out();
            wrote.map_err(|e| (stats, e))?;
        }};
    }

    loop {
        let outcome = read_frame(
            input,
            &mut buf,
            opts.max_frame_bytes,
            opts.stop.as_deref(),
            &mut stats.bytes_in,
        )
        .map_err(|e| (stats, EbvError::io("wire session: read", e)))?;
        let handled = match outcome {
            ReadOutcome::Eof => break, // client hung up without `shutdown`; end quietly
            ReadOutcome::Stopped => {
                // Server-initiated drain: say goodbye like a shutdown.
                log::info!(target: "wire", "drain after {} frames", stats.frames);
                send!(&ResponseFrame::Goodbye { served: stats.solves });
                break;
            }
            ReadOutcome::Oversized => {
                stats.frames += 1;
                stats.errors += 1;
                Handled::Reply(ResponseFrame::error(
                    ErrorCode::Oversized,
                    format!(
                        "frame exceeds max_frame_bytes ({}); line discarded",
                        opts.max_frame_bytes.unwrap_or(usize::MAX)
                    ),
                ))
            }
            ReadOutcome::BinaryOversized(declared) => {
                stats.frames += 1;
                stats.errors += 1;
                Handled::Reply(ResponseFrame::error(
                    ErrorCode::Oversized,
                    format!(
                        "binary frame declares {declared} payload bytes, exceeds \
                         max_frame_bytes ({}); payload discarded",
                        opts.max_frame_bytes.unwrap_or(usize::MAX)
                    ),
                ))
            }
            ReadOutcome::BinaryBad(msg) => {
                stats.frames += 1;
                stats.errors += 1;
                Handled::Reply(ResponseFrame::error(ErrorCode::Decode, msg))
            }
            ReadOutcome::Binary(kind) => {
                stats.frames += 1;
                if !writer.is_binary() {
                    // The payload was consumed in sync, so the session
                    // survives — but un-negotiated binary is refused.
                    stats.errors += 1;
                    Handled::Reply(ResponseFrame::error(
                        ErrorCode::Decode,
                        "binary frame before negotiation: offer `accept_binary` on an \
                         NDJSON request first; payload discarded",
                    ))
                } else {
                    let decoded = {
                        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Ingest);
                        binary::decode_request_payload(kind, &buf)
                    };
                    handle_decoded(svc, opts, &mut stats, &mut next_id, decoded)
                }
            }
            ReadOutcome::Line => {
                let text = match std::str::from_utf8(&buf) {
                    Ok(text) => text.trim(),
                    Err(_) => {
                        stats.frames += 1;
                        stats.errors += 1;
                        send!(&ResponseFrame::error(
                            ErrorCode::Decode,
                            "frame is not valid UTF-8",
                        ));
                        drain_spans(svc);
                        continue;
                    }
                };
                if text.is_empty() {
                    continue;
                }
                stats.frames += 1;

                let decoded = {
                    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Ingest);
                    decode_request_ext(text, &opts.decode)
                };
                let (decoded, ext) = match decoded {
                    Ok((frame, ext)) => (Ok(frame), ext),
                    Err(e) => (Err(e), FrameExt::default()),
                };
                if ext.accept_binary && !writer.is_binary() {
                    // Per-session latch: from here on, ok-solutions go
                    // out binary (the next frame carries the ack) and
                    // binary requests are accepted.
                    log::info!(target: "wire", "binary negotiated after {} frames", stats.frames);
                    svc.metrics().binary_sessions.fetch_add(1, Ordering::Relaxed);
                    writer.enable_binary();
                }
                handle_decoded(svc, opts, &mut stats, &mut next_id, decoded)
            }
        };
        match handled {
            Handled::Shutdown => {
                log::info!(target: "wire", "shutdown frame after {} frames", stats.frames);
                send!(&ResponseFrame::Goodbye { served: stats.solves });
                break;
            }
            Handled::Reply(response) => send!(&response),
        }
        drain_spans(svc);
    }
    drain_spans(svc);
    Ok(stats)
}

/// Drain the session thread's span sink, crediting the wire-side
/// `ingest`/`encode` time to the service-wide accumulators. The sink is
/// per-request scratch — a long-lived pipe must not accumulate spans
/// forever — so this runs after every frame.
fn drain_spans(svc: &ServiceHandle) {
    if !crate::obs::enabled() {
        return;
    }
    let (mut ingest, mut encode) = (0u64, 0u64);
    for span in crate::obs::take_thread_spans() {
        match span.phase {
            crate::obs::Phase::Ingest => ingest += span.dur_ns,
            crate::obs::Phase::Encode => encode += span.dur_ns,
            _ => {}
        }
    }
    if ingest > 0 {
        svc.metrics().wire_ingest_ns.fetch_add(ingest, Ordering::Relaxed);
    }
    if encode > 0 {
        svc.metrics().wire_encode_ns.fetch_add(encode, Ordering::Relaxed);
    }
}

/// Submit one solve and block for its response frame, up to `deadline`.
fn run_solve(
    svc: &ServiceHandle,
    id: u64,
    ws: WireSolve,
    deadline: Option<Duration>,
) -> ResponseFrame {
    let key = ws.effective_key();
    let pattern_key = ws.effective_pattern_key();
    let WireSolve { matrix, b, .. } = ws;
    let submitted = match matrix {
        WireMatrix::Dense(a) => svc.submit_dense(Arc::new(a), b, key),
        WireMatrix::Sparse(a) => {
            svc.submit_sparse_with_pattern(Arc::new(a), b, key, pattern_key)
        }
    };
    let rx = match submitted {
        Ok(rx) => rx,
        // Admission-control rejection (backpressure): a `busy` error
        // frame, not a failed solution — the client should back off
        // and retry. Any other submit failure is server-side.
        Err(e) => {
            let msg = e.to_string();
            let code =
                if msg.contains("backpressure") { ErrorCode::Busy } else { ErrorCode::Internal };
            return ResponseFrame::error(code, msg);
        }
    };
    let received = match deadline {
        None => rx.recv().map_err(|_| {
            ResponseFrame::error(ErrorCode::Internal, "coordinator: service dropped the request")
        }),
        Some(d) => rx.recv_timeout(d).map_err(|e| match e {
            // The worker's late send to the dropped receiver is a
            // harmless no-op; the result is simply discarded.
            RecvTimeoutError::Timeout => ResponseFrame::error(
                ErrorCode::Deadline,
                format!("deadline: solve not finished within {}ms; result discarded", d.as_millis()),
            ),
            RecvTimeoutError::Disconnected => ResponseFrame::error(
                ErrorCode::Internal,
                "coordinator: service dropped the request",
            ),
        }),
    };
    match received {
        Ok(resp) => ResponseFrame::Solution(WireSolution {
            id,
            result: resp.result,
            residual: resp.residual,
            backend: resp.backend.to_string(),
            batch_size: resp.batch_size,
            matrix_key: key,
            timings: resp.timings,
        }),
        Err(frame) => frame,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::coordinator::SolverService;
    use crate::matrix::generate::{diag_dominant_dense, GenSeed};
    use crate::wire::codec::{decode_response, encode_request};
    use crate::wire::frame::RequestFrame;

    fn test_service() -> ServiceHandle {
        SolverService::start(ServiceConfig {
            lanes: 2,
            max_batch: 4,
            batch_window_us: 100,
            queue_capacity: 64,
            engine_lanes: 2,
            use_runtime: false,
            ..ServiceConfig::default()
        })
        .unwrap()
    }

    fn run(input: &str) -> (SessionStats, Vec<ResponseFrame>) {
        run_with(input, SessionOptions::default())
    }

    fn run_with(input: &str, opts: SessionOptions) -> (SessionStats, Vec<ResponseFrame>) {
        let (stats, out) = run_raw(input.as_bytes(), opts);
        let text = String::from_utf8(out).unwrap();
        let frames = text.lines().map(|l| decode_response(l).unwrap()).collect();
        (stats, frames)
    }

    /// Like `run_with`, but the response stream stays raw bytes — for
    /// sessions whose responses are (partly) binary.
    fn run_raw(input: &[u8], opts: SessionOptions) -> (SessionStats, Vec<u8>) {
        let svc = test_service();
        let mut out = Vec::new();
        let stats = serve_session_with(&svc, input, &mut out, opts).unwrap();
        svc.shutdown();
        (stats, out)
    }

    #[test]
    fn session_solves_and_says_goodbye() {
        let a = diag_dominant_dense(8, GenSeed(21));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"shutdown\"}}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.solves, 1);
        assert_eq!(stats.errors, 0);
        assert_eq!(frames.len(), 2);
        let ResponseFrame::Solution(s) = &frames[0] else { panic!("{frames:?}") };
        assert!(s.result.is_ok());
        assert!(s.residual < 1e-9);
        assert_eq!(frames[1], ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn bad_line_gets_error_frame_and_session_continues() {
        let a = diag_dominant_dense(6, GenSeed(22));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        let input = format!("this is not json\n{solve}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.frames, 2);
        assert_eq!(stats.errors, 1);
        assert!(
            matches!(&frames[0], ResponseFrame::Error { code: ErrorCode::Decode, .. }),
            "{frames:?}"
        );
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn oversized_line_gets_typed_error_and_session_continues() {
        let a = diag_dominant_dense(6, GenSeed(25));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        assert!(solve.len() <= 4096, "cap must admit the real frame");
        let huge = "x".repeat(5000);
        let input = format!("{huge}\n{solve}\n{{\"op\":\"shutdown\"}}\n");
        let opts = SessionOptions { max_frame_bytes: Some(4096), ..SessionOptions::default() };
        let (stats, frames) = run_with(&input, opts);
        assert_eq!(stats.frames, 3);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.solves, 1);
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Oversized);
        assert!(message.contains("4096"), "{message}");
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
        assert_eq!(frames[2], ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn missing_final_newline_still_answers_the_frame() {
        let a = diag_dominant_dense(5, GenSeed(26));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 5])));
        // No trailing newline: the partial line at EOF is decoded.
        let (stats, frames) = run(&solve);
        assert_eq!(stats.solves, 1);
        assert!(matches!(&frames[0], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn pre_set_stop_flag_drains_before_reading() {
        let a = diag_dominant_dense(4, GenSeed(27));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 4])));
        let stop = Arc::new(AtomicBool::new(true));
        let opts = SessionOptions { stop: Some(Arc::clone(&stop)), ..SessionOptions::default() };
        let (stats, frames) = run_with(&format!("{solve}\n"), opts);
        // The drain flag was set before the first read: goodbye only.
        assert_eq!(stats.solves, 0);
        assert_eq!(frames, vec![ResponseFrame::Goodbye { served: 0 }]);
    }

    #[test]
    fn expired_deadline_yields_deadline_error_frame() {
        let a = diag_dominant_dense(64, GenSeed(28));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 64])));
        let opts = SessionOptions {
            deadline: Some(Duration::from_nanos(1)),
            ..SessionOptions::default()
        };
        let (stats, frames) = run_with(&format!("{solve}\n"), opts);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.solves, 0);
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Deadline);
        assert!(message.contains("deadline"), "{message}");
    }

    #[test]
    fn sessions_fold_into_service_metrics() {
        let svc = test_service();
        let a = diag_dominant_dense(6, GenSeed(29));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        let input = format!("not json\n{solve}\n");
        for _ in 0..2 {
            let mut out = Vec::new();
            serve_session(&svc, input.as_bytes(), &mut out).unwrap();
        }
        let m = svc.metrics_snapshot();
        svc.shutdown();
        assert_eq!(m.sessions_total, 2);
        assert_eq!(m.active_sessions, 0);
        assert_eq!(m.peak_sessions, 1, "sequential sessions never overlap");
        assert_eq!(m.wire_frames, 4);
        assert_eq!(m.wire_solves, 2);
        assert_eq!(m.wire_errors, 2);
        // Byte accounting folds too: each session consumed the whole
        // input and wrote at least one response byte per frame.
        assert_eq!(m.wire_bytes_in, 2 * input.len() as u64);
        assert!(m.wire_bytes_out > 0);
        assert_eq!(m.binary_sessions, 0, "nothing negotiated binary here");
    }

    #[test]
    fn metrics_frame_carries_engine_stats() {
        let a = diag_dominant_dense(8, GenSeed(24));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"metrics\"}}\n{{\"op\":\"shutdown\"}}\n");
        let (_, frames) = run(&input);
        let ResponseFrame::Metrics(m) = &frames[1] else { panic!("{frames:?}") };
        // The test service runs a 2-lane engine; an 8×8 solve stays on
        // the sequential fall-through, so jobs may be zero — but the
        // resident pool and solver config are always reported.
        assert_eq!(m.engine_lanes, 2);
        assert_eq!(m.engine_barrier_waits, m.engine_steps * m.engine_lanes);
        assert_eq!(m.panel_width, 64, "default panel width travels in the frame");
        // The in-flight session is visible to its own metrics frame.
        assert_eq!(m.sessions_total, 1);
        assert_eq!(m.active_sessions, 1);
    }

    #[test]
    fn eof_without_shutdown_ends_cleanly() {
        let (stats, frames) = run("");
        assert_eq!(stats, SessionStats::default());
        assert!(frames.is_empty());
    }

    #[test]
    fn negotiated_session_interleaves_formats_and_counts_bytes() {
        use crate::wire::codec::encode_request_negotiating;
        let a = diag_dominant_dense(6, GenSeed(41));
        // Offer on a metrics frame so the ack is visible as a spliced
        // NDJSON member; then a binary solve; then NDJSON shutdown.
        let offer = encode_request_negotiating(&RequestFrame::Metrics);
        let bin = binary::encode_request_binary(&RequestFrame::Solve(WireSolve::dense(
            a,
            vec![1.0; 6],
        )))
        .unwrap();
        let mut input = Vec::new();
        input.extend_from_slice(offer.as_bytes());
        input.push(b'\n');
        input.extend_from_slice(&bin);
        input.extend_from_slice(b"{\"op\":\"shutdown\"}\n");

        let svc = test_service();
        let mut out = Vec::new();
        let stats = serve_session_with(&svc, input.as_slice(), &mut out, SessionOptions::default())
            .unwrap();
        let m = svc.metrics_snapshot();
        svc.shutdown();
        assert_eq!((stats.frames, stats.solves, stats.errors), (3, 1, 0));
        assert_eq!(stats.bytes_in, input.len() as u64);
        assert_eq!(stats.bytes_out, out.len() as u64);
        assert_eq!(m.binary_sessions, 1);

        let frames = binary::decode_response_stream(&out).unwrap();
        assert_eq!(frames.len(), 3);
        assert!(frames[0].1.accept_binary, "ack rides the first response: {frames:?}");
        assert!(matches!(&frames[0].0, ResponseFrame::Metrics(_)));
        let ResponseFrame::Solution(s) = &frames[1].0 else { panic!("{frames:?}") };
        assert!(s.result.is_ok());
        assert_eq!(frames[2].0, ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn binary_before_negotiation_is_refused_and_session_survives() {
        let a = diag_dominant_dense(5, GenSeed(42));
        let bin = binary::encode_request_binary(&RequestFrame::Solve(WireSolve::dense(
            a.clone(),
            vec![1.0; 5],
        )))
        .unwrap();
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![2.0; 5])));
        let mut input = bin;
        input.extend_from_slice(solve.as_bytes());
        input.push(b'\n');
        let (stats, out) = run_raw(&input, SessionOptions::default());
        assert_eq!((stats.frames, stats.solves, stats.errors), (2, 1, 1));
        // Both responses are NDJSON — the session never negotiated.
        let text = String::from_utf8(out).unwrap();
        let frames: Vec<_> = text.lines().map(|l| decode_response(l).unwrap()).collect();
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Decode);
        assert!(message.contains("negotiation"), "{message}");
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn oversized_binary_frame_is_discarded_without_allocation() {
        // Header declares 1 GiB; the cap is 4 KiB. The "payload" that
        // actually follows is a normal NDJSON solve — it gets eaten by
        // the streaming discard up to the declared length or EOF.
        let header = binary::encode_header(binary::KIND_SOLVE_DENSE, 1 << 30);
        let mut input = header.to_vec();
        input.extend_from_slice(b"leftover");
        let opts = SessionOptions { max_frame_bytes: Some(4096), ..SessionOptions::default() };
        let (stats, out) = run_raw(&input, opts);
        // EOF hit mid-discard: the session ends quietly after counting
        // what it consumed.
        assert_eq!(stats.frames, 0);
        assert_eq!(stats.bytes_in, input.len() as u64);
        assert!(out.is_empty());

        // With the full declared payload present, the typed `oversized`
        // error comes back and the session continues to a shutdown.
        let header = binary::encode_header(binary::KIND_SOLVE_DENSE, 8000);
        let mut input = header.to_vec();
        input.extend_from_slice(&vec![0u8; 8000]);
        input.extend_from_slice(b"{\"op\":\"shutdown\"}\n");
        let opts = SessionOptions { max_frame_bytes: Some(4096), ..SessionOptions::default() };
        let (stats, out) = run_raw(&input, opts);
        assert_eq!((stats.frames, stats.errors), (2, 1));
        let text = String::from_utf8(out).unwrap();
        let frames: Vec<_> = text.lines().map(|l| decode_response(l).unwrap()).collect();
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Oversized);
        assert!(message.contains("8000") && message.contains("4096"), "{message}");
        assert_eq!(frames[1], ResponseFrame::Goodbye { served: 0 });
    }

    #[test]
    fn server_assigns_sequential_ids_and_echoes_explicit_ones() {
        let a = diag_dominant_dense(4, GenSeed(23));
        let unnumbered = encode_request(&RequestFrame::Solve(WireSolve::dense(a.clone(), vec![1.0; 4])));
        let numbered =
            encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![2.0; 4]).with_id(90)));
        let input = format!("{unnumbered}\n{numbered}\n{unnumbered}\n");
        let (_, frames) = run(&input);
        let ids: Vec<u64> = frames
            .iter()
            .map(|f| match f {
                ResponseFrame::Solution(s) => s.id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![0, 90, 91]);
    }
}
