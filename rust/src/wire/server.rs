//! Blocking NDJSON session loop over the solve service.
//!
//! [`serve_session`] is generic over `BufRead`/`Write`, so the same
//! loop serves `stdin`/`stdout` behind `ebv-solve serve`, in-memory
//! buffers in tests, and accepted sockets behind
//! [`super::listener::WireServer`]. Framing is one JSON object per
//! line (see `docs/PROTOCOL.md`); every request line produces exactly
//! one response line, written and flushed before the next read, so a
//! pipe client can drive the session synchronously.
//!
//! Error containment: a malformed or oversized line produces a typed
//! `error` frame (see [`ErrorCode`]) and the session continues — one
//! bad request in a long-lived pipe must not tear down the connection.
//! Only I/O failure (peer gone), a `shutdown` frame, or the server's
//! cooperative [`SessionOptions::stop`] drain flag ends the loop.
//!
//! Each session folds its [`SessionStats`] and, with profiling on
//! (`service.profiling` / `serve --profile`), its wire-side span time
//! (`ingest` around request decode, `encode` around response write)
//! into the shared [`ServiceMetrics`] — the `sessions_*`/`wire_*`
//! fields of the metrics frame aggregate across all sessions a service
//! ever ran.
//!
//! [`ServiceMetrics`]: crate::coordinator::metrics::ServiceMetrics

use std::io::{BufRead, ErrorKind, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::service::ServiceHandle;
use crate::util::error::{EbvError, Result};
use crate::wire::codec::{decode_request_with, encode_response, DecodeOptions};
use crate::wire::frame::{
    ErrorCode, RequestFrame, ResponseFrame, WireMatrix, WireSolution, WireSolve,
};

/// Counters of one wire session.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionStats {
    /// Non-empty request lines read (oversized lines count — they
    /// consumed a frame slot even though their payload was discarded).
    pub frames: u64,
    /// Solve frames that produced a solution frame (ok or failed);
    /// rejected/undeliverable submissions count as `errors` instead.
    pub solves: u64,
    /// Error frames written (decode failures, rejected submissions,
    /// expired deadlines, oversized lines).
    pub errors: u64,
}

/// Per-session policy. `Default` is the permissive stdio posture: no
/// deadline, no frame-size cap, no stop flag, restrictive decode.
#[derive(Debug, Clone, Default)]
pub struct SessionOptions {
    pub decode: DecodeOptions,
    /// Per-request solve deadline. When the coordinator has not
    /// answered within it, the session writes a `deadline` error frame
    /// and moves on; the solve may still finish server-side, its
    /// result discarded. `None` waits indefinitely.
    pub deadline: Option<Duration>,
    /// Hard cap on one request line's byte length. An over-cap line is
    /// discarded (to the newline) and answered with an `oversized`
    /// error frame; the session continues. `None` is unbounded.
    pub max_frame_bytes: Option<usize>,
    /// Cooperative drain flag, polled between reads. Once set, the
    /// session writes `goodbye` and ends as if the client had sent
    /// `shutdown`. Only effective when the reader yields periodically
    /// (e.g. a socket with a read timeout) — a reader parked in a
    /// blocking `read` is released at its next timeout or byte.
    pub stop: Option<Arc<AtomicBool>>,
}

/// Run one session with default (stdio) options; see
/// [`serve_session_with`].
pub fn serve_session<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    input: R,
    output: W,
) -> Result<SessionStats> {
    serve_session_with(svc, input, output, SessionOptions::default())
}

/// Run one session: read NDJSON request frames from `input`, answer
/// each on `output`, until `shutdown`, EOF, drain, or an I/O error.
/// The service handle is borrowed — the caller owns service lifetime
/// and can serve sequential or concurrent sessions on one warmed-up
/// service (keeping the `FactorCache` across sessions is the point of
/// the fingerprint key).
///
/// Session accounting (`sessions_total`, `active_sessions`,
/// `peak_sessions`, and the folded `wire_*` totals) is recorded on the
/// service metrics even when the session ends in an I/O error.
pub fn serve_session_with<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    mut input: R,
    mut output: W,
    opts: SessionOptions,
) -> Result<SessionStats> {
    svc.metrics().session_opened();
    let outcome = session_loop(svc, &mut input, &mut output, &opts);
    let stats = match &outcome {
        Ok(stats) => *stats,
        Err((stats, _)) => *stats,
    };
    svc.metrics().session_closed(stats.frames, stats.solves, stats.errors);
    if crate::obs::enabled() {
        eprintln!("{}", crate::obs::summary_line(&svc.metrics_snapshot()));
    }
    outcome.map(|_| stats).map_err(|(_, e)| e)
}

/// What one bounded line read produced.
enum ReadOutcome {
    /// A complete request line is in the buffer (newline stripped).
    Line,
    Eof,
    /// The line blew past `max_frame_bytes`; its remainder was
    /// discarded up to the newline (or EOF).
    Oversized,
    /// The drain flag tripped while waiting for input.
    Stopped,
}

/// Read one `\n`-terminated line into `buf`, enforcing the frame-size
/// cap and polling the drain flag whenever the underlying reader
/// yields (`WouldBlock`/`TimedOut`, as sockets with a read timeout do).
/// A partial line buffered at EOF is returned as a final `Line` — a
/// client that writes a frame and half-closes without the trailing
/// newline still gets its answer.
fn read_frame_line<R: BufRead>(
    input: &mut R,
    buf: &mut Vec<u8>,
    max_bytes: Option<usize>,
    stop: Option<&AtomicBool>,
) -> std::io::Result<ReadOutcome> {
    buf.clear();
    let cap = max_bytes.unwrap_or(usize::MAX);
    let mut over = false;
    loop {
        // Drain wins even mid-line: a half-written frame at drain time
        // is dropped, never half-parsed — shutdown must not be
        // stallable by a client that withholds its newline.
        if stop.is_some_and(|s| s.load(Ordering::Relaxed)) {
            return Ok(ReadOutcome::Stopped);
        }
        let chunk = match input.fill_buf() {
            Ok(chunk) => chunk,
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                // Read-timeout tick (or EINTR): loop back to poll the
                // drain flag, then park in the next fill_buf.
                continue;
            }
            Err(e) => return Err(e),
        };
        if chunk.is_empty() {
            return Ok(if over {
                ReadOutcome::Oversized
            } else if buf.is_empty() {
                ReadOutcome::Eof
            } else {
                ReadOutcome::Line
            });
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if !over && buf.len().saturating_add(pos) > cap {
                    over = true;
                    buf.clear();
                } else if !over {
                    buf.extend_from_slice(&chunk[..pos]);
                }
                input.consume(pos + 1);
                return Ok(if over { ReadOutcome::Oversized } else { ReadOutcome::Line });
            }
            None => {
                let len = chunk.len();
                if !over && buf.len().saturating_add(len) > cap {
                    over = true;
                    buf.clear(); // don't hold a frame we already rejected
                } else if !over {
                    buf.extend_from_slice(chunk);
                }
                input.consume(len);
            }
        }
    }
}

fn session_loop<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    input: &mut R,
    output: &mut W,
    opts: &SessionOptions,
) -> std::result::Result<SessionStats, (SessionStats, EbvError)> {
    let mut stats = SessionStats::default();
    let mut buf = Vec::new();
    // Session-sequential fallback ids for requests that don't carry one.
    let mut next_id: u64 = 0;

    loop {
        let outcome =
            read_frame_line(input, &mut buf, opts.max_frame_bytes, opts.stop.as_deref())
                .map_err(|e| (stats, EbvError::io("wire session: read", e)))?;
        let response = match outcome {
            ReadOutcome::Eof => break, // client hung up without `shutdown`; end quietly
            ReadOutcome::Stopped => {
                // Server-initiated drain: say goodbye like a shutdown.
                log::info!(target: "wire", "drain after {} frames", stats.frames);
                write_frame(output, &ResponseFrame::Goodbye { served: stats.solves })
                    .map_err(|e| (stats, e))?;
                break;
            }
            ReadOutcome::Oversized => {
                stats.frames += 1;
                stats.errors += 1;
                ResponseFrame::error(
                    ErrorCode::Oversized,
                    format!(
                        "frame exceeds max_frame_bytes ({}); line discarded",
                        opts.max_frame_bytes.unwrap_or(usize::MAX)
                    ),
                )
            }
            ReadOutcome::Line => {
                let text = match std::str::from_utf8(&buf) {
                    Ok(text) => text.trim(),
                    Err(_) => {
                        stats.frames += 1;
                        stats.errors += 1;
                        write_frame(
                            output,
                            &ResponseFrame::error(
                                ErrorCode::Decode,
                                "frame is not valid UTF-8",
                            ),
                        )
                        .map_err(|e| (stats, e))?;
                        drain_spans(svc);
                        continue;
                    }
                };
                if text.is_empty() {
                    continue;
                }
                stats.frames += 1;

                let decoded = {
                    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Ingest);
                    decode_request_with(text, &opts.decode)
                };
                match decoded {
                    Err(e) => {
                        stats.errors += 1;
                        ResponseFrame::error(ErrorCode::Decode, e.to_string())
                    }
                    Ok(RequestFrame::Shutdown) => {
                        log::info!(target: "wire", "shutdown frame after {} frames", stats.frames);
                        write_frame(output, &ResponseFrame::Goodbye { served: stats.solves })
                            .map_err(|e| (stats, e))?;
                        break;
                    }
                    Ok(RequestFrame::Metrics) => ResponseFrame::Metrics(svc.metrics_snapshot()),
                    Ok(RequestFrame::Solve(ws)) | Ok(RequestFrame::SolveSparse(ws)) => {
                        let id = ws.id.unwrap_or(next_id);
                        next_id = next_id.max(id) + 1;
                        let resp = run_solve(svc, id, ws, opts.deadline);
                        // `served` promises produced solutions; a
                        // rejected or dropped submission is an error,
                        // not a serve.
                        match &resp {
                            ResponseFrame::Solution(_) => stats.solves += 1,
                            ResponseFrame::Error { .. } => stats.errors += 1,
                            _ => {}
                        }
                        resp
                    }
                }
            }
        };
        write_frame(output, &response).map_err(|e| (stats, e))?;
        drain_spans(svc);
    }
    drain_spans(svc);
    Ok(stats)
}

/// Drain the session thread's span sink, crediting the wire-side
/// `ingest`/`encode` time to the service-wide accumulators. The sink is
/// per-request scratch — a long-lived pipe must not accumulate spans
/// forever — so this runs after every frame.
fn drain_spans(svc: &ServiceHandle) {
    if !crate::obs::enabled() {
        return;
    }
    let (mut ingest, mut encode) = (0u64, 0u64);
    for span in crate::obs::take_thread_spans() {
        match span.phase {
            crate::obs::Phase::Ingest => ingest += span.dur_ns,
            crate::obs::Phase::Encode => encode += span.dur_ns,
            _ => {}
        }
    }
    if ingest > 0 {
        svc.metrics().wire_ingest_ns.fetch_add(ingest, Ordering::Relaxed);
    }
    if encode > 0 {
        svc.metrics().wire_encode_ns.fetch_add(encode, Ordering::Relaxed);
    }
}

/// Submit one solve and block for its response frame, up to `deadline`.
fn run_solve(
    svc: &ServiceHandle,
    id: u64,
    ws: WireSolve,
    deadline: Option<Duration>,
) -> ResponseFrame {
    let key = ws.effective_key();
    let pattern_key = ws.effective_pattern_key();
    let WireSolve { matrix, b, .. } = ws;
    let submitted = match matrix {
        WireMatrix::Dense(a) => svc.submit_dense(Arc::new(a), b, key),
        WireMatrix::Sparse(a) => {
            svc.submit_sparse_with_pattern(Arc::new(a), b, key, pattern_key)
        }
    };
    let rx = match submitted {
        Ok(rx) => rx,
        // Admission-control rejection (backpressure): a `busy` error
        // frame, not a failed solution — the client should back off
        // and retry. Any other submit failure is server-side.
        Err(e) => {
            let msg = e.to_string();
            let code =
                if msg.contains("backpressure") { ErrorCode::Busy } else { ErrorCode::Internal };
            return ResponseFrame::error(code, msg);
        }
    };
    let received = match deadline {
        None => rx.recv().map_err(|_| {
            ResponseFrame::error(ErrorCode::Internal, "coordinator: service dropped the request")
        }),
        Some(d) => rx.recv_timeout(d).map_err(|e| match e {
            // The worker's late send to the dropped receiver is a
            // harmless no-op; the result is simply discarded.
            RecvTimeoutError::Timeout => ResponseFrame::error(
                ErrorCode::Deadline,
                format!("deadline: solve not finished within {}ms; result discarded", d.as_millis()),
            ),
            RecvTimeoutError::Disconnected => ResponseFrame::error(
                ErrorCode::Internal,
                "coordinator: service dropped the request",
            ),
        }),
    };
    match received {
        Ok(resp) => ResponseFrame::Solution(WireSolution {
            id,
            result: resp.result,
            residual: resp.residual,
            backend: resp.backend.to_string(),
            batch_size: resp.batch_size,
            matrix_key: key,
            timings: resp.timings,
        }),
        Err(frame) => frame,
    }
}

fn write_frame<W: Write>(output: &mut W, frame: &ResponseFrame) -> Result<()> {
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Encode);
    let mut line = encode_response(frame);
    line.push('\n');
    output
        .write_all(line.as_bytes())
        .and_then(|()| output.flush())
        .map_err(|e| EbvError::io("wire session: write", e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::coordinator::SolverService;
    use crate::matrix::generate::{diag_dominant_dense, GenSeed};
    use crate::wire::codec::{decode_response, encode_request};
    use crate::wire::frame::RequestFrame;

    fn test_service() -> ServiceHandle {
        SolverService::start(ServiceConfig {
            lanes: 2,
            max_batch: 4,
            batch_window_us: 100,
            queue_capacity: 64,
            engine_lanes: 2,
            use_runtime: false,
            ..ServiceConfig::default()
        })
        .unwrap()
    }

    fn run(input: &str) -> (SessionStats, Vec<ResponseFrame>) {
        run_with(input, SessionOptions::default())
    }

    fn run_with(input: &str, opts: SessionOptions) -> (SessionStats, Vec<ResponseFrame>) {
        let svc = test_service();
        let mut out = Vec::new();
        let stats = serve_session_with(&svc, input.as_bytes(), &mut out, opts).unwrap();
        svc.shutdown();
        let text = String::from_utf8(out).unwrap();
        let frames = text.lines().map(|l| decode_response(l).unwrap()).collect();
        (stats, frames)
    }

    #[test]
    fn session_solves_and_says_goodbye() {
        let a = diag_dominant_dense(8, GenSeed(21));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"shutdown\"}}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.solves, 1);
        assert_eq!(stats.errors, 0);
        assert_eq!(frames.len(), 2);
        let ResponseFrame::Solution(s) = &frames[0] else { panic!("{frames:?}") };
        assert!(s.result.is_ok());
        assert!(s.residual < 1e-9);
        assert_eq!(frames[1], ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn bad_line_gets_error_frame_and_session_continues() {
        let a = diag_dominant_dense(6, GenSeed(22));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        let input = format!("this is not json\n{solve}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.frames, 2);
        assert_eq!(stats.errors, 1);
        assert!(
            matches!(&frames[0], ResponseFrame::Error { code: ErrorCode::Decode, .. }),
            "{frames:?}"
        );
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn oversized_line_gets_typed_error_and_session_continues() {
        let a = diag_dominant_dense(6, GenSeed(25));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        assert!(solve.len() <= 4096, "cap must admit the real frame");
        let huge = "x".repeat(5000);
        let input = format!("{huge}\n{solve}\n{{\"op\":\"shutdown\"}}\n");
        let opts = SessionOptions { max_frame_bytes: Some(4096), ..SessionOptions::default() };
        let (stats, frames) = run_with(&input, opts);
        assert_eq!(stats.frames, 3);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.solves, 1);
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Oversized);
        assert!(message.contains("4096"), "{message}");
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
        assert_eq!(frames[2], ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn missing_final_newline_still_answers_the_frame() {
        let a = diag_dominant_dense(5, GenSeed(26));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 5])));
        // No trailing newline: the partial line at EOF is decoded.
        let (stats, frames) = run(&solve);
        assert_eq!(stats.solves, 1);
        assert!(matches!(&frames[0], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn pre_set_stop_flag_drains_before_reading() {
        let a = diag_dominant_dense(4, GenSeed(27));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 4])));
        let stop = Arc::new(AtomicBool::new(true));
        let opts = SessionOptions { stop: Some(Arc::clone(&stop)), ..SessionOptions::default() };
        let (stats, frames) = run_with(&format!("{solve}\n"), opts);
        // The drain flag was set before the first read: goodbye only.
        assert_eq!(stats.solves, 0);
        assert_eq!(frames, vec![ResponseFrame::Goodbye { served: 0 }]);
    }

    #[test]
    fn expired_deadline_yields_deadline_error_frame() {
        let a = diag_dominant_dense(64, GenSeed(28));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 64])));
        let opts = SessionOptions {
            deadline: Some(Duration::from_nanos(1)),
            ..SessionOptions::default()
        };
        let (stats, frames) = run_with(&format!("{solve}\n"), opts);
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.solves, 0);
        let ResponseFrame::Error { code, message } = &frames[0] else { panic!("{frames:?}") };
        assert_eq!(*code, ErrorCode::Deadline);
        assert!(message.contains("deadline"), "{message}");
    }

    #[test]
    fn sessions_fold_into_service_metrics() {
        let svc = test_service();
        let a = diag_dominant_dense(6, GenSeed(29));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        let input = format!("not json\n{solve}\n");
        for _ in 0..2 {
            let mut out = Vec::new();
            serve_session(&svc, input.as_bytes(), &mut out).unwrap();
        }
        let m = svc.metrics_snapshot();
        svc.shutdown();
        assert_eq!(m.sessions_total, 2);
        assert_eq!(m.active_sessions, 0);
        assert_eq!(m.peak_sessions, 1, "sequential sessions never overlap");
        assert_eq!(m.wire_frames, 4);
        assert_eq!(m.wire_solves, 2);
        assert_eq!(m.wire_errors, 2);
    }

    #[test]
    fn metrics_frame_carries_engine_stats() {
        let a = diag_dominant_dense(8, GenSeed(24));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"metrics\"}}\n{{\"op\":\"shutdown\"}}\n");
        let (_, frames) = run(&input);
        let ResponseFrame::Metrics(m) = &frames[1] else { panic!("{frames:?}") };
        // The test service runs a 2-lane engine; an 8×8 solve stays on
        // the sequential fall-through, so jobs may be zero — but the
        // resident pool and solver config are always reported.
        assert_eq!(m.engine_lanes, 2);
        assert_eq!(m.engine_barrier_waits, m.engine_steps * m.engine_lanes);
        assert_eq!(m.panel_width, 64, "default panel width travels in the frame");
        // The in-flight session is visible to its own metrics frame.
        assert_eq!(m.sessions_total, 1);
        assert_eq!(m.active_sessions, 1);
    }

    #[test]
    fn eof_without_shutdown_ends_cleanly() {
        let (stats, frames) = run("");
        assert_eq!(stats, SessionStats::default());
        assert!(frames.is_empty());
    }

    #[test]
    fn server_assigns_sequential_ids_and_echoes_explicit_ones() {
        let a = diag_dominant_dense(4, GenSeed(23));
        let unnumbered = encode_request(&RequestFrame::Solve(WireSolve::dense(a.clone(), vec![1.0; 4])));
        let numbered =
            encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![2.0; 4]).with_id(90)));
        let input = format!("{unnumbered}\n{numbered}\n{unnumbered}\n");
        let (_, frames) = run(&input);
        let ids: Vec<u64> = frames
            .iter()
            .map(|f| match f {
                ResponseFrame::Solution(s) => s.id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![0, 90, 91]);
    }
}
