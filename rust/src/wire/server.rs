//! Blocking NDJSON session loop over the solve service.
//!
//! [`serve_session`] is generic over `BufRead`/`Write`, so the same
//! loop serves `stdin`/`stdout` behind `ebv-solve serve`, in-memory
//! buffers in tests, and (future work) an accepted socket per session.
//! Framing is one JSON object per line; every request line produces
//! exactly one response line, written and flushed before the next read,
//! so a pipe client can drive the session synchronously.
//!
//! Error containment: a malformed line produces an `error` frame and
//! the session continues — one bad request in a long-lived pipe must
//! not tear down the connection. Only I/O failure (peer gone) or a
//! `shutdown` frame ends the loop.
//!
//! With profiling on (`service.profiling` / `serve --profile`) the loop
//! contributes the wire-side spans to the solve timeline — `ingest`
//! around request decode and `encode` around response write — and
//! prints an `obs` summary line to stderr when the session ends.

use std::io::{BufRead, Write};
use std::sync::Arc;

use crate::coordinator::service::ServiceHandle;
use crate::util::error::{EbvError, Result};
use crate::wire::codec::{decode_request_with, encode_response, DecodeOptions};
use crate::wire::frame::{RequestFrame, ResponseFrame, WireMatrix, WireSolution, WireSolve};

/// Counters of one wire session.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionStats {
    /// Non-empty request lines read.
    pub frames: u64,
    /// Solve frames that produced a solution frame (ok or failed);
    /// rejected/undeliverable submissions count as `errors` instead.
    pub solves: u64,
    /// Error frames written (decode failures, rejected submissions).
    pub errors: u64,
}

/// Per-session policy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionOptions {
    pub decode: DecodeOptions,
}

/// Run one session with default (restrictive) options; see
/// [`serve_session_with`].
pub fn serve_session<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    input: R,
    output: W,
) -> Result<SessionStats> {
    serve_session_with(svc, input, output, SessionOptions::default())
}

/// Run one session: read NDJSON request frames from `input`, answer
/// each on `output`, until `shutdown`, EOF, or an I/O error. The
/// service handle is borrowed — the caller owns service lifetime and
/// can serve sequential sessions on one warmed-up service (keeping the
/// `FactorCache` across sessions is the point of the fingerprint key).
pub fn serve_session_with<R: BufRead, W: Write>(
    svc: &ServiceHandle,
    mut input: R,
    mut output: W,
    opts: SessionOptions,
) -> Result<SessionStats> {
    let mut stats = SessionStats::default();
    let mut line = String::new();
    // Session-sequential fallback ids for requests that don't carry one.
    let mut next_id: u64 = 0;

    loop {
        line.clear();
        let n = input
            .read_line(&mut line)
            .map_err(|e| EbvError::io("wire session: read", e))?;
        if n == 0 {
            // EOF without `shutdown`: client hung up; end quietly.
            break;
        }
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        stats.frames += 1;

        let decoded = {
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Ingest);
            decode_request_with(text, &opts.decode)
        };
        let response = match decoded {
            Err(e) => {
                stats.errors += 1;
                ResponseFrame::Error { message: e.to_string() }
            }
            Ok(RequestFrame::Shutdown) => {
                log::info!(target: "wire", "shutdown frame after {} frames", stats.frames);
                write_frame(&mut output, &ResponseFrame::Goodbye { served: stats.solves })?;
                break;
            }
            Ok(RequestFrame::Metrics) => ResponseFrame::Metrics(svc.metrics_snapshot()),
            Ok(RequestFrame::Solve(ws)) | Ok(RequestFrame::SolveSparse(ws)) => {
                let id = ws.id.unwrap_or(next_id);
                next_id = next_id.max(id) + 1;
                let resp = run_solve(svc, id, ws);
                // `served` promises produced solutions; a rejected or
                // dropped submission is an error, not a serve.
                match &resp {
                    ResponseFrame::Solution(_) => stats.solves += 1,
                    ResponseFrame::Error { .. } => stats.errors += 1,
                    _ => {}
                }
                resp
            }
        };
        write_frame(&mut output, &response)?;
        if crate::obs::enabled() {
            // Drain the session thread's span sink every frame — the
            // wire-side ingest/encode spans are per-request scratch,
            // and a long-lived pipe must not accumulate them forever.
            let _ = crate::obs::take_thread_spans();
        }
    }
    if crate::obs::enabled() {
        eprintln!("{}", crate::obs::summary_line(&svc.metrics_snapshot()));
    }
    Ok(stats)
}

/// Submit one solve and block for its response frame.
fn run_solve(svc: &ServiceHandle, id: u64, ws: WireSolve) -> ResponseFrame {
    let key = ws.effective_key();
    let pattern_key = ws.effective_pattern_key();
    let WireSolve { matrix, b, .. } = ws;
    let submitted = match matrix {
        WireMatrix::Dense(a) => svc.submit_dense(Arc::new(a), b, key),
        WireMatrix::Sparse(a) => {
            svc.submit_sparse_with_pattern(Arc::new(a), b, key, pattern_key)
        }
    };
    let rx = match submitted {
        Ok(rx) => rx,
        // Admission-control rejection (backpressure): an error frame,
        // not a failed solution — the client should retry later.
        Err(e) => return ResponseFrame::Error { message: e.to_string() },
    };
    match rx.recv() {
        Ok(resp) => ResponseFrame::Solution(WireSolution {
            id,
            result: resp.result,
            residual: resp.residual,
            backend: resp.backend.to_string(),
            batch_size: resp.batch_size,
            matrix_key: key,
            timings: resp.timings,
        }),
        Err(_) => ResponseFrame::Error {
            message: "coordinator: service dropped the request".to_string(),
        },
    }
}

fn write_frame<W: Write>(output: &mut W, frame: &ResponseFrame) -> Result<()> {
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Encode);
    let mut line = encode_response(frame);
    line.push('\n');
    output
        .write_all(line.as_bytes())
        .and_then(|()| output.flush())
        .map_err(|e| EbvError::io("wire session: write", e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;
    use crate::coordinator::SolverService;
    use crate::matrix::generate::{diag_dominant_dense, GenSeed};
    use crate::wire::codec::{decode_response, encode_request};
    use crate::wire::frame::RequestFrame;

    fn test_service() -> ServiceHandle {
        SolverService::start(ServiceConfig {
            lanes: 2,
            max_batch: 4,
            batch_window_us: 100,
            queue_capacity: 64,
            engine_lanes: 2,
            use_runtime: false,
            ..ServiceConfig::default()
        })
        .unwrap()
    }

    fn run(input: &str) -> (SessionStats, Vec<ResponseFrame>) {
        let svc = test_service();
        let mut out = Vec::new();
        let stats = serve_session(&svc, input.as_bytes(), &mut out).unwrap();
        svc.shutdown();
        let text = String::from_utf8(out).unwrap();
        let frames = text.lines().map(|l| decode_response(l).unwrap()).collect();
        (stats, frames)
    }

    #[test]
    fn session_solves_and_says_goodbye() {
        let a = diag_dominant_dense(8, GenSeed(21));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"shutdown\"}}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.solves, 1);
        assert_eq!(stats.errors, 0);
        assert_eq!(frames.len(), 2);
        let ResponseFrame::Solution(s) = &frames[0] else { panic!("{frames:?}") };
        assert!(s.result.is_ok());
        assert!(s.residual < 1e-9);
        assert_eq!(frames[1], ResponseFrame::Goodbye { served: 1 });
    }

    #[test]
    fn bad_line_gets_error_frame_and_session_continues() {
        let a = diag_dominant_dense(6, GenSeed(22));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 6])));
        let input = format!("this is not json\n{solve}\n");
        let (stats, frames) = run(&input);
        assert_eq!(stats.frames, 2);
        assert_eq!(stats.errors, 1);
        assert!(matches!(frames[0], ResponseFrame::Error { .. }));
        assert!(matches!(&frames[1], ResponseFrame::Solution(s) if s.result.is_ok()));
    }

    #[test]
    fn metrics_frame_carries_engine_stats() {
        let a = diag_dominant_dense(8, GenSeed(24));
        let solve = encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![1.0; 8])));
        let input = format!("{solve}\n{{\"op\":\"metrics\"}}\n{{\"op\":\"shutdown\"}}\n");
        let (_, frames) = run(&input);
        let ResponseFrame::Metrics(m) = &frames[1] else { panic!("{frames:?}") };
        // The test service runs a 2-lane engine; an 8×8 solve stays on
        // the sequential fall-through, so jobs may be zero — but the
        // resident pool and solver config are always reported.
        assert_eq!(m.engine_lanes, 2);
        assert_eq!(m.engine_barrier_waits, m.engine_steps * m.engine_lanes);
        assert_eq!(m.panel_width, 64, "default panel width travels in the frame");
    }

    #[test]
    fn eof_without_shutdown_ends_cleanly() {
        let (stats, frames) = run("");
        assert_eq!(stats, SessionStats::default());
        assert!(frames.is_empty());
    }

    #[test]
    fn server_assigns_sequential_ids_and_echoes_explicit_ones() {
        let a = diag_dominant_dense(4, GenSeed(23));
        let unnumbered = encode_request(&RequestFrame::Solve(WireSolve::dense(a.clone(), vec![1.0; 4])));
        let numbered =
            encode_request(&RequestFrame::Solve(WireSolve::dense(a, vec![2.0; 4]).with_id(90)));
        let input = format!("{unnumbered}\n{numbered}\n{unnumbered}\n");
        let (_, frames) = run(&input);
        let ids: Vec<u64> = frames
            .iter()
            .map(|f| match f {
                ResponseFrame::Solution(s) => s.id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![0, 90, 91]);
    }
}
