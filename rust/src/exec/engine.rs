//! [`LaneEngine`]: the public face of the persistent pool — job
//! submission, the inline fast path, stats, and the process-global
//! default engine used by the standalone solver API.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::exec::stats::{EngineStats, EngineStatsSnapshot};
use crate::exec::team::{LaneTeam, RawJob};
use crate::obs::{LaneProfile, LaneProfileSnapshot};

/// Per-(vlane, step) verdict of a step closure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepCtl {
    /// Keep stepping.
    Continue,
    /// Finish the current step on every lane, then end the job (no step
    /// after this one runs anywhere). Any single vlane may break — the
    /// engine propagates the stop unanimously through the step barrier.
    Break,
}

/// A step closure: `(vlane, step) -> StepCtl`, shared by every lane.
pub type StepFn<'a> = &'a (dyn Fn(usize, usize) -> StepCtl + Sync);

/// A persistent pool of pinned lane workers executing barrier-stepped
/// jobs (see the [module docs](crate::exec)).
///
/// Jobs serialize: `run_steps` from a second thread blocks until the
/// engine is free. That is the intended sharing model — one engine
/// sized for the machine, fed by every solve path, instead of each
/// caller spawning its own oversubscribed lane set.
///
/// # Limitations
/// Submitting from inside a running job of the *same* engine deadlocks
/// (the resident lanes cannot pick up nested work); none of the solver
/// paths nest. A panicking step closure is caught on whichever lane it
/// runs, ends the job at that step, and re-raises on the submitting
/// thread — the pool itself survives and stays usable.
pub struct LaneEngine {
    lanes: usize,
    /// `None` for single-lane engines — those run every job inline.
    team: Option<LaneTeam>,
    /// Serializes jobs; held for the full duration of a pooled job.
    submit: Mutex<()>,
    stats: EngineStats,
    /// Measured per-lane busy/wait accumulators (obs profiler); shared
    /// with the team's workers, written only while profiling is on.
    profile: Arc<LaneProfile>,
    /// Dataflow-mode counters (see [`crate::exec::dep`]): runs, tasks,
    /// and queue-spin iterations, recorded per [`run_dataflow`] call.
    ///
    /// [`run_dataflow`]: crate::exec::run_dataflow
    dep: DepCounters,
}

/// Process-lifetime counters for the dataflow scheduler, one set per
/// engine. Relaxed accumulation — these are whole-run tallies, not a
/// synchronization mechanism.
#[derive(Debug, Default)]
struct DepCounters {
    runs: AtomicU64,
    tasks: AtomicU64,
    spins: AtomicU64,
}

/// Snapshot of an engine's dataflow counters: how many dataflow runs it
/// executed, how many tasks they covered, and how many empty-slot spin
/// iterations lanes burned waiting for work to be published (the
/// dataflow analogue of barrier wait, reported by the ablation benches
/// alongside the profiler's wait ns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DepStatsSnapshot {
    pub runs: u64,
    pub tasks: u64,
    pub spins: u64,
}

impl fmt::Debug for LaneEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LaneEngine").field("lanes", &self.lanes).finish_non_exhaustive()
    }
}

// The auto-impls are lost only to the worker `JoinHandle`s, which expose
// no engine state; everything observable lives behind mutexes (which
// poison) and atomics. A panicking step closure is caught per lane and
// re-raised on the submitter with the pool already joined and
// consistent (see `team::run_job`), so an unwind boundary sees no
// broken invariant.
impl std::panic::UnwindSafe for LaneEngine {}
impl std::panic::RefUnwindSafe for LaneEngine {}

impl LaneEngine {
    /// Engine with `lanes` resident lanes (`lanes - 1` worker threads;
    /// the submitting thread is lane 0). `lanes <= 1` builds an inline
    /// engine with no threads at all.
    pub fn new(lanes: usize) -> LaneEngine {
        let lanes = lanes.max(1);
        let profile = Arc::new(LaneProfile::new(lanes));
        LaneEngine {
            lanes,
            team: (lanes > 1).then(|| LaneTeam::spawn(lanes, Arc::clone(&profile))),
            submit: Mutex::new(()),
            stats: EngineStats::default(),
            profile,
            dep: DepCounters::default(),
        }
    }

    /// Engine sized like [`default_lanes`].
    pub fn auto() -> LaneEngine {
        LaneEngine::new(default_lanes())
    }

    /// Resident lanes (including the submitting lane).
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run a step-loop job: for each of `steps` barrier-separated steps,
    /// execute `f(vlane, step)` once for every virtual lane in
    /// `0..width`. Within a step all vlanes run concurrently (dealt
    /// round-robin across the resident lanes); across steps the barrier
    /// guarantees every write of step `s` is visible at step `s + 1`.
    ///
    /// Blocks until the job completes; the closure may borrow from the
    /// caller's stack. Vlanes must write disjoint data within a step
    /// (the solvers guarantee this by row ownership).
    pub fn run_steps<F>(&self, width: usize, steps: usize, f: F)
    where
        F: Fn(usize, usize) -> StepCtl + Sync,
    {
        if width == 0 || steps == 0 {
            return;
        }
        let Some(team) = &self.team else {
            return self.run_inline(width, steps, &f);
        };
        if width == 1 {
            // One vlane cannot use the pool; skip the hand-off.
            return self.run_inline(width, steps, &f);
        }
        let erased: StepFn<'_> = &f;
        // SAFETY: the only lie is the lifetime — `team.run` joins every
        // lane before returning, so no reference to `f` survives this
        // frame. `F: Sync` makes the shared `&f` sound across lanes.
        let erased: StepFn<'static> =
            unsafe { std::mem::transmute::<StepFn<'_>, StepFn<'static>>(erased) };
        // Poison-tolerant: a previous job's re-raised panic unwound
        // through this lock, but the pool joined cleanly first — the
        // engine remains consistent and serviceable.
        let guard = self.submit.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        team.run(RawJob { f: erased, width, steps });
        drop(guard);
        self.stats.record_pooled_job();
        if crate::obs::enabled() {
            self.profile.record_job();
        }
    }

    /// Caller-thread execution preserving pooled semantics exactly: all
    /// vlanes of a step run (in ascending order) even when one breaks,
    /// and no later step runs after a break.
    fn run_inline(&self, width: usize, steps: usize, f: &(dyn Fn(usize, usize) -> StepCtl + Sync)) {
        self.stats.record_inline_job();
        // Inline jobs have no barrier: all time is lane-0 busy time.
        let t0 = crate::obs::enabled().then(std::time::Instant::now);
        for step in 0..steps {
            let mut stop = false;
            for vlane in 0..width {
                if f(vlane, step) == StepCtl::Break {
                    stop = true;
                }
            }
            if stop {
                break;
            }
        }
        if let Some(t0) = t0 {
            self.profile.record(0, t0.elapsed().as_nanos() as u64, 0);
            self.profile.record_job();
        }
    }

    /// Detached counters for metrics frames and logs.
    pub fn stats(&self) -> EngineStatsSnapshot {
        let (steps, barrier_waits, slow_waits) = match &self.team {
            Some(t) => (t.generations(), t.waits(), t.slow_waits()),
            None => (0, 0, 0),
        };
        let profile = self.profile.snapshot();
        EngineStatsSnapshot {
            lanes: self.lanes as u64,
            jobs: self.stats.jobs.load(Ordering::Relaxed),
            inline_jobs: self.stats.inline_jobs.load(Ordering::Relaxed),
            steps,
            barrier_waits,
            slow_waits,
            busy_ns: profile.total_busy_ns(),
            wait_ns: profile.total_wait_ns(),
            profiled_jobs: profile.jobs,
        }
    }

    /// Point-in-time copy of the measured per-lane busy/wait profile
    /// (all zeros unless the process ran with profiling on).
    pub fn lane_profile(&self) -> LaneProfileSnapshot {
        self.profile.snapshot()
    }

    /// Detached dataflow-mode counters (see
    /// [`run_dataflow`](crate::exec::run_dataflow)): all zeros until
    /// some path runs with `Schedule::Dataflow`.
    pub fn dep_stats(&self) -> DepStatsSnapshot {
        DepStatsSnapshot {
            runs: self.dep.runs.load(Ordering::Relaxed),
            tasks: self.dep.tasks.load(Ordering::Relaxed),
            spins: self.dep.spins.load(Ordering::Relaxed),
        }
    }

    /// Tally one completed dataflow run (called by `dep::run_dataflow`).
    pub(crate) fn record_dep_run(&self, tasks: u64, spins: u64) {
        self.dep.runs.fetch_add(1, Ordering::Relaxed);
        self.dep.tasks.fetch_add(tasks, Ordering::Relaxed);
        self.dep.spins.fetch_add(spins, Ordering::Relaxed);
    }
}

/// Lane count for auto-sized engines: `EBV_ENGINE_LANES` if set and
/// positive, else the machine's available parallelism.
pub fn default_lanes() -> usize {
    std::env::var("EBV_ENGINE_LANES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        })
}

static GLOBAL: OnceLock<LaneEngine> = OnceLock::new();

/// The process-global default engine, built on first use. The
/// standalone solver API (solvers constructed without an explicit
/// engine) submits here, so library users get pooled execution without
/// plumbing; services construct their own sized engine and share it via
/// [`Arc`].
pub fn global() -> &'static LaneEngine {
    GLOBAL.get_or_init(LaneEngine::auto)
}

/// Convenience for call sites holding an optional engine override.
pub fn engine_or_global(engine: Option<&Arc<LaneEngine>>) -> &LaneEngine {
    match engine {
        Some(e) => e.as_ref(),
        None => global(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Per-(vlane, step) execution counter grid.
    fn count_grid(width: usize, steps: usize) -> Vec<Vec<AtomicUsize>> {
        (0..steps)
            .map(|_| (0..width).map(|_| AtomicUsize::new(0)).collect())
            .collect()
    }

    #[test]
    fn every_vlane_runs_every_step() {
        for lanes in [1usize, 2, 4] {
            let engine = LaneEngine::new(lanes);
            for width in [1usize, 2, 3, 7] {
                let steps = 5;
                let grid = count_grid(width, steps);
                engine.run_steps(width, steps, |vlane, step| {
                    grid[step][vlane].fetch_add(1, Ordering::Relaxed);
                    StepCtl::Continue
                });
                for row in &grid {
                    for cell in row {
                        assert_eq!(cell.load(Ordering::Relaxed), 1, "lanes={lanes} width={width}");
                    }
                }
            }
        }
    }

    #[test]
    fn barrier_publishes_nonatomic_writes() {
        // Ping-pong ring shift through two plain (non-atomic) buffers:
        // step s reads the buffer written at step s-1, so the values can
        // only come out right if the step barrier publishes every write.
        let engine = LaneEngine::new(4);
        let width = 8;
        let steps = 50;
        let mut a = vec![0u64; width];
        let mut b = vec![0u64; width];
        let pa = crate::exec::LaneSlots::new(&mut a);
        let pb = crate::exec::LaneSlots::new(&mut b);
        engine.run_steps(width, steps, |vlane, step| {
            let (src, dst) = if step % 2 == 0 { (&pa, &pb) } else { (&pb, &pa) };
            // SAFETY: each vlane writes only dst[vlane]; each src slot
            // has exactly one reader, and src was last written a step
            // ago (published by the barrier).
            unsafe { *dst.slot(vlane) = *src.slot((vlane + 1) % width) + 1 };
            StepCtl::Continue
        });
        // The final write of step `steps - 1` landed in `a` (odd last
        // step writes the even-parity buffer).
        assert!(a.iter().all(|&v| v == steps as u64), "{a:?}");
    }

    #[test]
    fn break_finishes_step_and_stops_after() {
        for lanes in [1usize, 3] {
            let engine = LaneEngine::new(lanes);
            let width = 6;
            let steps = 8;
            let grid = count_grid(width, steps);
            engine.run_steps(width, steps, |vlane, step| {
                grid[step][vlane].fetch_add(1, Ordering::Relaxed);
                // Only vlane 2 hits the stop condition, at step 3 — the
                // heterogeneous case (e.g. a zero diagonal seen by one
                // owner).
                if vlane == 2 && step == 3 {
                    StepCtl::Break
                } else {
                    StepCtl::Continue
                }
            });
            for (step, row) in grid.iter().enumerate() {
                for (vlane, cell) in row.iter().enumerate() {
                    let expected = usize::from(step <= 3);
                    assert_eq!(
                        cell.load(Ordering::Relaxed),
                        expected,
                        "lanes={lanes} step={step} vlane={vlane}"
                    );
                }
            }
        }
    }

    #[test]
    fn jobs_serialize_across_threads() {
        let engine = std::sync::Arc::new(LaneEngine::new(2));
        let in_job = std::sync::Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let engine = std::sync::Arc::clone(&engine);
                let in_job = std::sync::Arc::clone(&in_job);
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        engine.run_steps(2, 3, |vlane, _| {
                            if vlane == 0 {
                                // Exactly one job may be inside the pool.
                                let now = in_job.fetch_add(1, Ordering::SeqCst);
                                assert_eq!(now, 0, "jobs overlapped");
                                in_job.fetch_sub(1, Ordering::SeqCst);
                            }
                            StepCtl::Continue
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("submitter thread");
        }
        assert_eq!(engine.stats().jobs, 80);
    }

    #[test]
    fn stats_track_inline_and_pooled() {
        let engine = LaneEngine::new(2);
        engine.run_steps(1, 4, |_, _| StepCtl::Continue); // width 1 -> inline
        engine.run_steps(3, 4, |_, _| StepCtl::Continue); // pooled
        let s = engine.stats();
        assert_eq!(s.lanes, 2);
        assert_eq!(s.inline_jobs, 1);
        assert_eq!(s.jobs, 1);
        assert_eq!(s.steps, 4);
        assert_eq!(s.barrier_waits, 8);

        let inline = LaneEngine::new(1);
        inline.run_steps(5, 5, |_, _| StepCtl::Continue);
        assert_eq!(inline.stats().inline_jobs, 1);
        assert_eq!(inline.stats().steps, 0);
    }

    #[test]
    fn panicking_closure_propagates_and_pool_survives() {
        let engine = LaneEngine::new(3);
        // vlane 4 lives on a *worker* lane (4 % 3 == 1): the panic must
        // cross back to the submitting thread, not hang the barrier.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run_steps(6, 4, |vlane, step| {
                if vlane == 4 && step == 1 {
                    panic!("boom in a lane");
                }
                StepCtl::Continue
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");

        // The pool is intact: a subsequent job runs every (vlane, step).
        let grid = count_grid(2, 3);
        engine.run_steps(2, 3, |vlane, step| {
            grid[step][vlane].fetch_add(1, Ordering::Relaxed);
            StepCtl::Continue
        });
        assert!(grid.iter().flatten().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_work_is_a_no_op() {
        let engine = LaneEngine::new(2);
        engine.run_steps(0, 10, |_, _| panic!("must not run"));
        engine.run_steps(10, 0, |_, _| panic!("must not run"));
        assert_eq!(engine.stats().jobs + engine.stats().inline_jobs, 0);
    }

    #[test]
    fn profiling_fills_the_lane_profile() {
        let _on = crate::obs::testhooks::Enabled::new();
        let engine = LaneEngine::new(2);
        engine.run_steps(4, 6, |_, _| StepCtl::Continue); // pooled
        engine.run_steps(1, 3, |_, _| StepCtl::Continue); // width 1 -> inline
        let p = engine.lane_profile();
        assert_eq!(p.busy_ns.len(), 2);
        assert_eq!(p.jobs, 2, "pooled + inline both profiled");
        let s = engine.stats();
        assert_eq!(s.profiled_jobs, 2);
        assert_eq!(s.busy_ns, p.total_busy_ns());
        assert_eq!(s.wait_ns, p.total_wait_ns());
    }

    #[test]
    fn disabled_profiling_records_nothing() {
        let _g = crate::obs::testhooks::OBS_LOCK
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        crate::obs::set_enabled(false);
        let engine = LaneEngine::new(2);
        engine.run_steps(4, 5, |_, _| StepCtl::Continue);
        let p = engine.lane_profile();
        assert_eq!(p.total_busy_ns(), 0);
        assert_eq!(p.total_wait_ns(), 0);
        assert_eq!(p.jobs, 0);
    }

    #[test]
    fn global_engine_is_shared_and_sized() {
        let g1 = global() as *const LaneEngine;
        let g2 = global() as *const LaneEngine;
        assert_eq!(g1, g2);
        assert!(global().lanes() >= 1);
    }
}
