//! Dependency-counted dataflow scheduling: the barrier-free execution
//! mode for DAG-shaped work.
//!
//! The epoch-barrier model ([`LaneEngine::run_steps`]) charges one
//! global barrier per elimination step — `FactorPlan` prices
//! `(n-1) + panels` of them for dense and one per DAG level for
//! sparse, and the PR-6 profiler measures the per-lane wait each one
//! costs. GLU 3.0-style factorization and self-scheduling triangular
//! solvers (PAPERS.md) show the alternative: give every task an atomic
//! *remaining-dependency* counter, let finishing tasks decrement their
//! children, and have lanes pull whatever is ready from a shared queue.
//! The whole DAG then executes as **one** engine step — a single
//! barrier entry per run regardless of depth.
//!
//! Two rules make the mode safe and bit-stable:
//!
//! * **Happens-before through the counters.** A task's completion
//!   performs an `AcqRel` `fetch_sub` on each child's counter; the
//!   lane that takes the counter to zero publishes the child with a
//!   `Release` store, and claimants spin with `Acquire` loads. RMWs on
//!   one counter form a release sequence, so *every* parent's writes —
//!   not just the last decrementer's — are visible to the child before
//!   it runs. Task arithmetic therefore never observes a torn or stale
//!   operand, and results are bitwise independent of lane count and
//!   interleaving (pinned in `tests/prop_schedule.rs`).
//! * **The break/panic protocol is preserved.** The scheduler runs
//!   inside an ordinary engine job, but lanes waiting on unpublished
//!   queue slots spin on the scheduler's own stop flag — so a breaking
//!   or panicking task must raise that flag *before* unwinding into
//!   the team's handler, or its siblings would wait forever for work
//!   that will never be published. [`run_dataflow`] does exactly that:
//!   `StepCtl::Break` and panics both stop the scheduler first; the
//!   panic payload then re-raises on the submitting thread via the
//!   team's existing stash, and the pool survives (stress-tested in
//!   `tests/prop_schedule.rs` to the `exec_engine.rs` bar).
//!
//! The queue is a fixed-size array MPMC: one slot per task, `0` the
//! empty sentinel (tasks are stored as `task + 1`), `tail` counting
//! publishes and `head` counting claims. A claimant whose slot is not
//! yet published spins (budgeted, then yields) until the producing
//! lane stores it — claims never exceed the task count, and in an
//! acyclic graph every claimed slot is eventually published unless the
//! run stops early. Graphs must be acyclic; construction asserts at
//! least one root so a cyclic graph fails fast instead of deadlocking.
//!
//! See `rust/DESIGN.md` §Dataflow scheduling for the ledger rows this
//! mode adds and the fallback matrix (which paths stay barrier-stepped).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

use crate::exec::{LaneEngine, StepCtl};

/// Execution schedule for the parallel factor/solve paths: classic
/// barrier-per-step epochs, or dependency-counted dataflow. Named so
/// CLI flags, config files, metrics, and the wire codec agree on
/// spelling (the `RowDist`/`Kernel` idiom).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    /// One global epoch barrier per elimination step / DAG level — the
    /// paper's `__syncthreads()` shape, and the default until dataflow
    /// is benched ahead on the target machine.
    #[default]
    Barrier,
    /// Dependency-counted self-scheduling: ready tasks run as soon as
    /// their inputs land, one barrier entry per whole run.
    Dataflow,
}

impl Schedule {
    /// Every schedule, in documentation order.
    pub const ALL: [Schedule; 2] = [Schedule::Barrier, Schedule::Dataflow];

    /// Stable lowercase name used by `--schedule`, metrics, and the
    /// wire codec.
    pub fn name(self) -> &'static str {
        match self {
            Schedule::Barrier => "barrier",
            Schedule::Dataflow => "dataflow",
        }
    }

    /// Inverse of [`Schedule::name`].
    pub fn parse(s: &str) -> Option<Schedule> {
        Schedule::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// A task DAG under construction: per-task remaining-dependency counts
/// plus the forward (parent → children) adjacency the scheduler walks
/// on completion. Tasks are dense indices `0..tasks`; edges are added
/// parent-first by the solver building the graph.
#[derive(Debug, Clone)]
pub struct DepGraph {
    deps: Vec<u32>,
    children: Vec<Vec<usize>>,
    edges: usize,
}

impl DepGraph {
    /// An edgeless graph of `tasks` tasks (all initially ready).
    pub fn new(tasks: usize) -> DepGraph {
        DepGraph { deps: vec![0; tasks], children: vec![Vec::new(); tasks], edges: 0 }
    }

    #[inline]
    pub fn tasks(&self) -> usize {
        self.deps.len()
    }

    #[inline]
    pub fn edges(&self) -> usize {
        self.edges
    }

    /// Declare that `child` must not start before `parent` completes.
    /// Duplicate edges are allowed (the counter balances because each
    /// completion decrements once per recorded edge).
    pub fn add_edge(&mut self, parent: usize, child: usize) {
        assert!(parent < self.tasks() && child < self.tasks(), "DepGraph: edge out of range");
        assert_ne!(parent, child, "DepGraph: self-edge would deadlock");
        self.deps[child] += 1;
        self.children[parent].push(child);
        self.edges += 1;
    }
}

/// Budgeted spin before yielding while waiting on an unpublished slot —
/// same shape as the team's job-wait spin.
const SPIN_BUDGET: u32 = 1 << 10;

/// The runtime state of one dataflow run: counters, flattened
/// adjacency, and the array MPMC ready queue.
struct DepScheduler {
    remaining: Vec<AtomicU32>,
    child_ptr: Vec<usize>,
    child_idx: Vec<usize>,
    /// One slot per task; `0` = empty, else `task + 1`.
    slots: Vec<AtomicUsize>,
    head: AtomicUsize,
    tail: AtomicUsize,
    stop: AtomicBool,
    /// Total empty-slot spin iterations across all lanes (the honest
    /// "wait" figure for this mode — dataflow spin time counts as busy
    /// in the lane profiler's accounting).
    spins: AtomicU64,
}

impl DepScheduler {
    fn new(graph: &DepGraph) -> DepScheduler {
        let tasks = graph.tasks();
        let sched = DepScheduler {
            remaining: graph.deps.iter().map(|&d| AtomicU32::new(d)).collect(),
            child_ptr: {
                let mut ptr = Vec::with_capacity(tasks + 1);
                ptr.push(0);
                let mut acc = 0;
                for c in &graph.children {
                    acc += c.len();
                    ptr.push(acc);
                }
                ptr
            },
            child_idx: graph.children.iter().flat_map(|c| c.iter().copied()).collect(),
            slots: (0..tasks).map(|_| AtomicUsize::new(0)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            spins: AtomicU64::new(0),
        };
        let mut roots = 0;
        for (t, &d) in graph.deps.iter().enumerate() {
            if d == 0 {
                sched.push(t);
                roots += 1;
            }
        }
        assert!(tasks == 0 || roots > 0, "DepScheduler: graph has no roots (cycle)");
        sched
    }

    /// Publish a ready task. Each task is pushed exactly once, so the
    /// publish index never exceeds the slot count.
    #[inline]
    fn push(&self, task: usize) {
        let t = self.tail.fetch_add(1, Ordering::Relaxed);
        self.slots[t].store(task + 1, Ordering::Release);
    }

    /// Claim the next task, spinning until its slot is published.
    /// Returns `None` when every task has been claimed or the run
    /// stopped early (break or panic elsewhere).
    fn pop(&self, spins_local: &mut u64) -> Option<usize> {
        let h = self.head.fetch_add(1, Ordering::Relaxed);
        if h >= self.slots.len() {
            return None;
        }
        let mut spin = 0u32;
        loop {
            if self.stop.load(Ordering::Acquire) {
                return None;
            }
            let v = self.slots[h].load(Ordering::Acquire);
            if v != 0 {
                return Some(v - 1);
            }
            spin = spin.saturating_add(1);
            *spins_local += 1;
            if spin > SPIN_BUDGET {
                std::thread::yield_now();
            }
        }
    }

    /// Retire a finished task: decrement each child's counter and
    /// publish the ones that hit zero. The `AcqRel` RMW chains every
    /// parent's writes into the child's claim (see module docs).
    fn complete(&self, task: usize) {
        let (lo, hi) = (self.child_ptr[task], self.child_ptr[task + 1]);
        for &c in &self.child_idx[lo..hi] {
            if self.remaining[c].fetch_sub(1, Ordering::AcqRel) == 1 {
                self.push(c);
            }
        }
    }

    /// One lane's work loop: claim, run, retire, until the queue is
    /// drained or the run stops. A breaking task raises `stop` and
    /// forwards `Break`; a panicking task raises `stop` *first* so
    /// sibling lanes stop spinning, then unwinds into the team's
    /// catch/stash/re-raise protocol.
    fn drain<F>(&self, worker: usize, f: &F) -> StepCtl
    where
        F: Fn(usize, usize) -> StepCtl + Sync,
    {
        let mut spins_local = 0u64;
        let mut ctl = StepCtl::Continue;
        while let Some(task) = self.pop(&mut spins_local) {
            match catch_unwind(AssertUnwindSafe(|| f(worker, task))) {
                Ok(StepCtl::Continue) => self.complete(task),
                Ok(StepCtl::Break) => {
                    self.stop.store(true, Ordering::Release);
                    ctl = StepCtl::Break;
                    break;
                }
                Err(payload) => {
                    self.stop.store(true, Ordering::Release);
                    if spins_local > 0 {
                        self.spins.fetch_add(spins_local, Ordering::Relaxed);
                    }
                    resume_unwind(payload);
                }
            }
        }
        if spins_local > 0 {
            self.spins.fetch_add(spins_local, Ordering::Relaxed);
        }
        ctl
    }
}

/// Execute `graph` as one dataflow run on `engine`: every lane
/// self-schedules ready tasks, `f(worker, task)` runs each task exactly
/// once with all parents completed (and their writes visible), and the
/// whole run costs a single engine step — one barrier entry — no matter
/// how deep the DAG is. `worker` is the executing virtual lane in
/// `0..engine.lanes()`, for per-lane scratch via
/// [`LaneSlots`](crate::exec::LaneSlots).
///
/// `StepCtl::Break` from a task stops the run after in-flight tasks
/// finish (tasks not yet claimed never start); a panicking task
/// re-raises on the submitting thread and leaves the pool serviceable,
/// exactly like the barrier path. On a single-lane engine the run is
/// inline and sequential — bitwise the same result, by the
/// happens-before argument in the module docs.
pub fn run_dataflow<F>(engine: &LaneEngine, graph: &DepGraph, f: F)
where
    F: Fn(usize, usize) -> StepCtl + Sync,
{
    if graph.tasks() == 0 {
        return;
    }
    let sched = DepScheduler::new(graph);
    let width = engine.lanes().max(1);
    engine.run_steps(width, 1, |worker, _step| sched.drain(worker, &f));
    engine.record_dep_run(graph.tasks() as u64, sched.spins.load(Ordering::Relaxed));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    #[test]
    fn schedule_names_parse_round_trip() {
        for s in Schedule::ALL {
            assert_eq!(Schedule::parse(s.name()), Some(s));
        }
        assert_eq!(Schedule::parse("levels"), None);
        assert_eq!(Schedule::default(), Schedule::Barrier);
    }

    #[test]
    fn empty_graph_is_a_no_op() {
        let engine = LaneEngine::new(2);
        run_dataflow(&engine, &DepGraph::new(0), |_, _| panic!("no tasks to run"));
    }

    #[test]
    fn chain_runs_in_dependency_order() {
        let n = 64;
        let mut g = DepGraph::new(n);
        for t in 1..n {
            g.add_edge(t - 1, t);
        }
        assert_eq!(g.edges(), n - 1);
        let engine = LaneEngine::new(4);
        let order = Mutex::new(Vec::new());
        run_dataflow(&engine, &g, |_, task| {
            order.lock().unwrap().push(task);
            StepCtl::Continue
        });
        assert_eq!(*order.lock().unwrap(), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn diamond_runs_each_task_once_with_parents_first() {
        // 0 -> {1..=6} -> 7, repeated 8 times in sequence.
        let layers = 8;
        let per = 8; // 1 source + 6 middles + 1 sink
        let mut g = DepGraph::new(layers * per);
        for l in 0..layers {
            let base = l * per;
            for m in 1..=6 {
                g.add_edge(base, base + m);
                g.add_edge(base + m, base + 7);
            }
            if l > 0 {
                g.add_edge(base - 1, base);
            }
        }
        let engine = LaneEngine::new(4);
        let runs: Vec<AtomicUsize> = (0..g.tasks()).map(|_| AtomicUsize::new(0)).collect();
        let order = Mutex::new(Vec::new());
        run_dataflow(&engine, &g, |_, task| {
            runs[task].fetch_add(1, Ordering::Relaxed);
            order.lock().unwrap().push(task);
            StepCtl::Continue
        });
        for r in &runs {
            assert_eq!(r.load(Ordering::Relaxed), 1);
        }
        let order = order.lock().unwrap();
        let pos = |t: usize| order.iter().position(|&x| x == t).unwrap();
        for l in 0..layers {
            let base = l * per;
            for m in 1..=6 {
                assert!(pos(base) < pos(base + m));
                assert!(pos(base + m) < pos(base + 7));
            }
        }
    }

    #[test]
    fn break_stops_unclaimed_tasks() {
        let n = 100;
        let mut g = DepGraph::new(n);
        for t in 1..n {
            g.add_edge(t - 1, t);
        }
        let engine = LaneEngine::new(4);
        let ran = AtomicUsize::new(0);
        run_dataflow(&engine, &g, |_, task| {
            ran.fetch_add(1, Ordering::Relaxed);
            if task == 10 {
                StepCtl::Break
            } else {
                StepCtl::Continue
            }
        });
        // A chain serializes execution, so exactly tasks 0..=10 ran.
        assert_eq!(ran.load(Ordering::Relaxed), 11);
    }

    #[test]
    fn panicking_task_reraises_and_pool_survives() {
        let engine = LaneEngine::new(4);
        let mut g = DepGraph::new(32);
        for t in 1..32 {
            g.add_edge(0, t);
        }
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_dataflow(&engine, &g, |_, task| {
                if task == 7 {
                    panic!("task 7 exploded");
                }
                StepCtl::Continue
            });
        }));
        let payload = caught.expect_err("panic must re-raise on the submitter");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "task 7 exploded");

        // The pool must remain serviceable for both execution modes.
        let hits = AtomicUsize::new(0);
        engine.run_steps(4, 2, |_, _| {
            hits.fetch_add(1, Ordering::Relaxed);
            StepCtl::Continue
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        let again = AtomicUsize::new(0);
        run_dataflow(&engine, &DepGraph::new(5), |_, _| {
            again.fetch_add(1, Ordering::Relaxed);
            StepCtl::Continue
        });
        assert_eq!(again.load(Ordering::Relaxed), 5);
    }

    #[test]
    #[should_panic(expected = "no roots")]
    fn cyclic_graph_fails_fast() {
        let mut g = DepGraph::new(2);
        g.add_edge(0, 1);
        g.add_edge(1, 0);
        let engine = LaneEngine::new(1);
        run_dataflow(&engine, &g, |_, _| StepCtl::Continue);
    }

    #[test]
    fn dep_stats_count_runs_and_tasks() {
        let engine = LaneEngine::new(2);
        let before = engine.dep_stats();
        run_dataflow(&engine, &DepGraph::new(3), |_, _| StepCtl::Continue);
        run_dataflow(&engine, &DepGraph::new(4), |_, _| StepCtl::Continue);
        let after = engine.dep_stats();
        assert_eq!(after.runs - before.runs, 2);
        assert_eq!(after.tasks - before.tasks, 7);
    }

    #[test]
    fn single_lane_engine_runs_inline_and_in_order() {
        let engine = LaneEngine::new(1);
        let mut g = DepGraph::new(8);
        for t in 1..8 {
            g.add_edge(t - 1, t);
        }
        let order = Mutex::new(Vec::new());
        run_dataflow(&engine, &g, |worker, task| {
            assert_eq!(worker, 0);
            order.lock().unwrap().push(task);
            StepCtl::Continue
        });
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }
}
