//! The resident worker team behind a [`LaneEngine`](super::LaneEngine).
//!
//! A team of `lanes` parties runs every job: `lanes - 1` spawned worker
//! threads (named `ebv-lane-1 …`) plus the submitting thread, which
//! participates as lane 0 — the submitter's share of the work runs
//! without a handoff, and it spins at the step barrier alongside the
//! workers instead of parking on a completion queue.
//!
//! Job hand-off protocol (one mutex + condvar, jobs strictly serialized
//! by the engine's submit lock):
//!
//! 1. The submitter publishes a [`RawJob`] under the slot mutex, bumps
//!    the epoch, sets `active = lanes - 1` and notifies the workers.
//! 2. Every party runs the step loop ([`run_job`]): per step, execute
//!    the closure for each owned virtual lane, cross the barrier, then
//!    stop if any vlane requested it. All parties therefore cross the
//!    barrier the same number of times and stop on the same step — the
//!    invariant that keeps a fixed-party barrier deadlock-free even
//!    when only one vlane hits the stop condition (e.g. a zero diagonal
//!    seen only by its owner).
//! 3. Workers decrement `active`; the submitter waits for zero before
//!    returning, so the type-erased closure is never dereferenced after
//!    its real lifetime ends.
//!
//! Panics: every closure call is wrapped in `catch_unwind`. A panicking
//! vlane is treated as a [`StepCtl::Break`] (so all lanes still stop on
//! the same step and the fixed-party barrier stays sound), the first
//! payload is stashed, and the submitter re-raises it after the join —
//! the same observable behavior as the scoped seed, whose panic
//! propagated at `thread::scope` join, except the pool survives.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Instant;

use crate::exec::barrier::EpochBarrier;
use crate::exec::engine::{StepCtl, StepFn};
use crate::obs::LaneProfile;

/// A published job: the lifetime-erased step closure plus its shape.
/// `Copy` so workers can lift it out of the slot without holding the
/// lock during execution.
#[derive(Clone, Copy)]
pub(crate) struct RawJob {
    /// Points at the submitter's closure; valid for the job's duration
    /// because the submitter joins (`active == 0`) before returning.
    pub(crate) f: StepFn<'static>,
    /// Virtual lanes (schedule width); may exceed the pool size.
    pub(crate) width: usize,
    /// Barrier-separated steps.
    pub(crate) steps: usize,
}

/// Slot + wakeup state shared by the team.
struct JobSlot {
    job: Option<RawJob>,
    /// Bumped once per published job; workers track the last epoch they
    /// executed, so a slow worker can never miss or double-run a job.
    epoch: u64,
    shutdown: bool,
}

struct TeamShared {
    slot: Mutex<JobSlot>,
    job_cv: Condvar,
    barrier: EpochBarrier,
    /// Any vlane returning [`StepCtl::Break`] (or panicking) sets this;
    /// every party checks it right after the step barrier, so all stop
    /// together.
    stop: AtomicBool,
    /// Workers still inside the current job's step loop.
    active: AtomicUsize,
    /// First panic payload caught in the current job; re-raised on the
    /// submitting thread after the join.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Per-lane busy/wait accumulators, shared with the owning engine.
    /// Written only while `obs::enabled()` — one flush per lane per job.
    profile: Arc<LaneProfile>,
}

/// Resident pool of `lanes - 1` workers; the submitter is lane 0.
pub(crate) struct LaneTeam {
    shared: Arc<TeamShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    lanes: usize,
}

impl LaneTeam {
    /// Spawn the team (`lanes >= 2`; single-lane engines run inline and
    /// never build a team).
    pub(crate) fn spawn(lanes: usize, profile: Arc<LaneProfile>) -> LaneTeam {
        assert!(lanes >= 2, "LaneTeam: needs at least two lanes");
        let shared = Arc::new(TeamShared {
            slot: Mutex::new(JobSlot { job: None, epoch: 0, shutdown: false }),
            job_cv: Condvar::new(),
            barrier: EpochBarrier::new(lanes),
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            panic: Mutex::new(None),
            profile,
        });
        let workers = (1..lanes)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ebv-lane-{lane}"))
                    .spawn(move || lane_main(lane, lanes, &shared))
                    .expect("spawn lane worker")
            })
            .collect();
        LaneTeam { shared, workers, lanes }
    }

    pub(crate) fn generations(&self) -> u64 {
        self.shared.barrier.generations()
    }

    pub(crate) fn waits(&self) -> u64 {
        self.shared.barrier.waits()
    }

    pub(crate) fn slow_waits(&self) -> u64 {
        self.shared.barrier.slow_waits()
    }

    /// Run one job to completion on the team, participating as lane 0.
    /// Caller must hold the engine's submit lock (jobs serialize).
    pub(crate) fn run(&self, job: RawJob) {
        let shared = &self.shared;
        // Reset the per-job flags *before* publication; the slot mutex
        // orders these writes ahead of every worker's pickup.
        shared.stop.store(false, Ordering::Relaxed);
        shared.active.store(self.lanes - 1, Ordering::Relaxed);
        {
            let mut slot = shared.slot.lock().expect("engine job slot");
            debug_assert!(slot.job.is_none(), "jobs must serialize");
            slot.epoch += 1;
            slot.job = Some(job);
            shared.job_cv.notify_all();
        }

        run_job(0, self.lanes, &job, shared);

        // Wait for the workers to leave the step loop before the
        // borrowed closure goes out of scope. They are at most a few
        // instructions behind (everyone crossed the same final
        // barrier), so spin briefly and then yield.
        let mut spins = 0u32;
        while shared.active.load(Ordering::Acquire) != 0 {
            if spins < 1 << 10 {
                std::hint::spin_loop();
                spins += 1;
            } else {
                std::thread::yield_now();
            }
        }
        shared.slot.lock().expect("engine job slot").job = None;

        // Re-raise the first panic any lane caught during this job. The
        // pool is fully consistent at this point (all lanes joined, the
        // slot is clear), so the engine stays usable afterwards.
        let caught = shared.panic.lock().unwrap_or_else(PoisonError::into_inner).take();
        if let Some(payload) = caught {
            resume_unwind(payload);
        }
    }
}

impl Drop for LaneTeam {
    fn drop(&mut self) {
        {
            // `into_inner` (not `expect`): shutting down a team whose
            // lock was poisoned by a panicking job must not double-panic.
            let mut slot =
                self.shared.slot.lock().unwrap_or_else(PoisonError::into_inner);
            slot.shutdown = true;
            self.shared.job_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn lane_main(lane: usize, lanes: usize, shared: &TeamShared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().expect("engine job slot");
            while !slot.shutdown && (slot.job.is_none() || slot.epoch == seen) {
                slot = shared.job_cv.wait(slot).expect("engine job slot");
            }
            if slot.shutdown {
                return;
            }
            seen = slot.epoch;
            slot.job.expect("checked by wait condition")
        };
        run_job(lane, lanes, &job, shared);
        shared.active.fetch_sub(1, Ordering::Release);
    }
}

/// One party's step loop. Virtual lanes are dealt round-robin: party
/// `lane` of `lanes` runs vlanes `lane, lane + lanes, …` each step —
/// within a step vlane order is irrelevant (vlanes own disjoint rows),
/// and across steps the barrier provides the dependency ordering.
///
/// Stop protocol: a vlane returning [`StepCtl::Break`] publishes the
/// stop flag but the *current* step still completes on every party
/// (matching the scoped seed semantics, where each lane detected the
/// same condition independently); the flag is observed after the step
/// barrier, which makes the read race-free and unanimous. A panicking
/// vlane is a Break whose payload is stashed for the submitter — the
/// lane keeps crossing barriers, so nobody deadlocks.
fn run_job(lane: usize, lanes: usize, job: &RawJob, shared: &TeamShared) {
    let f = job.f;
    // Zero-overhead contract: the profiling flag is one relaxed load
    // per *job*; with it off the loop below is clock-free and the
    // profile is never touched. With it on, busy/wait accumulate in
    // locals and flush once at job end (see obs::profiler).
    let profiling = crate::obs::enabled();
    let mut busy_ns = 0u64;
    let mut wait_ns = 0u64;
    for step in 0..job.steps {
        let t0 = profiling.then(Instant::now);
        let mut vlane = lane;
        while vlane < job.width {
            match catch_unwind(AssertUnwindSafe(|| f(vlane, step))) {
                Ok(StepCtl::Continue) => {}
                Ok(StepCtl::Break) => shared.stop.store(true, Ordering::Release),
                Err(payload) => {
                    let mut slot =
                        shared.panic.lock().unwrap_or_else(PoisonError::into_inner);
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                    shared.stop.store(true, Ordering::Release);
                }
            }
            vlane += lanes;
        }
        if let Some(t0) = t0 {
            busy_ns += t0.elapsed().as_nanos() as u64;
            wait_ns += shared.barrier.wait_timed();
        } else {
            shared.barrier.wait();
        }
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
    }
    if profiling {
        shared.profile.record(lane, busy_ns, wait_ns);
    }
}
