//! Reusable epoch (generation) barrier for the lane engine.
//!
//! The per-step synchronization of an EBV elimination is the hottest
//! sync primitive in the system: one crossing per matrix column per
//! solve. `std::sync::Barrier` parks threads in the kernel on every
//! wait; at wire-traffic step rates (sub-microsecond steps on small
//! systems) the wakeup latency dominates the arithmetic. This barrier
//! spins first — lanes mid-factorization arrive within nanoseconds of
//! each other — and degrades to `yield_now` when the pool is
//! oversubscribed, so it stays correct (if slower) with more lanes than
//! cores.
//!
//! The design is the classic centralized sense-free barrier: a counter
//! of arrivals plus a monotonically increasing epoch. The last arrival
//! of a generation resets the counter and bumps the epoch with release
//! ordering; everyone else spins on the epoch with acquire ordering, so
//! every write sequenced before any lane's `wait` is visible to every
//! lane after it — exactly the `__syncthreads()` contract the paper's
//! kernel assumes.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// How many spin iterations a waiter burns before yielding its slice.
const SPIN_BUDGET: u32 = 1 << 14;

/// A reusable barrier for a fixed party count, tracking generation and
/// contention counters for the engine's stats surface.
#[derive(Debug)]
pub struct EpochBarrier {
    parties: usize,
    arrived: AtomicUsize,
    epoch: AtomicU64,
    /// Waits that exhausted the spin budget and fell back to yielding.
    slow_waits: AtomicU64,
}

impl EpochBarrier {
    /// Barrier for `parties` lanes (at least 1).
    pub fn new(parties: usize) -> EpochBarrier {
        assert!(parties > 0, "EpochBarrier: parties must be positive");
        EpochBarrier {
            parties,
            arrived: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            slow_waits: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn parties(&self) -> usize {
        self.parties
    }

    /// Completed generations since construction — with the engine
    /// protocol (every lane waits exactly once per step) this *is* the
    /// total number of barrier-separated steps executed.
    pub fn generations(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Total lane crossings. Derived — every generation is exactly
    /// `parties` crossings under the engine protocol — and kept here,
    /// next to the mechanism, so a future barrier change that breaks
    /// the identity has to change this accessor too.
    pub fn waits(&self) -> u64 {
        self.generations().saturating_mul(self.parties as u64)
    }

    /// Waits that outlived the spin budget (scheduler-contention signal).
    pub fn slow_waits(&self) -> u64 {
        self.slow_waits.load(Ordering::Relaxed)
    }

    /// Block until all `parties` lanes of the current generation arrive.
    ///
    /// Every lane must call `wait` exactly once per generation; the
    /// engine's job protocol guarantees this (all lanes execute the same
    /// number of steps and stop together — see `team::run_job`).
    pub fn wait(&self) {
        // Loading the epoch before registering arrival is safe: this
        // generation cannot complete (and the epoch cannot advance)
        // until our own increment lands.
        let epoch = self.epoch.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == self.parties {
            // Last arrival: open the next generation. The counter reset
            // must precede the epoch bump — waiters re-enter `wait` only
            // after observing the bump.
            self.arrived.store(0, Ordering::Relaxed);
            self.epoch.fetch_add(1, Ordering::Release);
            return;
        }
        let mut spins = 0u32;
        while self.epoch.load(Ordering::Acquire) == epoch {
            if spins < SPIN_BUDGET {
                std::hint::spin_loop();
                spins += 1;
            } else {
                if spins == SPIN_BUDGET {
                    self.slow_waits.fetch_add(1, Ordering::Relaxed);
                    spins += 1;
                }
                std::thread::yield_now();
            }
        }
    }

    /// [`wait`](EpochBarrier::wait), returning the nanoseconds this
    /// lane spent inside the crossing. Used by the obs profiler's
    /// busy/wait split; the untimed `wait` stays clock-free so the
    /// profiling-off hot path pays nothing.
    pub fn wait_timed(&self) -> u64 {
        let t0 = std::time::Instant::now();
        self.wait();
        t0.elapsed().as_nanos() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn single_party_never_blocks() {
        let b = EpochBarrier::new(1);
        for _ in 0..5 {
            b.wait();
        }
        assert_eq!(b.generations(), 5);
    }

    #[test]
    fn steps_are_separated_across_threads() {
        // Each thread increments a shared counter once per step; after
        // the step barrier the counter must be exactly `parties * step`.
        let parties = 4;
        let steps = 200;
        let barrier = Arc::new(EpochBarrier::new(parties));
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..parties)
            .map(|_| {
                let barrier = Arc::clone(&barrier);
                let counter = Arc::clone(&counter);
                std::thread::spawn(move || {
                    for step in 1..=steps {
                        counter.fetch_add(1, Ordering::Relaxed);
                        barrier.wait();
                        let seen = counter.load(Ordering::Relaxed);
                        assert!(
                            seen >= parties * step && seen <= parties * (step + 1) - 1,
                            "step {step}: counter {seen}"
                        );
                        barrier.wait();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("barrier thread");
        }
        assert_eq!(counter.load(Ordering::Relaxed), parties * steps);
        assert_eq!(barrier.generations(), 2 * steps as u64);
    }

    #[test]
    fn wait_timed_advances_the_generation() {
        let b = EpochBarrier::new(1);
        let ns = b.wait_timed();
        assert_eq!(b.generations(), 1);
        // Duration is whatever the clock says; only sanity-bound it.
        assert!(ns < 1_000_000_000, "{ns}");
    }

    #[test]
    fn generations_count_waits() {
        let b = Arc::new(EpochBarrier::new(2));
        let b2 = Arc::clone(&b);
        let t = std::thread::spawn(move || {
            for _ in 0..10 {
                b2.wait();
            }
        });
        for _ in 0..10 {
            b.wait();
        }
        t.join().expect("barrier peer");
        assert_eq!(b.generations(), 10);
    }
}
