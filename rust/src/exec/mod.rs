//! Persistent lane engine: one long-lived, barrier-stepped worker pool
//! under every parallel solve path.
//!
//! The paper's execution model is a fixed team of GPU threads marching
//! through elimination steps separated by `__syncthreads()`. The seed
//! code reproduced that shape faithfully *per call* — every
//! factorization and every parallel substitution spun up a fresh
//! `std::thread::scope` — which made thread creation the dominant fixed
//! cost once the wire protocol started serving repeat traffic. This
//! module keeps the team **resident**: lanes are spawned once, parked on
//! a condvar between jobs, and synchronized per step with a spin-first
//! [`EpochBarrier`] instead of being created and joined per solve.
//!
//! A job is a *step loop*: a closure over `(vlane, step)` executed for
//! `width` virtual lanes across `steps` barrier-separated steps (see
//! [`LaneEngine::run_steps`]). Virtual lanes let a schedule built for
//! any lane count run on a pool of any size with bit-identical results —
//! the arithmetic each row sees depends only on the row partition, never
//! on which OS thread executes it.
//!
//! The [`devices`] module lifts the same model one level up: a
//! [`DeviceSet`] partitions the machine into device groups (one engine
//! each) and runs device-sharded jobs with a staged exchange phase
//! between steps — the multi-device execution the paper's conclusion
//! claims, promoted from the `gpusim::cluster` cost model to a runtime.
//!
//! See `rust/DESIGN.md` §Execution engine and §Device layer for the
//! architecture notes and §Substitutions for the GPU→lane mapping this
//! realizes.

pub mod barrier;
pub mod dep;
pub mod devices;
pub mod engine;
pub mod stats;
pub mod team;

pub use barrier::EpochBarrier;
pub use dep::{run_dataflow, DepGraph, Schedule};
pub use devices::{DeviceSet, DeviceSetSnapshot, ExchangeBuffer};
pub use engine::{
    default_lanes, engine_or_global, global, DepStatsSnapshot, LaneEngine, StepCtl, StepFn,
};
pub use stats::{EngineStats, EngineStatsSnapshot};

/// Shared mutable slot array for engine jobs whose virtual lanes write
/// disjoint indices — the `SharedMatrix`/`SharedVec` raw-pointer idiom
/// from the solvers, generalized over the element type. The multi-RHS
/// panel solve holds one result slot per vlane; the sparse numeric
/// refactorization (`SparseSymbolic`) holds one dense accumulator per
/// vlane the same way.
pub struct LaneSlots<T> {
    ptr: *mut T,
    len: usize,
}

unsafe impl<T: Send> Send for LaneSlots<T> {}
unsafe impl<T: Send> Sync for LaneSlots<T> {}

impl<T> LaneSlots<T> {
    /// Wrap a slice whose slots will be written by distinct vlanes.
    pub fn new(xs: &mut [T]) -> LaneSlots<T> {
        LaneSlots { ptr: xs.as_mut_ptr(), len: xs.len() }
    }

    /// Mutable access to slot `i`.
    ///
    /// # Safety
    /// At most one vlane may touch slot `i` during a job, and the
    /// backing slice must outlive the job (guaranteed when the wrapper
    /// is created by the submitting frame — `run_steps` joins before
    /// returning).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slot(&self, i: usize) -> &mut T {
        assert!(i < self.len, "LaneSlots: index {i} out of {}", self.len);
        &mut *self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_slots_disjoint_writes_land() {
        let mut xs = vec![0usize; 8];
        let slots = LaneSlots::new(&mut xs);
        let engine = LaneEngine::new(2);
        engine.run_steps(8, 1, |vlane, _| {
            // SAFETY: each vlane writes only its own slot.
            unsafe { *slots.slot(vlane) = vlane + 1 };
            StepCtl::Continue
        });
        assert_eq!(xs, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn lane_slots_bound_checked() {
        let mut xs = vec![0u8; 2];
        let slots = LaneSlots::new(&mut xs);
        unsafe {
            *slots.slot(2) = 1;
        }
    }
}
