//! Engine observability: cheap atomic counters plus a detached snapshot
//! that travels in coordinator metrics and wire `metrics` frames.

use std::sync::atomic::{AtomicU64, Ordering};

/// Live engine counters (lock-free; updated once per job, not per step —
/// per-step accounting rides on the barrier's generation counter).
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Jobs executed on the resident pool.
    pub jobs: AtomicU64,
    /// Jobs short-circuited onto the calling thread (single-lane engine,
    /// width 1, or zero steps) — these never touch the barrier.
    pub inline_jobs: AtomicU64,
}

impl EngineStats {
    pub fn record_pooled_job(&self) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_inline_job(&self) {
        self.inline_jobs.fetch_add(1, Ordering::Relaxed);
    }
}

/// Point-in-time copy of the engine counters, detached from the atomics
/// so it can be merged into [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot)
/// and carried in wire frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EngineStatsSnapshot {
    /// Resident lanes (pool size including the submitting lane).
    pub lanes: u64,
    /// Barrier-stepped jobs run on the pool.
    pub jobs: u64,
    /// Jobs run inline on the caller.
    pub inline_jobs: u64,
    /// Barrier-separated steps across all pooled jobs.
    pub steps: u64,
    /// Lane-barrier crossings (`steps × lanes`).
    pub barrier_waits: u64,
    /// Barrier waits that fell out of the spin budget into yielding.
    pub slow_waits: u64,
    /// Summed per-lane compute nanoseconds from the obs profiler
    /// (zero unless the process ran with profiling on).
    pub busy_ns: u64,
    /// Summed per-lane barrier-wait nanoseconds from the obs profiler.
    pub wait_ns: u64,
    /// Jobs profiled into the busy/wait accumulators.
    pub profiled_jobs: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let s = EngineStats::default();
        s.record_pooled_job();
        s.record_pooled_job();
        s.record_inline_job();
        assert_eq!(s.jobs.load(Ordering::Relaxed), 2);
        assert_eq!(s.inline_jobs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn snapshot_is_plain_data() {
        let snap = EngineStatsSnapshot { lanes: 4, jobs: 7, ..Default::default() };
        let copy = snap;
        assert_eq!(copy, snap);
        assert_eq!(copy.jobs, 7);
    }
}
