//! [`DeviceSet`]: the two-level device-sharded runtime.
//!
//! The paper's conclusion claims the EBV scheme "also is convenient for
//! other parallelism method and multi devices". Until this layer the
//! repo only *simulated* that claim (`gpusim::cluster` prices a
//! pivot-row broadcast over an [`Interconnect`] cost model). A
//! `DeviceSet` makes it real: the machine is partitioned into `D`
//! device groups, each backed by its own resident [`LaneEngine`], and a
//! sharded job runs barrier-separated steps on **all** devices
//! concurrently with a staged exchange phase between steps — the
//! pivot-row broadcast the cost model prices, executed.
//!
//! One sharded step is a three-phase protocol:
//!
//! 1. **Exchange** — the host of device 0 runs the job's exchange
//!    closure once: stage the data every device will need this step
//!    (the pivot row) into an [`ExchangeBuffer`] and account the
//!    broadcast traffic. Single-writer by construction.
//! 2. **Cross-device barrier** — all `D` hosts cross an
//!    [`EpochBarrier`]; the staged writes (and every compute write of
//!    the previous step) are published to every device.
//! 3. **Compute** — each host submits a one-step job to its own
//!    engine: the step closure runs for every virtual lane of every
//!    device. A second barrier crossing closes the step and makes the
//!    devices' writes mutually visible before the next exchange.
//!
//! The stop protocol mirrors the engine's: any vlane (or the exchange
//! closure) returning [`StepCtl::Break`] sets a shared flag that every
//! host reads immediately after a barrier crossing, so all devices end
//! the job on the same step and the fixed-party barrier stays sound.
//!
//! **Bit identity.** Sharding changes *where* rows execute, never what
//! they compute: each row's arithmetic depends only on the schedule
//! decomposition (column order, panel decomposition, symbolic
//! pattern), and the staged pivot row is a bit-exact copy. A job
//! therefore produces identical bits for every device count — and
//! `devices = 1` never even enters this module: every solver path
//! falls through to its flat single-engine code. See `rust/DESIGN.md`
//! §Device layer and the bit-identity ledger.
//!
//! [`Interconnect`]: crate::gpusim::cluster::Interconnect

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::exec::barrier::EpochBarrier;
use crate::exec::engine::{LaneEngine, StepCtl};

/// Detached copy of the device-set counters, merged into
/// [`MetricsSnapshot`](crate::coordinator::MetricsSnapshot) and carried
/// in wire `metrics` frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DeviceSetSnapshot {
    /// Device groups in the set.
    pub devices: u64,
    /// Resident lanes per device engine.
    pub lanes_per_device: u64,
    /// Sharded jobs executed across the set.
    pub sharded_jobs: u64,
    /// Exchange stages executed (one per sharded step).
    pub exchange_steps: u64,
    /// `f64` elements staged or accounted through the exchange — the
    /// measured counterpart of the cost model's broadcast bytes
    /// (multiply by 8 for bytes).
    pub exchange_elems: u64,
    /// Summed busy nanoseconds across every device engine's lane
    /// profile (zero unless the process ran with profiling on).
    pub busy_ns: u64,
    /// Nanoseconds spent inside exchange closures (profiling on only).
    pub exchange_ns: u64,
}

/// A partition of the machine into `D` device groups, each a resident
/// [`LaneEngine`], plus the cross-device step barrier and exchange
/// accounting. Shared by the coordinator workers via [`Arc`], exactly
/// like a single engine.
pub struct DeviceSet {
    engines: Vec<Arc<LaneEngine>>,
    lanes_per_device: usize,
    sharded_jobs: AtomicU64,
    exchange_steps: AtomicU64,
    exchange_elems: AtomicU64,
    /// Time spent inside exchange closures; written only while the obs
    /// profiling flag is on.
    exchange_ns: AtomicU64,
}

impl std::fmt::Debug for DeviceSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceSet")
            .field("devices", &self.engines.len())
            .field("lanes_per_device", &self.lanes_per_device)
            .finish_non_exhaustive()
    }
}

impl DeviceSet {
    /// Build `devices` device groups with `lanes_per_device` resident
    /// lanes each (both clamped to at least 1).
    pub fn new(devices: usize, lanes_per_device: usize) -> DeviceSet {
        let devices = devices.max(1);
        let lanes_per_device = lanes_per_device.max(1);
        DeviceSet {
            engines: (0..devices).map(|_| Arc::new(LaneEngine::new(lanes_per_device))).collect(),
            lanes_per_device,
            sharded_jobs: AtomicU64::new(0),
            exchange_steps: AtomicU64::new(0),
            exchange_elems: AtomicU64::new(0),
            exchange_ns: AtomicU64::new(0),
        }
    }

    /// Wrap an existing engine as a single-device set, for callers
    /// holding only an engine who want to feed a `&DeviceSet`-shaped
    /// API (every sharded entry point falls through to flat execution
    /// on `engine(0)` when the set has one device). The solver paths
    /// themselves never need this — with `devices = 1` they keep
    /// their flat engine code directly.
    pub fn single(engine: Arc<LaneEngine>) -> DeviceSet {
        let lanes_per_device = engine.lanes();
        DeviceSet {
            engines: vec![engine],
            lanes_per_device,
            sharded_jobs: AtomicU64::new(0),
            exchange_steps: AtomicU64::new(0),
            exchange_elems: AtomicU64::new(0),
            exchange_ns: AtomicU64::new(0),
        }
    }

    /// Number of device groups.
    #[inline]
    pub fn devices(&self) -> usize {
        self.engines.len()
    }

    /// Resident lanes per device engine.
    #[inline]
    pub fn lanes_per_device(&self) -> usize {
        self.lanes_per_device
    }

    /// The engine backing device `d`.
    #[inline]
    pub fn engine(&self, d: usize) -> &Arc<LaneEngine> {
        &self.engines[d]
    }

    /// Account `elems` f64 elements of exchange traffic (staged pivot
    /// rows, broadcast panel blocks, level results). Called from
    /// exchange closures.
    #[inline]
    pub fn record_exchange(&self, elems: usize) {
        self.exchange_elems.fetch_add(elems as u64, Ordering::Relaxed);
    }

    /// Detached counters for metrics frames and logs.
    pub fn snapshot(&self) -> DeviceSetSnapshot {
        DeviceSetSnapshot {
            devices: self.engines.len() as u64,
            lanes_per_device: self.lanes_per_device as u64,
            sharded_jobs: self.sharded_jobs.load(Ordering::Relaxed),
            exchange_steps: self.exchange_steps.load(Ordering::Relaxed),
            exchange_elems: self.exchange_elems.load(Ordering::Relaxed),
            busy_ns: self
                .engines
                .iter()
                .map(|e| e.lane_profile().total_busy_ns())
                .sum(),
            exchange_ns: self.exchange_ns.load(Ordering::Relaxed),
        }
    }

    /// Measured max/mean imbalance of per-device busy time — the
    /// runtime counterpart of
    /// [`DevicePlan::device_imbalance`](crate::ebv::plan::DevicePlan),
    /// computed by the same statistic over the device engines' lane
    /// profiles. `1.0` when nothing was profiled.
    pub fn measured_imbalance(&self) -> f64 {
        let loads: Vec<usize> = self
            .engines
            .iter()
            .map(|e| e.lane_profile().total_busy_ns() as usize)
            .collect();
        crate::ebv::equalize::max_mean_imbalance(&loads)
    }

    /// Run a device-sharded step-loop job: for each of `steps` steps,
    /// the `exchange` closure runs once (on device 0's host, between
    /// cross-device barriers — the staged broadcast), then
    /// `f(device, vlane, step)` runs for every virtual lane in
    /// `0..width` on every device concurrently (each device's engine
    /// executes its own vlanes as a one-step engine job).
    ///
    /// Either closure returning [`StepCtl::Break`] ends the job for
    /// every device: an exchange break skips the step's compute phase
    /// entirely, a compute break finishes the current step everywhere
    /// first — both are observed unanimously through the cross-device
    /// barrier. Blocks until the job completes; closures may borrow
    /// from the caller's stack (the scoped hosts join before
    /// returning). Vlanes must write disjoint data within a step, and
    /// exchange must only touch data no device reads or writes during
    /// compute (the solvers guarantee both by row ownership).
    pub fn run_sharded<E, F>(&self, width: usize, steps: usize, exchange: E, f: F)
    where
        E: Fn(usize) -> StepCtl + Sync,
        F: Fn(usize, usize, usize) -> StepCtl + Sync,
    {
        if width == 0 || steps == 0 {
            return;
        }
        let d = self.engines.len();
        self.sharded_jobs.fetch_add(1, Ordering::Relaxed);
        let xbar = EpochBarrier::new(d);
        let stop = AtomicBool::new(false);
        let steps_done = AtomicU64::new(0);
        // Obs profiling: sampled once per sharded job; with it off the
        // exchange phase stays clock-free.
        let profiling = crate::obs::enabled();
        let exchange_ns = AtomicU64::new(0);

        let host = |dev: usize| {
            for step in 0..steps {
                // A panicking exchange closure must not skip the
                // barrier (the peers would spin on it forever): catch,
                // publish a unanimous stop, cross, then re-raise.
                let mut exchange_panic = None;
                if dev == 0 {
                    let t0 = profiling.then(std::time::Instant::now);
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        exchange(step)
                    })) {
                        // Counted only on Continue: a breaking exchange
                        // (singular pivot) staged nothing, and the
                        // snapshot's steps must pair with its elems.
                        Ok(StepCtl::Continue) => {
                            steps_done.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(StepCtl::Break) => stop.store(true, Ordering::Release),
                        Err(payload) => {
                            exchange_panic = Some(payload);
                            stop.store(true, Ordering::Release);
                        }
                    }
                    if let Some(t0) = t0 {
                        exchange_ns
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                }
                // Publishes the staged exchange (and the previous
                // step's compute writes) to every host; each host's
                // engine-job submission republishes to its lanes.
                xbar.wait();
                if let Some(payload) = exchange_panic {
                    std::panic::resume_unwind(payload);
                }
                if stop.load(Ordering::Acquire) {
                    break;
                }
                // A panicking step closure is caught per lane by the
                // device's own engine and re-raised on this host; catch
                // it here so the host still crosses the closing barrier
                // (the peers would spin on it forever otherwise), turn
                // it into a unanimous stop, and re-raise after.
                let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.engines[dev].run_steps(width, 1, |vlane, _| {
                        let ctl = f(dev, vlane, step);
                        if ctl == StepCtl::Break {
                            stop.store(true, Ordering::Release);
                        }
                        ctl
                    });
                }));
                if caught.is_err() {
                    stop.store(true, Ordering::Release);
                }
                // Closes the step: every device's writes become visible
                // before the next exchange, and a compute break (or
                // panic) is observed by all hosts at the same point.
                xbar.wait();
                if let Err(payload) = caught {
                    std::panic::resume_unwind(payload);
                }
                if stop.load(Ordering::Acquire) {
                    break;
                }
            }
        };

        if d == 1 {
            host(0);
        } else {
            std::thread::scope(|scope| {
                let host = &host;
                let handles: Vec<_> =
                    (1..d).map(|dev| scope.spawn(move || host(dev))).collect();
                // Run device 0 on the submitting thread; a panic here
                // unwinds into the scope, which joins the peers first
                // (they all saw the stop flag and exited their loops).
                host(0);
                // Re-raise the first peer panic on the submitter, like
                // the engine's own panic protocol.
                for h in handles {
                    if let Err(payload) = h.join() {
                        std::panic::resume_unwind(payload);
                    }
                }
            });
        }
        self.exchange_steps.fetch_add(steps_done.load(Ordering::Relaxed), Ordering::Relaxed);
        if profiling {
            self.exchange_ns
                .fetch_add(exchange_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }
}

/// Staging buffer for the per-step device exchange: written only by
/// the exchange closure (single host, between cross-device barriers),
/// read by every device during the following compute phase — the
/// broadcast payload of the step, realized as a bit-exact copy so
/// staging never perturbs the arithmetic.
pub struct ExchangeBuffer {
    ptr: *mut f64,
    len: usize,
}

unsafe impl Send for ExchangeBuffer {}
unsafe impl Sync for ExchangeBuffer {}

impl ExchangeBuffer {
    /// Wrap the backing storage (owned by the submitting frame, which
    /// outlives the sharded job — `run_sharded` joins before
    /// returning).
    pub fn new(buf: &mut [f64]) -> ExchangeBuffer {
        ExchangeBuffer { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// Copy `src` into the buffer at `offset`.
    ///
    /// # Safety
    /// Must only be called from an exchange closure (no device is
    /// reading or writing the buffer between the surrounding barriers).
    pub unsafe fn stage(&self, offset: usize, src: &[f64]) {
        assert!(
            offset + src.len() <= self.len,
            "ExchangeBuffer: stage of {} at {offset} exceeds {}",
            src.len(),
            self.len
        );
        std::ptr::copy_nonoverlapping(src.as_ptr(), self.ptr.add(offset), src.len());
    }

    /// Read the staged contents.
    ///
    /// # Safety
    /// Must only be called from a compute closure (the exchange writer
    /// is quiescent between the surrounding barriers).
    #[inline]
    pub unsafe fn staged(&self) -> &[f64] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_device_and_vlane_runs_every_step() {
        for devices in [1usize, 2, 3] {
            let set = DeviceSet::new(devices, 2);
            let width = 3;
            let steps = 4;
            let grid: Vec<Vec<Vec<AtomicUsize>>> = (0..steps)
                .map(|_| {
                    (0..devices)
                        .map(|_| (0..width).map(|_| AtomicUsize::new(0)).collect())
                        .collect()
                })
                .collect();
            let exchanges = AtomicUsize::new(0);
            set.run_sharded(
                width,
                steps,
                |_| {
                    exchanges.fetch_add(1, Ordering::Relaxed);
                    StepCtl::Continue
                },
                |dev, vlane, step| {
                    grid[step][dev][vlane].fetch_add(1, Ordering::Relaxed);
                    StepCtl::Continue
                },
            );
            assert_eq!(exchanges.load(Ordering::Relaxed), steps, "devices={devices}");
            for (step, per_dev) in grid.iter().enumerate() {
                for (dev, cells) in per_dev.iter().enumerate() {
                    for (vlane, cell) in cells.iter().enumerate() {
                        assert_eq!(
                            cell.load(Ordering::Relaxed),
                            1,
                            "devices={devices} step={step} dev={dev} vlane={vlane}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn exchange_publishes_across_devices() {
        // Device 1 reads, each step, a value staged by the exchange and
        // derived from what *device 0* wrote the previous step — only
        // correct if the double barrier publishes both directions.
        let set = DeviceSet::new(2, 1);
        let steps = 32;
        let mut produced = vec![0u64; steps + 1];
        let mut staged = vec![0.0f64; 1];
        let mut echoed = vec![0u64; steps];
        let produced_slots = crate::exec::LaneSlots::new(&mut produced);
        let stage = ExchangeBuffer::new(&mut staged);
        let echo_slots = crate::exec::LaneSlots::new(&mut echoed);
        set.run_sharded(
            1,
            steps,
            |step| {
                // SAFETY: exchange phase — sole accessor of the buffer;
                // produced[step] was written by device 0 a step ago.
                unsafe {
                    let prev = *produced_slots.slot(step);
                    stage.stage(0, &[prev as f64 + 1.0]);
                }
                StepCtl::Continue
            },
            |dev, _vlane, step| {
                if dev == 0 {
                    // SAFETY: single writer of produced[step + 1].
                    unsafe { *produced_slots.slot(step + 1) = step as u64 + 1 };
                } else {
                    // SAFETY: compute phase — the stage is read-only.
                    unsafe { *echo_slots.slot(step) = stage.staged()[0] as u64 };
                }
                StepCtl::Continue
            },
        );
        // produced[0] = 0 initially; device 0 wrote produced[s] = s at
        // step s-1 — so the exchange at step s staged s + 1.
        for (s, &e) in echoed.iter().enumerate() {
            assert_eq!(e, s as u64 + 1, "step {s}");
        }
    }

    #[test]
    fn break_stops_all_devices_on_the_same_step() {
        for devices in [1usize, 2, 4] {
            let set = DeviceSet::new(devices, 2);
            let steps = 6;
            let ran = AtomicUsize::new(0);
            set.run_sharded(
                2,
                steps,
                |_| StepCtl::Continue,
                |dev, vlane, step| {
                    ran.fetch_add(1, Ordering::Relaxed);
                    // One vlane on the last device sees the stop.
                    if dev == devices - 1 && vlane == 1 && step == 2 {
                        StepCtl::Break
                    } else {
                        StepCtl::Continue
                    }
                },
            );
            // Steps 0..=2 ran everywhere (the breaking step completes),
            // nothing after.
            assert_eq!(ran.load(Ordering::Relaxed), devices * 2 * 3, "devices={devices}");
        }
    }

    #[test]
    fn exchange_break_skips_the_step_compute() {
        let set = DeviceSet::new(2, 1);
        let ran = AtomicUsize::new(0);
        set.run_sharded(
            1,
            5,
            |step| if step == 3 { StepCtl::Break } else { StepCtl::Continue },
            |_, _, _| {
                ran.fetch_add(1, Ordering::Relaxed);
                StepCtl::Continue
            },
        );
        // Steps 0, 1, 2 computed on both devices; step 3's exchange
        // broke before compute.
        assert_eq!(ran.load(Ordering::Relaxed), 2 * 3);
    }

    #[test]
    fn snapshot_counts_jobs_steps_and_traffic() {
        let set = DeviceSet::new(2, 1);
        set.run_sharded(
            1,
            4,
            |_| {
                set.record_exchange(10);
                StepCtl::Continue
            },
            |_, _, _| StepCtl::Continue,
        );
        let s = set.snapshot();
        assert_eq!(s.devices, 2);
        assert_eq!(s.lanes_per_device, 1);
        assert_eq!(s.sharded_jobs, 1);
        assert_eq!(s.exchange_steps, 4);
        assert_eq!(s.exchange_elems, 40);
    }

    #[test]
    fn profiling_times_the_exchange_and_device_busy() {
        let _on = crate::obs::testhooks::Enabled::new();
        let set = DeviceSet::new(2, 2);
        set.run_sharded(
            2,
            8,
            |_| {
                // Make the exchange long enough to register on any clock.
                std::thread::sleep(std::time::Duration::from_micros(50));
                StepCtl::Continue
            },
            |_, _, _| StepCtl::Continue,
        );
        let s = set.snapshot();
        assert!(s.exchange_ns > 0, "timed exchange phases: {s:?}");
        // The device engines profiled their one-step compute jobs.
        assert!(set.engine(0).lane_profile().jobs >= 1);
        assert!(set.measured_imbalance() >= 1.0);
    }

    #[test]
    fn disabled_profiling_leaves_device_timers_zero() {
        let _g = crate::obs::testhooks::OBS_LOCK
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        crate::obs::set_enabled(false);
        let set = DeviceSet::new(2, 1);
        set.run_sharded(1, 3, |_| StepCtl::Continue, |_, _, _| StepCtl::Continue);
        let s = set.snapshot();
        assert_eq!(s.exchange_ns, 0);
        assert_eq!(s.busy_ns, 0);
        assert_eq!(set.measured_imbalance(), 1.0, "vacuous balance when unprofiled");
    }

    #[test]
    fn panicking_compute_propagates_and_set_survives() {
        let set = DeviceSet::new(2, 2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            set.run_sharded(
                2,
                4,
                |_| StepCtl::Continue,
                |dev, vlane, step| {
                    // The panic lands on a *peer* device's lane: it must
                    // cross back to the submitting thread, not hang the
                    // cross-device barrier.
                    if dev == 1 && vlane == 1 && step == 1 {
                        panic!("boom on a device");
                    }
                    StepCtl::Continue
                },
            );
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The set is intact: a subsequent job runs on every device.
        let ran = AtomicUsize::new(0);
        set.run_sharded(
            1,
            2,
            |_| StepCtl::Continue,
            |_, _, _| {
                ran.fetch_add(1, Ordering::Relaxed);
                StepCtl::Continue
            },
        );
        assert_eq!(ran.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn zero_work_is_a_no_op() {
        let set = DeviceSet::new(2, 2);
        set.run_sharded(0, 5, |_| panic!("no exchange"), |_, _, _| panic!("no compute"));
        set.run_sharded(5, 0, |_| panic!("no exchange"), |_, _, _| panic!("no compute"));
        assert_eq!(set.snapshot().sharded_jobs, 0);
    }

    #[test]
    fn single_wraps_an_existing_engine() {
        let engine = Arc::new(LaneEngine::new(3));
        let set = DeviceSet::single(Arc::clone(&engine));
        assert_eq!(set.devices(), 1);
        assert_eq!(set.lanes_per_device(), 3);
        assert!(Arc::ptr_eq(set.engine(0), &engine));
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn exchange_buffer_bounds_checked() {
        let mut buf = vec![0.0f64; 2];
        let stage = ExchangeBuffer::new(&mut buf);
        unsafe { stage.stage(1, &[1.0, 2.0]) };
    }
}
