//! Artifact manifest: what `python -m compile.aot` produced.
//!
//! `artifacts/manifest.json` lists every lowered program with its kind,
//! size, dtype and I/O shapes. The rust side never guesses shapes — it
//! validates every execution against this manifest.

use std::path::{Path, PathBuf};

use crate::util::error::{EbvError, Result};
use crate::util::json::Json;

/// What a compiled program computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Packed LU factorization of a dense system.
    LuFactor,
    /// Full solve: factorization + both substitutions.
    LuSolve,
    /// Batched solve: `k` right-hand sides.
    LuSolveBatched,
    /// Sparse matrix–vector product (ELL layout).
    Spmv,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "lu_factor" => Ok(ArtifactKind::LuFactor),
            "lu_solve" => Ok(ArtifactKind::LuSolve),
            "lu_solve_batched" => Ok(ArtifactKind::LuSolveBatched),
            "spmv" => Ok(ArtifactKind::Spmv),
            other => Err(EbvError::Runtime(format!("unknown artifact kind `{other}`"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::LuFactor => "lu_factor",
            ArtifactKind::LuSolve => "lu_solve",
            ArtifactKind::LuSolveBatched => "lu_solve_batched",
            ArtifactKind::Spmv => "spmv",
        }
    }
}

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    /// System size `n`.
    pub n: usize,
    /// Batch width (1 unless `LuSolveBatched`).
    pub batch: usize,
    pub dtype: String,
    /// Per-input element dtypes (`"f32"` / `"i32"`); defaults to all-f32
    /// when the manifest omits the field.
    pub input_dtypes: Vec<String>,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes, outermost-first.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<ArtifactEntry> {
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            v.require(key)?
                .as_arr()
                .ok_or_else(|| EbvError::Json(format!("{key} must be an array")))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .ok_or_else(|| EbvError::Json(format!("{key} entries must be arrays")))?
                        .iter()
                        .map(|d| {
                            d.as_usize()
                                .ok_or_else(|| EbvError::Json("bad shape dim".into()))
                        })
                        .collect()
                })
                .collect()
        };
        let inputs = shapes("inputs")?;
        let input_dtypes = match v.get("input_dtypes").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|d| {
                    d.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| EbvError::Json("input_dtypes entries must be strings".into()))
                })
                .collect::<Result<Vec<_>>>()?,
            None => vec!["f32".to_string(); inputs.len()],
        };
        Ok(ArtifactEntry {
            name: v
                .require("name")?
                .as_str()
                .ok_or_else(|| EbvError::Json("name must be a string".into()))?
                .to_string(),
            kind: ArtifactKind::parse(
                v.require("kind")?
                    .as_str()
                    .ok_or_else(|| EbvError::Json("kind must be a string".into()))?,
            )?,
            n: v.require("n")?.as_usize().ok_or_else(|| EbvError::Json("bad n".into()))?,
            batch: v.get("batch").and_then(Json::as_usize).unwrap_or(1),
            dtype: v
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
            input_dtypes,
            file: v
                .require("file")?
                .as_str()
                .ok_or_else(|| EbvError::Json("file must be a string".into()))?
                .to_string(),
            inputs,
            outputs: shapes("outputs")?,
        })
    }

    /// Total element count expected for input `i`.
    pub fn input_elems(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: usize,
    pub entries: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from (resolves `file` paths).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| EbvError::io(format!("read {}", path.display()), e))?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let version = v.require("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(EbvError::Runtime(format!("unsupported manifest version {version}")));
        }
        let entries = v
            .require("entries")?
            .as_arr()
            .ok_or_else(|| EbvError::Json("entries must be an array".into()))?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version, entries, dir: dir.to_path_buf() })
    }

    /// Find the entry for `kind` at size `n` (batch 1).
    pub fn find(&self, kind: ArtifactKind, n: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.kind == kind && e.n == n && e.batch == 1)
    }

    /// Find a batched entry covering `batch` right-hand sides.
    pub fn find_batched(&self, n: usize, batch: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::LuSolveBatched && e.n == n && e.batch >= batch)
            .min_by_key(|e| e.batch)
    }

    /// All sizes available for a kind.
    pub fn sizes(&self, kind: ArtifactKind) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.entries.iter().filter(|e| e.kind == kind).map(|e| e.n).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "lu_solve_n64", "kind": "lu_solve", "n": 64, "dtype": "f32",
         "file": "lu_solve_n64.hlo.txt",
         "inputs": [[64, 64], [64]], "outputs": [[64]]},
        {"name": "lu_solve_n64_b8", "kind": "lu_solve_batched", "n": 64, "batch": 8,
         "dtype": "f32", "file": "lu_solve_n64_b8.hlo.txt",
         "inputs": [[64, 64], [8, 64]], "outputs": [[8, 64]]},
        {"name": "lu_factor_n128", "kind": "lu_factor", "n": 128, "dtype": "f32",
         "file": "lu_factor_n128.hlo.txt",
         "inputs": [[128, 128]], "outputs": [[128, 128]]}
      ]
    }"#;

    #[test]
    fn parses_and_finds_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("arts")).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.find(ArtifactKind::LuSolve, 64).unwrap();
        assert_eq!(e.inputs, vec![vec![64, 64], vec![64]]);
        assert_eq!(e.input_elems(0), 4096);
        assert!(m.find(ArtifactKind::LuSolve, 32).is_none());
        assert_eq!(m.sizes(ArtifactKind::LuFactor), vec![128]);
        assert_eq!(
            m.path_of(e),
            Path::new("arts").join("lu_solve_n64.hlo.txt")
        );
    }

    #[test]
    fn batched_lookup_picks_smallest_cover() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let e = m.find_batched(64, 3).unwrap();
        assert_eq!(e.batch, 8);
        assert!(m.find_batched(64, 9).is_none());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"version": 2, "entries": []}"#, Path::new(".")).is_err());
        let bad_kind = r#"{"version": 1, "entries": [{"name": "x", "kind": "wat",
            "n": 4, "file": "f", "inputs": [], "outputs": []}]}"#;
        assert!(Manifest::parse(bad_kind, Path::new(".")).is_err());
    }

    #[test]
    fn kind_round_trips() {
        for k in [
            ArtifactKind::LuFactor,
            ArtifactKind::LuSolve,
            ArtifactKind::LuSolveBatched,
            ArtifactKind::Spmv,
        ] {
            assert_eq!(ArtifactKind::parse(k.as_str()).unwrap(), k);
        }
    }
}
