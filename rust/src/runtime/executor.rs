//! Dedicated runtime thread: owns the PJRT client and every compiled
//! executable, serves execution requests over channels.
//!
//! PJRT handles are not `Send`; confining them to one thread both
//! satisfies that constraint and models the single device context the
//! paper's GPU had. Callers hold a cheap, cloneable [`RuntimeHandle`].

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::runtime::artifacts::{ArtifactKind, Manifest};
use crate::runtime::pjrt::PjrtRuntime;
use crate::util::error::{EbvError, Result};

/// A request to the runtime thread.
enum Request {
    Execute {
        kind: ArtifactKind,
        n: usize,
        batch: usize,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    /// List available (kind, n) pairs.
    Capabilities {
        reply: mpsc::Sender<Vec<(ArtifactKind, usize, usize)>>,
    },
    Shutdown,
}

/// Execution counters, shared with callers.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub failures: u64,
    pub total_exec_secs: f64,
    pub compilations: u64,
}

/// Owner of the runtime thread: shuts it down on drop. Obtain cheap
/// per-worker clients with [`RuntimeHandle::client`].
pub struct RuntimeHandle {
    client: RuntimeClient,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable, `Send` client to the runtime thread.
#[derive(Clone)]
pub struct RuntimeClient {
    tx: mpsc::Sender<Request>,
    stats: Arc<Mutex<RuntimeStats>>,
}

impl RuntimeHandle {
    /// Spawn the runtime thread over the manifest in `dir`. Executables
    /// are compiled lazily on first use and cached.
    pub fn spawn(dir: PathBuf) -> Result<RuntimeHandle> {
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(RuntimeStats::default()));
        let thread_stats = Arc::clone(&stats);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let join = std::thread::Builder::new()
            .name("ebv-runtime".into())
            .spawn(move || runtime_main(manifest, rx, thread_stats, ready_tx))
            .map_err(|e| EbvError::Runtime(format!("spawn runtime thread: {e}")))?;

        // Wait for the client to come up (or fail fast).
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(RuntimeHandle {
                client: RuntimeClient { tx, stats },
                join: Some(join),
            }),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(EbvError::Runtime("runtime thread died during startup".into())),
        }
    }

    /// A cheap cloneable client for worker threads.
    pub fn client(&self) -> RuntimeClient {
        self.client.clone()
    }

    /// Execute the artifact of `kind` at size `n` (batch 1).
    pub fn execute(
        &self,
        kind: ArtifactKind,
        n: usize,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        self.client.execute_batched(kind, n, 1, inputs)
    }

    /// Execute a batched artifact covering `batch` RHS.
    pub fn execute_batched(
        &self,
        kind: ArtifactKind,
        n: usize,
        batch: usize,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        self.client.execute_batched(kind, n, batch, inputs)
    }

    /// Available `(kind, n, batch)` triples.
    pub fn capabilities(&self) -> Result<Vec<(ArtifactKind, usize, usize)>> {
        self.client.capabilities()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.client.stats()
    }
}

impl RuntimeClient {
    /// Execute the artifact of `kind` at size `n` (batch 1).
    pub fn execute(
        &self,
        kind: ArtifactKind,
        n: usize,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        self.execute_batched(kind, n, 1, inputs)
    }

    /// Execute a batched artifact covering `batch` RHS.
    pub fn execute_batched(
        &self,
        kind: ArtifactKind,
        n: usize,
        batch: usize,
        inputs: Vec<Vec<f32>>,
    ) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { kind, n, batch, inputs, reply })
            .map_err(|_| EbvError::Runtime("runtime thread is gone".into()))?;
        rx.recv().map_err(|_| EbvError::Runtime("runtime reply channel closed".into()))?
    }

    /// Available `(kind, n, batch)` triples.
    pub fn capabilities(&self) -> Result<Vec<(ArtifactKind, usize, usize)>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Capabilities { reply })
            .map_err(|_| EbvError::Runtime("runtime thread is gone".into()))?;
        rx.recv().map_err(|_| EbvError::Runtime("runtime reply channel closed".into()))
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().expect("stats poisoned").clone()
    }
}

impl Drop for RuntimeHandle {
    fn drop(&mut self) {
        let _ = self.client.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn runtime_main(
    manifest: Manifest,
    rx: mpsc::Receiver<Request>,
    stats: Arc<Mutex<RuntimeStats>>,
    ready: mpsc::Sender<Result<()>>,
) {
    let runtime = match PjrtRuntime::cpu() {
        Ok(r) => {
            let _ = ready.send(Ok(()));
            r
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    log::info!(target: "runtime", "PJRT client up on `{}`, {} artifacts", runtime.platform(), manifest.entries.len());

    // (kind, n, batch) -> compiled kernel, filled lazily.
    let mut cache: HashMap<(ArtifactKind, usize, usize), crate::runtime::pjrt::LoadedKernel> =
        HashMap::new();

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Capabilities { reply } => {
                let caps =
                    manifest.entries.iter().map(|e| (e.kind, e.n, e.batch)).collect::<Vec<_>>();
                let _ = reply.send(caps);
            }
            Request::Execute { kind, n, batch, inputs, reply } => {
                let result = execute_one(&runtime, &manifest, &mut cache, kind, n, batch, inputs, &stats);
                if result.is_err() {
                    stats.lock().expect("stats").failures += 1;
                }
                let _ = reply.send(result);
            }
        }
    }
    log::info!(target: "runtime", "runtime thread shutting down");
}

#[allow(clippy::too_many_arguments)]
fn execute_one(
    runtime: &PjrtRuntime,
    manifest: &Manifest,
    cache: &mut HashMap<(ArtifactKind, usize, usize), crate::runtime::pjrt::LoadedKernel>,
    kind: ArtifactKind,
    n: usize,
    batch: usize,
    inputs: Vec<Vec<f32>>,
    stats: &Arc<Mutex<RuntimeStats>>,
) -> Result<Vec<Vec<f32>>> {
    let entry = if batch == 1 {
        manifest.find(kind, n)
    } else {
        manifest.find_batched(n, batch)
    }
    .ok_or_else(|| {
        EbvError::Runtime(format!("no artifact for kind={} n={n} batch={batch}", kind.as_str()))
    })?
    .clone();

    let key = (entry.kind, entry.n, entry.batch);
    if !cache.contains_key(&key) {
        let t0 = Instant::now();
        let kernel = runtime.load(&entry, &manifest.path_of(&entry))?;
        log::info!(
            target: "runtime",
            "compiled `{}` in {:.1} ms",
            entry.name,
            t0.elapsed().as_secs_f64() * 1e3
        );
        stats.lock().expect("stats").compilations += 1;
        cache.insert(key, kernel);
    }
    let kernel = cache.get(&key).expect("just inserted");

    let t0 = Instant::now();
    let out = kernel.run_f32(&inputs)?;
    let dt = t0.elapsed().as_secs_f64();
    let mut s = stats.lock().expect("stats");
    s.executions += 1;
    s.total_exec_secs += dt;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_fails_cleanly_without_manifest() {
        let err = RuntimeHandle::spawn(PathBuf::from("/nonexistent-dir"));
        assert!(err.is_err());
    }
}
