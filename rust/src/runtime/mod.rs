//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from the rust hot path.
//!
//! Layering (see DESIGN.md): python lowers the L2 model once at build
//! time to HLO *text* (jax ≥ 0.5 emits serialized protos with 64-bit ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//! This module compiles that text on a `PjRtClient` and exposes typed
//! f32 execution.
//!
//! PJRT handles are not `Send`, so [`executor::RuntimeHandle`] confines
//! the client and all executables to one dedicated thread and serves
//! execution requests over channels — the same discipline a single GPU
//! context would impose.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::{ArtifactEntry, ArtifactKind, Manifest};
pub use executor::{RuntimeClient, RuntimeHandle, RuntimeStats};
pub use pjrt::{LoadedKernel, PjrtRuntime};
