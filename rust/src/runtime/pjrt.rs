//! Thin typed wrapper over the `xla` crate's PJRT client.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `compile` → `execute`. All programs are lowered with
//! `return_tuple=True`, so outputs are unpacked from a tuple literal.
//!
//! The real client needs the external `xla` crate, which cannot be
//! vendored offline; it is compiled only under the `pjrt` cargo
//! feature. Without it this module exposes the same API but
//! `PjrtRuntime::cpu()` fails with a descriptive error, which the
//! executor surfaces as "runtime unavailable" — the service then runs
//! every request on the native backends.

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;

    use crate::runtime::artifacts::ArtifactEntry;
    use crate::util::error::{EbvError, Result};

    /// A PJRT client (CPU platform in this environment).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<PjrtRuntime> {
            Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile one artifact.
        pub fn load(&self, entry: &ArtifactEntry, path: &Path) -> Result<LoadedKernel> {
            if !path.exists() {
                return Err(EbvError::Runtime(format!(
                    "artifact file missing: {} (run `make artifacts`)",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(LoadedKernel { entry: entry.clone(), exe })
        }
    }

    /// One compiled program plus its manifest entry (for shape checking).
    pub struct LoadedKernel {
        entry: ArtifactEntry,
        exe: xla::PjRtLoadedExecutable,
    }

    impl LoadedKernel {
        pub fn entry(&self) -> &ArtifactEntry {
            &self.entry
        }

        /// Execute with f32 inputs, validating shapes against the manifest.
        /// Returns the flattened f32 outputs in manifest order.
        pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            if inputs.len() != self.entry.inputs.len() {
                return Err(EbvError::Runtime(format!(
                    "{}: expected {} inputs, got {}",
                    self.entry.name,
                    self.entry.inputs.len(),
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, data) in inputs.iter().enumerate() {
                let want = self.entry.input_elems(i);
                if data.len() != want {
                    return Err(EbvError::Runtime(format!(
                        "{}: input {i} has {} elements, expected {want}",
                        self.entry.name,
                        data.len()
                    )));
                }
                let dims: Vec<i64> = self.entry.inputs[i].iter().map(|&d| d as i64).collect();
                // Integer inputs (e.g. the SpMV column-index array) arrive as
                // f32 host data and are converted per the manifest dtype.
                let lit = match self.entry.input_dtypes.get(i).map(String::as_str) {
                    Some("i32") => {
                        let ints: Vec<i32> = data.iter().map(|&v| v as i32).collect();
                        xla::Literal::vec1(&ints).reshape(&dims)?
                    }
                    _ => xla::Literal::vec1(data).reshape(&dims)?,
                };
                literals.push(lit);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let out_literal = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| EbvError::Runtime("empty execution result".into()))?
                .to_literal_sync()?;
            // Programs are lowered with return_tuple=True.
            let parts = out_literal.to_tuple()?;
            if parts.len() != self.entry.outputs.len() {
                return Err(EbvError::Runtime(format!(
                    "{}: got {} outputs, manifest says {}",
                    self.entry.name,
                    parts.len(),
                    self.entry.outputs.len()
                )));
            }
            parts.into_iter().map(|p| p.to_vec::<f32>().map_err(Into::into)).collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use crate::runtime::artifacts::ArtifactEntry;
    use crate::util::error::{EbvError, Result};

    fn unavailable() -> EbvError {
        EbvError::Runtime(
            "PJRT support not compiled in (build with `--features pjrt` and the `xla` crate)"
                .into(),
        )
    }

    /// Stub PJRT client: construction always fails, so callers take the
    /// native fallback paths.
    pub struct PjrtRuntime {
        _priv: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<PjrtRuntime> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, _entry: &ArtifactEntry, _path: &Path) -> Result<LoadedKernel> {
            Err(unavailable())
        }
    }

    /// Stub compiled program; never constructed.
    pub struct LoadedKernel {
        entry: ArtifactEntry,
    }

    impl LoadedKernel {
        pub fn entry(&self) -> &ArtifactEntry {
            &self.entry
        }

        pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            Err(unavailable())
        }
    }
}

pub use imp::{LoadedKernel, PjrtRuntime};

// Tests for this module live in `rust/tests/runtime_integration.rs`
// because they need real artifacts produced by `make artifacts`.

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_with_descriptive_error() {
        let err = PjrtRuntime::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
