//! `ebv-solve` binary: CLI front-end over the library.
//!
//! Subcommands: `solve`, `serve`, `metrics`, `tables`, `schedule`,
//! `info` — see `ebv_solve::cli::USAGE`.

use std::sync::Arc;
use std::time::Instant;

use ebv_solve::cli::{Args, USAGE};
use ebv_solve::config::ServiceConfig;
use ebv_solve::coordinator::SolverService;
use ebv_solve::ebv::schedule::{LaneSchedule, RowDist};
use ebv_solve::ebv::{bivectorize, equalize, imbalance, PairingMode};
use ebv_solve::gpusim::{
    simulate_cpu_dense, simulate_cpu_sparse, simulate_gpu_dense, simulate_gpu_sparse, CpuModel,
    GpuModel,
};
use ebv_solve::matrix::generate::{
    diag_dominant_dense, diag_dominant_sparse, poisson_2d, rhs, GenSeed,
};
use ebv_solve::exec::{DeviceSet, Schedule};
use ebv_solve::runtime::Manifest;
use ebv_solve::solver::{solver_by_name, EbvLu, Kernel, LuSolver, SparseLu, SparseSymbolic};
use ebv_solve::util::fmt;
use ebv_solve::wire::{
    install_sigint_handler, serve_session_with, DecodeOptions, ListenOptions, SessionOptions,
    WireServer,
};
use ebv_solve::workload::{generate_trace, SystemKind, TraceSpec};

fn main() {
    ebv_solve::util::logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        "tables" => cmd_tables(&args),
        "schedule" => cmd_schedule(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse `--kernel` into a [`Kernel`] (absent = `auto`: the
/// `EBV_KERNEL` env override or the tiled default at dispatch time).
fn kernel_arg(args: &Args) -> ebv_solve::Result<Kernel> {
    match args.opt("kernel") {
        None => Ok(Kernel::Auto),
        Some(name) => Kernel::parse(name).ok_or_else(|| {
            ebv_solve::EbvError::Config(format!(
                "--kernel: unknown kernel `{name}` (expected auto|unroll4|unroll8|tiled)"
            ))
        }),
    }
}

/// Parse `--schedule` into a [`Schedule`] (absent = `barrier`, the
/// epoch-stepped default; `dataflow` swaps in the dependency-counted
/// lane scheduler — bitwise-identical results either way).
fn schedule_arg(args: &Args) -> ebv_solve::Result<Schedule> {
    match args.opt("schedule") {
        None => Ok(Schedule::Barrier),
        Some(name) => Schedule::parse(name).ok_or_else(|| {
            ebv_solve::EbvError::Config(format!(
                "--schedule: unknown schedule `{name}` (expected barrier|dataflow)"
            ))
        }),
    }
}

fn cmd_solve(args: &Args) -> ebv_solve::Result<()> {
    if args.flag("profile") {
        return cmd_solve_profiled(args);
    }
    if args.flag("binary") {
        return cmd_solve_binary(args);
    }
    let n = args.opt_parsed("n", 512usize)?;
    let seed = args.opt_parsed("seed", 7u64)?;
    let kind = args.opt("kind").unwrap_or("dense");
    let lanes = args.opt_positive("lanes", ebv_solve::exec::default_lanes())?;
    let panel = args.opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?;
    let devices = args.opt_positive("devices", 1usize)?;
    let kernel = kernel_arg(args)?;
    let schedule = schedule_arg(args)?;
    // Two-level sharded runtime: split the lane budget across devices.
    let device_set = (devices > 1)
        .then(|| Arc::new(DeviceSet::new(devices, lanes.div_ceil(devices).max(1))));
    let solver_name = args.opt("solver").unwrap_or("ebv");

    match kind {
        "dense" => {
            let a = diag_dominant_dense(n, GenSeed(seed));
            let b = rhs(n, GenSeed(seed ^ 1));
            if let Some(set) = &device_set {
                if solver_name != "ebv" {
                    return Err(ebv_solve::EbvError::Config(
                        "--devices > 1 requires --solver ebv (the sharded path)".into(),
                    ));
                }
                // Asking for devices forces the sharded path even below
                // the sequential crossover, so the exchange summary
                // printed below always reflects a real sharded run.
                let solver = EbvLu::with_lanes(lanes)
                    .panel(panel)
                    .kernel(kernel)
                    .seq_threshold(0)
                    .with_devices(Arc::clone(set));
                let t0 = Instant::now();
                let x = solver.solve(&a, &b)?;
                let dt = t0.elapsed().as_secs_f64();
                let snap = set.snapshot();
                println!(
                    "dense n={n} solver=ebv lanes={lanes} devices={devices}: {} \
                     (residual {:.3e}; exchange {} elems over {} steps)",
                    fmt::secs(dt),
                    a.residual(&x, &b),
                    snap.exchange_elems,
                    snap.exchange_steps
                );
            } else {
                let solver = solver_by_name(solver_name, lanes, panel, kernel, schedule)
                    .ok_or_else(|| {
                        ebv_solve::EbvError::Config(format!("unknown solver `{solver_name}`"))
                    })?;
                let t0 = Instant::now();
                let x = solver.solve(&a, &b)?;
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "dense n={n} solver={} lanes={lanes}: {} (residual {:.3e})",
                    solver.name(),
                    fmt::secs(dt),
                    a.residual(&x, &b)
                );
            }
        }
        "sparse" | "poisson" => {
            let a = if kind == "sparse" {
                diag_dominant_sparse(n, 5, GenSeed(seed))
            } else {
                let g = (n as f64).sqrt().round().max(2.0) as usize;
                poisson_2d(g)
            };
            let b = rhs(a.rows(), GenSeed(seed ^ 1));
            if args.opt_parsed("sparse-parallel", true)? {
                // Symbolic/numeric split: the one-time pattern analysis
                // and the per-values refactorization are separate costs
                // — the second is what repeat same-pattern traffic pays.
                let t0 = Instant::now();
                let sym = SparseSymbolic::analyze(&a)?.with_kernel(kernel).with_schedule(schedule);
                let t_sym = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let f = match &device_set {
                    Some(set) => sym.factor_sharded(&a, lanes, set.as_ref())?,
                    None => sym.factor_par(&a, lanes)?,
                };
                let t_num = t1.elapsed().as_secs_f64();
                let t2 = Instant::now();
                let x = match &device_set {
                    Some(set) => f.solve_sharded(&b, lanes, set.as_ref())?,
                    None => f.solve_par(&b, lanes)?,
                };
                let t_solve = t2.elapsed().as_secs_f64();
                println!(
                    "{kind} n={} nnz={} factor-levels={}: symbolic {} + numeric {} + \
                     solve {} (residual {:.3e})",
                    a.rows(),
                    a.nnz(),
                    sym.level_count(),
                    fmt::secs(t_sym),
                    fmt::secs(t_num),
                    fmt::secs(t_solve),
                    a.residual(&x, &b)
                );
            } else {
                let t0 = Instant::now();
                let f = SparseLu::new().factor(&a)?;
                let t_factor = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                let x = f.solve_par(&b, lanes)?;
                let t_solve = t1.elapsed().as_secs_f64();
                println!(
                    "{kind} n={} nnz={} levels={}: factor {} + solve {} (residual {:.3e})",
                    a.rows(),
                    a.nnz(),
                    f.level_count(),
                    fmt::secs(t_factor),
                    fmt::secs(t_solve),
                    a.residual(&x, &b)
                );
            }
        }
        other => {
            return Err(ebv_solve::EbvError::Config(format!("unknown kind `{other}`")));
        }
    }
    Ok(())
}

/// `solve --binary`: drive a complete negotiated wire session in
/// process — an NDJSON solve carrying the `accept_binary` offer, the
/// same matrix again as a length-prefixed binary frame (fresh RHS, so
/// the second solve rides the factor cache), `metrics`, `shutdown` —
/// then decode the mixed response stream and report what the binary
/// encoding saves on the payload-heavy frames. Doubles as the
/// end-to-end binary exercise the CI smoke leg runs.
fn cmd_solve_binary(args: &Args) -> ebv_solve::Result<()> {
    use ebv_solve::wire::{
        binary, encode_request, encode_request_negotiating, encode_response, RequestFrame,
        ResponseFrame, WireSolve,
    };

    let n = args.opt_parsed("n", 512usize)?;
    let seed = args.opt_parsed("seed", 7u64)?;
    let kind = args.opt("kind").unwrap_or("dense");
    let lanes = args.opt_positive("lanes", ebv_solve::exec::default_lanes())?;
    let cfg = ServiceConfig {
        lanes,
        engine_lanes: lanes,
        panel_width: args.opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?,
        kernel: kernel_arg(args)?,
        schedule: schedule_arg(args)?,
        sparse_parallel: args.opt_parsed("sparse-parallel", true)?,
        ..ServiceConfig::default()
    };
    let svc = SolverService::start(cfg)?;

    // Same matrix twice with fresh right-hand sides: the NDJSON frame
    // offers `accept_binary`, the repeat travels binary and must hit
    // the factor cache (identical content fingerprint, so identical
    // `matrix_key` in both replies).
    let (req1, req2) = match kind {
        "dense" => {
            let a = diag_dominant_dense(n, GenSeed(seed));
            let b1 = rhs(n, GenSeed(seed ^ 1));
            let b2 = rhs(n, GenSeed(seed ^ 2));
            (
                RequestFrame::Solve(WireSolve::dense(a.clone(), b1).with_id(1)),
                RequestFrame::Solve(WireSolve::dense(a, b2).with_id(2)),
            )
        }
        "sparse" | "poisson" => {
            let a = if kind == "sparse" {
                diag_dominant_sparse(n, 5, GenSeed(seed))
            } else {
                let g = (n as f64).sqrt().round().max(2.0) as usize;
                poisson_2d(g)
            };
            let b1 = rhs(a.rows(), GenSeed(seed ^ 1));
            let b2 = rhs(a.rows(), GenSeed(seed ^ 2));
            (
                RequestFrame::SolveSparse(WireSolve::sparse(a.clone(), b1).with_id(1)),
                RequestFrame::SolveSparse(WireSolve::sparse(a, b2).with_id(2)),
            )
        }
        other => {
            return Err(ebv_solve::EbvError::Config(format!("unknown kind `{other}`")));
        }
    };

    let req_ndjson_len = encode_request(&req2).len() + 1;
    let req_binary = binary::encode_request_binary(&req2)?;
    let req_binary_len = req_binary.len();

    let mut input = Vec::new();
    input.extend_from_slice(encode_request_negotiating(&req1).as_bytes());
    input.push(b'\n');
    input.extend_from_slice(&req_binary);
    input.extend_from_slice(encode_request(&RequestFrame::Metrics).as_bytes());
    input.push(b'\n');
    input.extend_from_slice(encode_request(&RequestFrame::Shutdown).as_bytes());
    input.push(b'\n');

    let t0 = Instant::now();
    let mut out = Vec::new();
    let stats = serve_session_with(&svc, &input[..], &mut out, SessionOptions::default())?;
    let wall = t0.elapsed().as_secs_f64();

    let frames = binary::decode_response_stream(&out)?;
    let mut solutions = Vec::new();
    let mut binary_sessions = 0u64;
    for (frame, _ext) in &frames {
        match frame {
            ResponseFrame::Solution(s) => match &s.result {
                Ok(_) => solutions.push(s.clone()),
                Err(e) => {
                    return Err(ebv_solve::EbvError::Runtime(format!(
                        "solve {} failed on the wire: {e}",
                        s.id
                    )));
                }
            },
            ResponseFrame::Metrics(m) => binary_sessions = m.binary_sessions,
            ResponseFrame::Error { code, message } => {
                return Err(ebv_solve::EbvError::Runtime(format!(
                    "wire session answered `{}`: {message}",
                    code.name()
                )));
            }
            ResponseFrame::Goodbye { .. } => {}
        }
    }
    let [s1, s2] = &solutions[..] else {
        return Err(ebv_solve::EbvError::Runtime(format!(
            "expected 2 solutions, got {}",
            solutions.len()
        )));
    };
    if binary_sessions != 1 {
        return Err(ebv_solve::EbvError::Runtime(format!(
            "metrics report {binary_sessions} binary sessions, expected 1"
        )));
    }
    if s1.matrix_key != s2.matrix_key || s1.matrix_key.is_none() {
        return Err(ebv_solve::EbvError::Runtime(format!(
            "fingerprint keys disagree across encodings: {:?} vs {:?}",
            s1.matrix_key, s2.matrix_key
        )));
    }

    let sol_ndjson_len = encode_response(&ResponseFrame::Solution(s2.clone())).len() + 1;
    let sol_binary_len = binary::encode_solution_binary(s2)?.len();
    println!(
        "{kind} n={n} --binary: negotiated session ok in {} \
         (2 solves, residuals {:.3e} / {:.3e}, shared matrix_key)",
        fmt::secs(wall),
        s1.residual,
        s2.residual
    );
    println!(
        "  solve request:  {} NDJSON -> {} binary ({:.1}x smaller)",
        fmt::bytes(req_ndjson_len as u64),
        fmt::bytes(req_binary_len as u64),
        req_ndjson_len as f64 / req_binary_len as f64
    );
    println!(
        "  solution frame: {} NDJSON -> {} binary ({:.1}x smaller)",
        fmt::bytes(sol_ndjson_len as u64),
        fmt::bytes(sol_binary_len as u64),
        sol_ndjson_len as f64 / sol_binary_len as f64
    );
    println!(
        "  session: {} frames, bytes_in={} bytes_out={}",
        stats.frames,
        fmt::bytes(stats.bytes_in),
        fmt::bytes(stats.bytes_out)
    );
    svc.shutdown();
    Ok(())
}

/// `solve --profile`: run the solve through an in-process service with
/// the obs subsystem on, then print the span timeline and the measured
/// imbalance next to the plan's predicted imbalance. The main thread
/// contributes the `ingest` (system build) and `encode` (report
/// formatting) spans; the worker thread contributes the solve phases
/// via the response trace.
fn cmd_solve_profiled(args: &Args) -> ebv_solve::Result<()> {
    use ebv_solve::ebv::plan::FactorPlan;
    use ebv_solve::obs::{self, Phase, SpanTimer};

    let n = args.opt_parsed("n", 512usize)?;
    let seed = args.opt_parsed("seed", 7u64)?;
    let kind = args.opt("kind").unwrap_or("dense");
    let lanes = args.opt_positive("lanes", ebv_solve::exec::default_lanes())?;
    let panel = args.opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?;
    let devices = args.opt_positive("devices", 1usize)?;
    let cfg = ServiceConfig {
        lanes,
        engine_lanes: lanes,
        devices,
        panel_width: panel,
        kernel: kernel_arg(args)?,
        schedule: schedule_arg(args)?,
        sparse_parallel: args.opt_parsed("sparse-parallel", true)?,
        profiling: true,
        ..ServiceConfig::default()
    };
    let dist = cfg.dist;
    let svc = SolverService::start(cfg)?;
    let _ = obs::take_thread_spans();
    let t0 = Instant::now();

    let (resp, rows, predicted) = match kind {
        "dense" => {
            let (a, b) = {
                let _t = SpanTimer::start(Phase::Ingest);
                (diag_dominant_dense(n, GenSeed(seed)), rhs(n, GenSeed(seed ^ 1)))
            };
            let schedule = LaneSchedule::build(n, lanes, dist);
            let predicted = FactorPlan::dense_blocked(n, panel, &schedule).lane_imbalance();
            (svc.solve_dense_blocking(Arc::new(a), b, Some(seed))?, n, predicted)
        }
        "sparse" | "poisson" => {
            let (a, b) = {
                let _t = SpanTimer::start(Phase::Ingest);
                let a = if kind == "sparse" {
                    diag_dominant_sparse(n, 5, GenSeed(seed))
                } else {
                    let g = (n as f64).sqrt().round().max(2.0) as usize;
                    poisson_2d(g)
                };
                let b = rhs(a.rows(), GenSeed(seed ^ 1));
                (a, b)
            };
            let rows = a.rows();
            // Sparse elimination has no dense FactorPlan; the planned
            // split is the schedule's lane-work statistic (same
            // max/mean formula).
            let predicted = LaneSchedule::build(rows, lanes, dist).work_imbalance();
            (svc.solve_sparse_blocking(Arc::new(a), b, Some(seed))?, rows, predicted)
        }
        other => {
            return Err(ebv_solve::EbvError::Config(format!("unknown kind `{other}`")));
        }
    };
    let wall = t0.elapsed().as_secs_f64();
    if let Err(e) = &resp.result {
        return Err(ebv_solve::EbvError::Runtime(format!("profiled solve failed: {e}")));
    }

    let report = {
        let _t = SpanTimer::start(Phase::Encode);
        format!(
            "{kind} n={rows} lanes={lanes} devices={devices} backend={}: {} (residual {:.3e})",
            resp.backend,
            fmt::secs(wall),
            resp.residual
        )
    };
    let mut trace = resp.trace.clone().unwrap_or_default();
    trace.merge(obs::take_thread_spans());
    println!("{report}");
    print!("{}", trace.render_timeline());
    let traced = trace.total_ns() as f64 / 1e9;
    println!(
        "spans cover {} of {} wall ({:.0}%)",
        fmt::secs(traced),
        fmt::secs(wall),
        100.0 * traced / wall.max(1e-12)
    );

    let snap = svc.metrics_snapshot();
    println!(
        "lane imbalance: predicted {predicted:.4} (plan) vs measured {:.4} \
         (busy {:.2} ms, barrier wait {:.2} ms, {} profiled jobs)",
        snap.measured_imbalance,
        snap.busy_ns as f64 / 1e6,
        snap.wait_ns as f64 / 1e6,
        snap.profiled_jobs
    );
    if devices > 1 {
        let sched =
            LaneSchedule::build_sharded(rows, devices, lanes.div_ceil(devices).max(1), dist);
        let dplan = FactorPlan::multi_device(rows, &sched);
        println!(
            "device imbalance: predicted {:.4} (DevicePlan) vs measured {:.4} \
             (device busy {:.2} ms, exchange {:.2} ms)",
            dplan.device_imbalance(),
            snap.device_measured_imbalance,
            snap.device_busy_ns as f64 / 1e6,
            snap.exchange_ns as f64 / 1e6
        );
    }
    if let Some(path) = args.opt("events") {
        let log = obs::EventLog::open(std::path::Path::new(path))?;
        log.append(&trace.to_json())?;
        println!("trace appended to {path}");
    }
    eprintln!("{}", obs::summary_line(&snap));
    svc.shutdown();
    Ok(())
}

/// `ebv-solve metrics`: run probe solves on an in-process profiled
/// service and print the Prometheus-style text exposition on stdout.
fn cmd_metrics(args: &Args) -> ebv_solve::Result<()> {
    let n = args.opt_parsed("n", 192usize)?;
    let seed = args.opt_parsed("seed", 7u64)?;
    let lanes = args.opt_positive("lanes", ebv_solve::exec::default_lanes())?;
    let cfg = ServiceConfig {
        lanes,
        engine_lanes: lanes,
        devices: args.opt_positive("devices", 1usize)?,
        panel_width: args.opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?,
        kernel: kernel_arg(args)?,
        schedule: schedule_arg(args)?,
        sparse_parallel: args.opt_parsed("sparse-parallel", true)?,
        profiling: !args.flag("no-profile"),
        ..ServiceConfig::default()
    };
    let svc = SolverService::start(cfg)?;
    let probes = args.opt_parsed("probes", 2usize)?;
    for i in 0..probes as u64 {
        let a = diag_dominant_dense(n, GenSeed(seed + i));
        let b = rhs(n, GenSeed(seed ^ 1));
        svc.solve_dense_blocking(Arc::new(a), b, Some(i))?;
        let s = diag_dominant_sparse(n, 5, GenSeed(seed + i));
        let b = rhs(s.rows(), GenSeed(seed ^ 2));
        svc.solve_sparse_blocking(Arc::new(s), b, Some(1000 + i))?;
    }
    let snap = svc.metrics_snapshot();
    print!("{}", ebv_solve::obs::prometheus(&snap));
    if let Some(path) = args.opt("events") {
        use ebv_solve::util::json::Json;
        let log = ebv_solve::obs::EventLog::open(std::path::Path::new(path))?;
        log.append(&Json::obj([
            ("event", Json::Str("metrics".into())),
            ("completed", Json::Num(snap.completed as f64)),
            ("failed", Json::Num(snap.failed as f64)),
            ("dense_solves", Json::Num(snap.dense_solves as f64)),
            ("sparse_solves", Json::Num(snap.sparse_solves as f64)),
            ("busy_ns", Json::Num(snap.busy_ns as f64)),
            ("wait_ns", Json::Num(snap.wait_ns as f64)),
            ("exchange_ns", Json::Num(snap.exchange_ns as f64)),
            ("measured_imbalance", Json::Num(snap.measured_imbalance)),
            ("device_measured_imbalance", Json::Num(snap.device_measured_imbalance)),
        ]))?;
        eprintln!("metrics event appended to {path}");
    }
    eprintln!("{}", ebv_solve::obs::summary_line(&snap));
    svc.shutdown();
    Ok(())
}

fn cmd_serve(args: &Args) -> ebv_solve::Result<()> {
    if args.flag("trace") {
        return cmd_serve_trace(args);
    }
    // Default: the NDJSON wire session on stdin/stdout; `--listen`
    // switches to the concurrent TCP front end. Diagnostics go to
    // stderr so stdout stays a clean frame stream.
    let cfg = ServiceConfig {
        lanes: args.opt_positive("lanes", 4usize)?,
        max_batch: args.opt_parsed("batch", 16usize)?,
        batch_window_us: args.opt_parsed("window-us", 200u64)?,
        queue_capacity: args.opt_parsed("queue", 1024usize)?,
        // Explicit `--engine-lanes 0` is rejected; omitting the flag
        // keeps the zero sentinel (auto = all cores).
        engine_lanes: args.opt_positive("engine-lanes", 0usize)?,
        devices: args.opt_positive("devices", 1usize)?,
        panel_width: args
            .opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?,
        kernel: kernel_arg(args)?,
        schedule: schedule_arg(args)?,
        sparse_parallel: args.opt_parsed("sparse-parallel", true)?,
        use_runtime: args.flag("runtime"),
        max_sessions: args.opt_positive("max-sessions", 8usize)?,
        deadline_ms: args.opt_parsed("deadline-ms", 0u64)?,
        profiling: args.flag("profile"),
        ..ServiceConfig::default()
    };
    let listen = args.opt("listen").map(str::to_string);
    // 64 MiB default line cap on TCP (a hostile peer must not OOM the
    // server); stdio trusts its pipe and stays unlimited.
    let default_frame_cap: usize = if listen.is_some() { 64 << 20 } else { usize::MAX };
    let max_frame_bytes = match args.opt_positive("max-frame-bytes", default_frame_cap)? {
        usize::MAX => None,
        cap => Some(cap),
    };
    let deadline = match cfg.deadline_ms {
        0 => None,
        ms => Some(std::time::Duration::from_millis(ms)),
    };
    let max_sessions = cfg.max_sessions;
    let svc = SolverService::start(cfg)?;
    let session = SessionOptions {
        decode: DecodeOptions { allow_mtx_path: args.flag("allow-mtx-path") },
        deadline,
        max_frame_bytes,
        ..SessionOptions::default()
    };
    let stats = if let Some(addr) = listen {
        install_sigint_handler();
        let server = WireServer::bind(
            addr.as_str(),
            ListenOptions { max_sessions, watch_sigint: true, session },
        )?;
        eprintln!(
            "ebv-solve serve: listening on {} (max_sessions={max_sessions}; \
             SIGINT drains)",
            server.local_addr()?
        );
        let listener_stats = server.run(&svc)?;
        eprintln!(
            "listener done: {} sessions served, {} shed",
            listener_stats.sessions, listener_stats.shed
        );
        None
    } else {
        eprintln!(
            "ebv-solve serve: NDJSON wire session on stdin/stdout \
             (send {{\"op\":\"shutdown\"}} or EOF to end)"
        );
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        Some(serve_session_with(&svc, stdin.lock(), stdout.lock(), session)?)
    };
    if let Some(stats) = stats {
        eprintln!(
            "session done: {} frames, {} solves, {} errors, {} in, {} out",
            stats.frames,
            stats.solves,
            stats.errors,
            fmt::bytes(stats.bytes_in),
            fmt::bytes(stats.bytes_out)
        );
    }
    let snap = svc.metrics_snapshot();
    eprintln!(
        "sessions: total={} peak={} shed={} binary={} wire_frames={} wire_solves={} \
         wire_errors={} bytes_in={} bytes_out={}",
        snap.sessions_total,
        snap.peak_sessions,
        snap.sessions_shed,
        snap.binary_sessions,
        snap.wire_frames,
        snap.wire_solves,
        snap.wire_errors,
        snap.wire_bytes_in,
        snap.wire_bytes_out
    );
    eprintln!("metrics: {}", svc.metrics().summary());
    let e = svc.engine().stats();
    eprintln!(
        "engine: lanes={} jobs={} inline_jobs={} steps={} barrier_waits={} slow_waits={}",
        e.lanes, e.jobs, e.inline_jobs, e.steps, e.barrier_waits, e.slow_waits
    );
    if let Some(set) = svc.device_set() {
        let d = set.snapshot();
        eprintln!(
            "devices: {}x{} lanes, sharded_jobs={} exchange_steps={} exchange_elems={}",
            d.devices, d.lanes_per_device, d.sharded_jobs, d.exchange_steps, d.exchange_elems
        );
    }
    svc.shutdown();
    Ok(())
}

fn cmd_serve_trace(args: &Args) -> ebv_solve::Result<()> {
    let requests = args.opt_parsed("requests", 200usize)?;
    let rate = args.opt_parsed("rate", 500.0f64)?;
    let lanes = args.opt_positive("lanes", 4usize)?;
    let batch = args.opt_parsed("batch", 8usize)?;
    let cfg = ServiceConfig {
        lanes,
        max_batch: batch,
        engine_lanes: args.opt_positive("engine-lanes", 0usize)?,
        devices: args.opt_positive("devices", 1usize)?,
        panel_width: args
            .opt_positive("panel-width", ebv_solve::solver::DEFAULT_PANEL_WIDTH)?,
        kernel: kernel_arg(args)?,
        schedule: schedule_arg(args)?,
        sparse_parallel: args.opt_parsed("sparse-parallel", true)?,
        use_runtime: args.flag("runtime"),
        profiling: args.flag("profile"),
        ..ServiceConfig::default()
    };
    let svc = SolverService::start(cfg)?;

    let trace = generate_trace(&TraceSpec {
        rate,
        count: requests,
        sizes: vec![64, 128, 256],
        mix: vec![(SystemKind::Dense, 0.6), (SystemKind::Sparse, 0.4)],
        seed: args.opt_parsed("seed", 0xEB5u64)?,
    });

    println!("serving {requests} requests at ~{rate}/s on {lanes} lanes (batch<={batch})");
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for job in &trace {
        // Replay arrivals in real time (compressed 10x to keep demos fast).
        let target = job.arrival / 10.0;
        let now = t0.elapsed().as_secs_f64();
        if target > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        let rx = match job.kind {
            SystemKind::Dense => {
                let (a, b) = job.dense_system();
                svc.submit_dense(Arc::new(a), b, Some(job.n as u64))
            }
            _ => {
                let (a, b) = job.sparse_system();
                svc.submit_sparse(Arc::new(a), b, Some(1000 + job.n as u64))
            }
        };
        match rx {
            Ok(rx) => rxs.push(rx),
            Err(e) => log::warn!("request rejected: {e}"),
        }
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("completed {ok}/{requests} in {}", fmt::secs(wall));
    println!("throughput: {}", fmt::rate(ok as f64 / wall, "req"));
    println!("metrics: {}", svc.metrics().summary());
    if ebv_solve::obs::enabled() {
        eprintln!("{}", ebv_solve::obs::summary_line(&svc.metrics_snapshot()));
    }
    svc.shutdown();
    Ok(())
}

fn cmd_tables(args: &Args) -> ebv_solve::Result<()> {
    let which = args.opt("table").unwrap_or("all");
    let sizes = args.opt_list("sizes", &[500, 1000, 2000, 4000, 8000, 16000])?;
    let gpu = GpuModel::gtx280();
    let cpu = CpuModel::i7_single();

    if which == "1" || which == "all" {
        println!("\nTable 1 (sparse, simulated GTX280 vs 1T CPU):");
        let mut rows = Vec::new();
        for &n in &sizes {
            // Factor a real sparse system at a feasible scale and use its
            // fill statistics; beyond 4000 extrapolate the pattern cost.
            let sim_n = n.min(2000);
            let a = diag_dominant_sparse(sim_n, 5, GenSeed(n as u64));
            let f = SparseLu::new().factor(&a)?;
            let scale = (n as f64 / sim_n as f64).powi(2);
            let g = simulate_gpu_sparse(f.l(), f.u(), f.level_count(), &gpu, RowDist::EbvFold);
            let c = simulate_cpu_sparse(f.l(), f.u(), &cpu);
            let gt = g.total() * scale;
            let ct = c.total() * scale;
            rows.push(vec![
                format!("{n}*{n}"),
                format!("{gt:.5}"),
                format!("{ct:.5}"),
                format!("{:.1}", ct / gt),
            ]);
        }
        println!("{}", fmt::table(&["Matrix size", "GPU, sec", "CPU, sec", "Speedup"], &rows));
    }
    if which == "2" || which == "all" {
        println!("\nTable 2 (dense, simulated GTX280 vs 1T CPU):");
        let mut rows = Vec::new();
        for &n in &sizes {
            let g = simulate_gpu_dense(n, &gpu, RowDist::EbvFold);
            let c = simulate_cpu_dense(n, &cpu);
            rows.push(vec![
                format!("{n}*{n}"),
                format!("{:.4}", g.total()),
                format!("{:.4}", c.total()),
                format!("{:.1}", c.total() / g.total()),
            ]);
        }
        println!("{}", fmt::table(&["Matrix size", "GPU, s", "CPU, s", "Speedup"], &rows));
    }
    if which == "3" || which == "all" {
        println!("\nTable 3 (host<->device transfers, simulated PCIe 2.0 x16):");
        let pcie = ebv_solve::gpusim::transfer::PcieModel::gen2_x16();
        let mut rows = Vec::new();
        for &n in &sizes {
            let t = ebv_solve::gpusim::transfer_times(n, n * n, &pcie);
            rows.push(vec![
                format!("{n}*{n}"),
                format!("{:.5}", t.to_gpu),
                format!("{:.5}", t.from_gpu),
            ]);
        }
        println!("{}", fmt::table(&["Matrix size", "To GPU,s", "From GPU,s"], &rows));
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> ebv_solve::Result<()> {
    let n = args.opt_parsed("n", 1024usize)?;
    let lanes = args.opt_parsed("lanes", 8usize)?;
    println!("bi-vectorization of n={n}: {} vectors", bivectorize(n).len());
    println!("\npairing-mode imbalance (vector units):");
    for mode in
        [PairingMode::PaperFold, PairingMode::Block, PairingMode::Cyclic, PairingMode::GreedyLpt]
    {
        let units = equalize(&bivectorize(n), mode, lanes);
        println!("  {mode:?}: {} units, imbalance {:.4}", units.len(), imbalance(&units));
    }
    println!("\nrow-distribution imbalance (lane work, lanes={lanes}):");
    for dist in RowDist::ALL {
        let s = LaneSchedule::build(n, lanes, dist);
        println!("  {:<12} {:.4}", s.work_imbalance(), dist.name());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> ebv_solve::Result<()> {
    println!("ebv-solve {}", ebv_solve::VERSION);
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    match Manifest::load(std::path::Path::new(dir)) {
        Ok(m) => {
            println!("artifacts ({dir}): {} entries", m.entries.len());
            for e in &m.entries {
                println!("  {:<22} kind={:<16} n={:<6} batch={}", e.name, e.kind.as_str(), e.n, e.batch);
            }
        }
        Err(e) => println!("artifacts ({dir}): unavailable ({e})"),
    }
    let gpu = GpuModel::gtx280();
    println!(
        "gpu model: {} ({} cores, {:.0} GFLOP/s peak, {:.1} GB/s)",
        gpu.name,
        gpu.cores,
        gpu.peak_flops() / 1e9,
        gpu.mem_bw / 1e9
    );
    let cpu = CpuModel::i7_single();
    println!(
        "cpu model: {} ({:.1} GFLOP/s dense, {:.1} GFLOP/s sparse)",
        cpu.name,
        cpu.dense_rate() / 1e9,
        cpu.sparse_rate() / 1e9
    );
    Ok(())
}
