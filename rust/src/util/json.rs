//! Minimal JSON parser and emitter.
//!
//! `serde_json` is not available offline; this module implements the
//! subset of JSON the repo needs — which is all of JSON, minus exotic
//! number forms beyond f64. Used for `artifacts/manifest.json`,
//! benchmark reports, and workload traces.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{EbvError, Result};

/// A JSON value. Objects use `BTreeMap` for deterministic emission order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage after document"));
        }
        Ok(v)
    }

    /// Emit compact JSON.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.emit_into(&mut s);
        s
    }

    /// Emit pretty-printed JSON with two-space indentation.
    pub fn emit_pretty(&self) -> String {
        let mut s = String::new();
        self.emit_pretty_into(&mut s, 0);
        s.push('\n');
        s
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => emit_num(*x, out),
            Json::Str(s) => emit_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }

    fn emit_pretty_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.emit_pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(map) if !map.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    emit_str(k, out);
                    out.push_str(": ");
                    v.emit_pretty_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.emit_into(out),
        }
    }

    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field lookup with a descriptive error.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| EbvError::Json(format!("missing required field `{key}`")))
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn emit_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no NaN/Inf; emit null like most tolerant emitters.
        out.push_str("null");
    }
}

/// Emit a JSON string literal (quoted, escaped) into `out`. Public so
/// streaming emitters (the wire codec) can escape without building a
/// `Json` tree.
pub fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ---------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> EbvError {
        EbvError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes_round_trip() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // Raw UTF-8 also survives emit/parse.
        let v = Json::Str("héllo 😀".into());
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
    }

    #[test]
    fn emit_parse_round_trip() {
        let v = Json::obj([
            ("name", Json::from("ebv")),
            ("sizes", Json::arr([Json::from(500usize), Json::from(1000usize)])),
            ("ok", Json::from(true)),
            ("ratio", Json::from(0.125)),
            ("nested", Json::obj([("x", Json::Null)])),
        ]);
        assert_eq!(Json::parse(&v.emit()).unwrap(), v);
        assert_eq!(Json::parse(&v.emit_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(1000.0).emit(), "1000");
        assert_eq!(Json::Num(0.5).emit(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 16, "s": "x"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(16));
        assert_eq!(v.get("n").unwrap().as_str(), None);
        assert!(v.require("missing").is_err());
    }
}
