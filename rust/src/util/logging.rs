//! Tiny `log` backend (offline substitute for `env_logger`).
//!
//! Level is taken from `EBV_LOG` (error|warn|info|debug|trace), default
//! `info`. Writes to stderr with elapsed-time prefixes so coordinator
//! traces read like a service log.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Log, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record<'_>) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!(
            "[{:>8.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger. Safe to call more than once; later calls are no-ops.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level_from_env());
    }
}

fn level_from_env() -> LevelFilter {
    let raw = std::env::var("EBV_LOG").unwrap_or_default();
    match raw.to_ascii_lowercase().as_str() {
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" | "" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        "off" => LevelFilter::Off,
        other => {
            // A typo'd level must not fall back silently — warn once
            // (straight to stderr: the logger isn't installed yet).
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "EBV_LOG: unrecognized level `{other}` \
                     (expected error|warn|info|debug|trace|off); using info"
                );
            });
            LevelFilter::Info
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logging smoke test");
    }

    #[test]
    fn unrecognized_level_falls_back_to_info_with_a_warning() {
        // `level_from_env` reads the process environment; exercise the
        // fallback (and the warn-once guard — the second call must not
        // print again, which we can at least execute for coverage).
        std::env::set_var("EBV_LOG", "verbose");
        assert_eq!(level_from_env(), LevelFilter::Info);
        assert_eq!(level_from_env(), LevelFilter::Info);
        std::env::set_var("EBV_LOG", "INFO");
        assert_eq!(level_from_env(), LevelFilter::Info, "explicit info is accepted");
        std::env::set_var("EBV_LOG", "off");
        assert_eq!(level_from_env(), LevelFilter::Off);
        std::env::remove_var("EBV_LOG");
        assert_eq!(level_from_env(), LevelFilter::Info, "unset defaults to info");
    }
}
