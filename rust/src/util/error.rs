//! Crate error type.
//!
//! Hand-rolled `Display`/`Error` impls (offline substitute for
//! `thiserror`) — the display strings are stable API, relied on by
//! tests and by wire-protocol error frames.

use std::fmt;

/// Errors surfaced by EBV-Solve's public API.
#[derive(Debug)]
pub enum EbvError {
    /// Matrix shape is invalid for the requested operation.
    Shape(String),

    /// The matrix violates a solver precondition (e.g. zero pivot on a
    /// non-pivoting path, or not diagonally dominant when required).
    Numeric(String),

    /// A singular (or numerically singular) pivot was encountered.
    SingularPivot { step: usize, value: f64, tol: f64 },

    /// Artifact registry / runtime failures (missing HLO, compile error).
    Runtime(String),

    /// Coordinator-level failures (queue closed, request rejected).
    Coordinator(String),

    /// Configuration / CLI parse errors.
    Config(String),

    /// JSON parse errors (manifest, traces, reports, wire frames).
    Json(String),

    /// I/O errors with context.
    Io {
        context: String,
        source: std::io::Error,
    },

    /// XLA/PJRT errors from the `xla` crate.
    Xla(String),
}

impl fmt::Display for EbvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EbvError::Shape(s) => write!(f, "shape mismatch: {s}"),
            EbvError::Numeric(s) => write!(f, "numeric precondition failed: {s}"),
            EbvError::SingularPivot { step, value, tol } => {
                write!(f, "singular pivot at step {step}: |{value}| < {tol}")
            }
            EbvError::Runtime(s) => write!(f, "runtime: {s}"),
            EbvError::Coordinator(s) => write!(f, "coordinator: {s}"),
            EbvError::Config(s) => write!(f, "config: {s}"),
            EbvError::Json(s) => write!(f, "json: {s}"),
            EbvError::Io { context, source } => write!(f, "io: {context}: {source}"),
            EbvError::Xla(s) => write!(f, "xla: {s}"),
        }
    }
}

impl std::error::Error for EbvError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EbvError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl EbvError {
    /// Attach a context string to an `std::io::Error`.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        EbvError::Io { context: context.into(), source }
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EbvError {
    fn from(e: xla::Error) -> Self {
        EbvError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, EbvError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = EbvError::Shape("expected 4x4, got 4x3".into());
        assert_eq!(e.to_string(), "shape mismatch: expected 4x4, got 4x3");
        let e = EbvError::SingularPivot { step: 3, value: 1e-20, tol: 1e-12 };
        assert!(e.to_string().contains("step 3"));
    }

    #[test]
    fn io_error_carries_context() {
        let e = EbvError::io("reading manifest", std::io::Error::other("boom"));
        assert!(e.to_string().contains("reading manifest"));
    }

    #[test]
    fn io_error_exposes_source() {
        use std::error::Error as _;
        let e = EbvError::io("ctx", std::io::Error::other("boom"));
        assert!(e.source().is_some());
        assert!(EbvError::Config("x".into()).source().is_none());
    }
}
