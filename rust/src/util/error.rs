//! Crate error type.

use thiserror::Error;

/// Errors surfaced by EBV-Solve's public API.
#[derive(Error, Debug)]
pub enum EbvError {
    /// Matrix shape is invalid for the requested operation.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// The matrix violates a solver precondition (e.g. zero pivot on a
    /// non-pivoting path, or not diagonally dominant when required).
    #[error("numeric precondition failed: {0}")]
    Numeric(String),

    /// A singular (or numerically singular) pivot was encountered.
    #[error("singular pivot at step {step}: |{value}| < {tol}")]
    SingularPivot { step: usize, value: f64, tol: f64 },

    /// Artifact registry / runtime failures (missing HLO, compile error).
    #[error("runtime: {0}")]
    Runtime(String),

    /// Coordinator-level failures (queue closed, request rejected).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// Configuration / CLI parse errors.
    #[error("config: {0}")]
    Config(String),

    /// JSON parse errors (manifest, traces, reports).
    #[error("json: {0}")]
    Json(String),

    /// I/O errors with context.
    #[error("io: {context}: {source}")]
    Io {
        context: String,
        #[source]
        source: std::io::Error,
    },

    /// XLA/PJRT errors from the `xla` crate.
    #[error("xla: {0}")]
    Xla(String),
}

impl EbvError {
    /// Attach a context string to an `std::io::Error`.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        EbvError::Io { context: context.into(), source }
    }
}

impl From<xla::Error> for EbvError {
    fn from(e: xla::Error) -> Self {
        EbvError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, EbvError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        let e = EbvError::Shape("expected 4x4, got 4x3".into());
        assert_eq!(e.to_string(), "shape mismatch: expected 4x4, got 4x3");
        let e = EbvError::SingularPivot { step: 3, value: 1e-20, tol: 1e-12 };
        assert!(e.to_string().contains("step 3"));
    }

    #[test]
    fn io_error_carries_context() {
        let e = EbvError::io("reading manifest", std::io::Error::other("boom"));
        assert!(e.to_string().contains("reading manifest"));
    }
}
