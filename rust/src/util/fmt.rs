//! Human-readable formatting helpers for benchmark and report output.

/// Format seconds adaptively (`1.23 s`, `4.56 ms`, `7.89 µs`, `12.3 ns`).
pub fn secs(t: f64) -> String {
    if !t.is_finite() {
        return format!("{t}");
    }
    let a = t.abs();
    if a >= 1.0 {
        format!("{t:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", t * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", t * 1e6)
    } else {
        format!("{:.1} ns", t * 1e9)
    }
}

/// Format a byte count (`1.5 GiB`, `23.4 MiB`, ...).
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format an operation rate (`12.3 GFLOP/s` style, generic suffix).
pub fn rate(per_sec: f64, suffix: &str) -> String {
    let a = per_sec.abs();
    if a >= 1e9 {
        format!("{:.2} G{suffix}/s", per_sec / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} M{suffix}/s", per_sec / 1e6)
    } else if a >= 1e3 {
        format!("{:.2} K{suffix}/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} {suffix}/s")
    }
}

/// Render a text table: header row plus data rows, columns padded.
/// Used by the bench harness to print the paper's tables.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut width = vec![0usize; ncol];
    for (i, h) in headers.iter().enumerate() {
        width[i] = h.len();
    }
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            width[i] = width[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        out.push('+');
        for w in &width {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    };
    sep(&mut out);
    out.push('|');
    for (i, h) in headers.iter().enumerate() {
        out.push_str(&format!(" {:<w$} |", h, w = width[i]));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (i, cell) in row.iter().enumerate().take(ncol) {
            out.push_str(&format!(" {:<w$} |", cell, w = width[i]));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_picks_sane_units() {
        assert_eq!(secs(1.5), "1.500 s");
        assert_eq!(secs(0.0042), "4.200 ms");
        assert_eq!(secs(2.5e-6), "2.500 µs");
        assert!(secs(3e-9).ends_with("ns"));
    }

    #[test]
    fn bytes_scales() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn rate_scales() {
        assert!(rate(2.5e9, "FLOP").starts_with("2.50 G"));
        assert!(rate(12.0, "req").starts_with("12.00 req"));
    }

    #[test]
    fn table_is_aligned() {
        let t = table(
            &["Matrix size", "GPU, s"],
            &[vec!["500*500".into(), "0.00096".into()], vec!["16000*16000".into(), "0.21".into()]],
        );
        assert!(t.contains("| Matrix size "));
        assert!(t.lines().count() >= 6);
        // Every data line has the same width.
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }
}
