//! Small shared utilities: error type, JSON, logging, env helpers.
//!
//! `serde`/`serde_json` are unavailable in this offline environment, so
//! [`json`] provides a minimal but complete JSON parser/emitter used for
//! the artifact manifest, config dumps and benchmark reports.

pub mod error;
pub mod fmt;
pub mod json;
pub mod logging;

pub use error::{EbvError, Result};
