//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not available offline, so this module provides a
//! SplitMix64 seeder and a Xoshiro256++ generator (Blackman & Vigna) with
//! the small distribution surface the repo needs. All matrix generators,
//! workloads and property tests seed from here, so every experiment is
//! reproducible from a single `u64`.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 256-bit state PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single `u64` (expanded via SplitMix64, per the
    /// reference implementation's recommendation).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 makes this astronomically
        // unlikely, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of randomness.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (polar form avoided for simplicity;
    /// this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (used for request inter-arrival
    /// times in the workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.uniform().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (reservoir; `k <= n`).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut out: Vec<usize> = (0..k).collect();
        for i in k..n {
            let j = self.below(i + 1);
            if j < k {
                out[j] = i;
            }
        }
        out.sort_unstable();
        out
    }

    /// Derive an independent child generator (for per-lane/per-request
    /// streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Rng::seed_from(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        let expect = n / 7;
        for c in counts {
            assert!((c as f64 - expect as f64).abs() < expect as f64 * 0.1);
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut rng = Rng::seed_from(13);
        let lambda = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::seed_from(17);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::seed_from(19);
        let s = rng.sample_indices(50, 10);
        assert_eq!(s.len(), 10);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::seed_from(23);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
