//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! Provides seeded generators, a `forall` runner that reports the failing
//! seed + iteration, and simple input shrinking for numeric sizes. Used
//! by the `rust/tests/prop_*.rs` integration suites.
//!
//! ```
//! use ebv_solve::testutil::{forall, Gen};
//!
//! forall("square of size is monotone", 100, |g| {
//!     let n = g.usize_in(1, 50);
//!     assert!(n * n >= n);
//! });
//! ```

use crate::rng::Rng;

/// Generator context handed to each property iteration.
pub struct Gen {
    rng: Rng,
    /// Trace of drawn scalars, reported on failure.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::seed_from(seed), trace: Vec::new() }
    }

    /// Uniform integer in `[lo, hi]`, biased toward the edges (edge
    /// cases find bugs — 25% of draws return lo, hi, or near-edges).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = if self.rng.chance(0.25) {
            match self.rng.below(4) {
                0 => lo,
                1 => hi,
                2 => lo + (hi - lo).min(1),
                _ => hi - (hi - lo).min(1),
            }
        } else {
            self.rng.int_in(lo, hi)
        };
        self.trace.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("f64_in({lo},{hi})={v:.6}"));
        v
    }

    /// Bernoulli draw.
    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let i = self.rng.below(xs.len());
        self.trace.push(format!("choose[{i}/{}]", xs.len()));
        &xs[i]
    }

    /// A fresh seed for nested deterministic structures (matrix
    /// generators etc.).
    pub fn seed(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(format!("seed={v:#x}"));
        v
    }

    /// Vector of f64 with the given length.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }
}

/// Base seed: fixed by default for reproducible CI; override with
/// `EBV_PROP_SEED` to explore, or to replay a failure.
fn base_seed() -> u64 {
    std::env::var("EBV_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xEB5_0001)
}

/// Run `body` for `iters` seeded iterations. On panic, re-raises with
/// the failing iteration, seed, and the generator's draw trace so the
/// case can be replayed exactly.
pub fn forall(name: &str, iters: usize, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = base_seed();
    for i in 0..iters {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
            g
        });
        if let Err(payload) = result {
            // Re-run to capture the trace (body is deterministic in seed).
            let trace = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
                g.trace
            })
            .unwrap_or_default();
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at iteration {i} (seed {seed:#x}):\n  {msg}\n  draws: [{}]\n  replay: EBV_PROP_SEED={base} (iteration {i})",
                trace.join(", ")
            );
        }
    }
}

/// Same-pattern value rescale of a CSR matrix: identical structure
/// (row pointers and column indices), every stored value multiplied by
/// `s`. The canonical way the suites and benches build the
/// "same sparsity pattern, different values" refactorization workload
/// the sparse symbolic/numeric split serves.
pub fn rescale_csr(a: &crate::matrix::CsrMatrix, s: f64) -> crate::matrix::CsrMatrix {
    crate::matrix::CsrMatrix::from_raw(
        a.rows(),
        a.cols(),
        a.row_ptr().to_vec(),
        a.col_idx().to_vec(),
        a.values().iter().map(|&v| v * s).collect(),
    )
    .expect("rescale preserves a valid CSR structure")
}

/// Assert two f64 slices agree within `tol` (∞-norm), with a helpful diff.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what}: index {i}: {x} vs {y} (|Δ|={} > {tol})",
            (x - y).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rescale_preserves_structure_and_scales_values() {
        let a = crate::matrix::generate::diag_dominant_sparse(
            12,
            3,
            crate::matrix::generate::GenSeed(3),
        );
        let b = rescale_csr(&a, -2.0);
        assert_eq!(a.row_ptr(), b.row_ptr());
        assert_eq!(a.col_idx(), b.col_idx());
        for (&va, &vb) in a.values().iter().zip(b.values().iter()) {
            assert_eq!(vb, va * -2.0);
        }
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("addition commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn forall_reports_failures_with_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("deliberately false", 50, |g| {
                let n = g.usize_in(0, 100);
                assert!(n < 95, "n too big: {n}");
            });
        });
        let err = result.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("deliberately false"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("usize_in"), "{msg}");
    }

    #[test]
    fn edge_bias_hits_bounds() {
        let mut lo_seen = false;
        let mut hi_seen = false;
        forall("edges appear", 200, |g| {
            let v = g.usize_in(3, 17);
            assert!((3..=17).contains(&v));
        });
        // Direct check of the bias mechanics.
        let mut g = Gen::new(42);
        for _ in 0..500 {
            match g.usize_in(3, 17) {
                3 => lo_seen = true,
                17 => hi_seen = true,
                _ => {}
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn assert_close_diagnoses_mismatch() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_close(&[1.0], &[2.0], 1e-9, "bad");
        });
        assert!(r.is_err());
    }
}
