//! Observability: span-structured solve tracing and the measured
//! lane/device imbalance profiler.
//!
//! The paper argues EBV wins by *equalizing* lane work; PRs 1–5 only
//! ever predicted that balance (`FactorPlan::lane_imbalance`,
//! `DevicePlan::device_imbalance`). This subsystem measures it:
//!
//! * [`span`] — a typed six-phase solve timeline (ingest → cache
//!   lookup → symbolic → numeric factor → trisolve → encode) recorded
//!   via RAII [`SpanTimer`]s into a per-thread sink and carried as a
//!   [`SolveTrace`];
//! * [`profiler`] — per-lane busy vs barrier-wait nanoseconds
//!   accumulated by the lane team while profiling is on, folded into
//!   the same `max_mean_imbalance` statistic the planner uses so
//!   predicted and measured imbalance are directly comparable;
//! * [`export`] — Prometheus text exposition, a JSONL [`EventLog`],
//!   and the stderr [`summary_line`] digest.
//!
//! **Zero-overhead contract**: everything is gated on one
//! process-global relaxed [`AtomicBool`](std::sync::atomic::AtomicBool)
//! ([`enabled`]). With profiling off (the default) every hook is a
//! single relaxed load and an untaken branch — no clocks, no
//! allocation, no shared-memory traffic — pinned by the
//! `ablation_obs` bench. Recording never changes arithmetic, so
//! results are bitwise identical with profiling on or off (pinned in
//! `tests/prop_devices.rs` and `tests/obs_profile.rs`).

pub mod export;
pub mod profiler;
pub mod span;

pub use export::{prometheus, summary_line, EventLog};
pub use profiler::{LaneProfile, LaneProfileSnapshot};
pub use span::{
    enabled, now_ns, record, set_enabled, take_thread_spans, Phase, SolveTrace, Span, SpanTimer,
};

/// Shared helpers for unit tests that toggle the process-global
/// profiling flag: they all serialize on one mutex so parallel test
/// threads can't observe each other's state.
#[cfg(test)]
pub(crate) mod testhooks {
    /// Serializes every test that flips [`super::set_enabled`].
    pub(crate) static OBS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// Enable profiling for one scope, restoring `false` on drop. Holds
    /// [`OBS_LOCK`] for its lifetime and drains the thread sink on both
    /// edges so spans can't leak across tests.
    pub(crate) struct Enabled(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);
    impl Enabled {
        pub(crate) fn new() -> Enabled {
            let g = OBS_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let _ = super::take_thread_spans();
            super::set_enabled(true);
            Enabled(g)
        }
    }
    impl Drop for Enabled {
        fn drop(&mut self) {
            super::set_enabled(false);
            let _ = super::take_thread_spans();
        }
    }
}
