//! Exporters for the observability registry.
//!
//! Three sinks over the same [`MetricsSnapshot`]:
//!
//! * [`prometheus`] — Prometheus text exposition (`# TYPE` headers +
//!   one sample per line), served by the `ebv-solve metrics`
//!   subcommand for scrape-style integration;
//! * [`EventLog`] — append-only JSONL writer (one [`Json`] document
//!   per line) for span timelines and per-request events, reusing the
//!   repo's own `util/json` emitter;
//! * [`summary_line`] — the single-line stderr digest printed at the
//!   end of a profiled session.
//!
//! All exporters are pull-side: they format data that was already
//! collected, so none of them is on the zero-overhead hot path.

use std::fmt::Write as _;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

use crate::coordinator::MetricsSnapshot;
use crate::util::error::{EbvError, Result};
use crate::util::json::Json;

/// Render a snapshot as Prometheus text exposition format. Counter
/// vs gauge classification follows the semantics of each field:
/// monotone totals are counters, ratios and means are gauges.
pub fn prometheus(m: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut counter = |name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP ebv_{name} {help}");
        let _ = writeln!(out, "# TYPE ebv_{name} counter");
        let _ = writeln!(out, "ebv_{name} {v}");
    };
    counter("submitted_total", "Requests accepted into the ingress queue.", m.submitted);
    counter("rejected_total", "Requests refused by admission control.", m.rejected);
    counter("completed_total", "Requests answered successfully.", m.completed);
    counter("failed_total", "Requests answered with an error.", m.failed);
    counter("batches_total", "Coalesced batches executed.", m.batches);
    counter("batched_requests_total", "Requests that rode in a batch.", m.batched_requests);
    counter("factor_hits_total", "Factor-cache hits.", m.factor_hits);
    counter("factor_misses_total", "Factor-cache misses.", m.factor_misses);
    counter("symbolic_reuse_total", "Sparse solves that reused a cached symbolic analysis.", m.symbolic_reuse);
    counter("numeric_refactor_total", "Level-parallel numeric refactorizations.", m.numeric_refactor);
    counter("dense_solves_total", "Dense solves observed by the class histogram.", m.dense_solves);
    counter("sparse_solves_total", "Sparse solves observed by the class histogram.", m.sparse_solves);
    counter("engine_lanes", "Resident lanes of the shared engine.", m.engine_lanes);
    counter("engine_jobs_total", "Pooled jobs executed by the engine.", m.engine_jobs);
    counter("engine_steps_total", "Barrier-separated steps executed.", m.engine_steps);
    counter("engine_barrier_waits_total", "Lane barrier crossings.", m.engine_barrier_waits);
    counter("panel_width", "Effective blocked-factorization panel width.", m.panel_width);
    counter("devices", "Device shards of the two-level runtime.", m.devices);
    counter("device_lanes", "Resident lanes per device engine.", m.device_lanes);
    counter("device_jobs_total", "Device-sharded jobs executed.", m.device_jobs);
    counter("exchange_steps_total", "Staged exchange phases executed.", m.exchange_steps);
    counter("exchange_elems_total", "f64 elements broadcast through the exchange.", m.exchange_elems);
    counter("lane_busy_ns_total", "Profiled per-lane compute nanoseconds (summed).", m.busy_ns);
    counter("lane_wait_ns_total", "Profiled per-lane barrier-wait nanoseconds (summed).", m.wait_ns);
    counter("profiled_jobs_total", "Jobs profiled into the lane accumulators.", m.profiled_jobs);
    counter("device_busy_ns_total", "Profiled per-device compute nanoseconds (summed).", m.device_busy_ns);
    counter("exchange_ns_total", "Profiled nanoseconds inside sharded exchanges.", m.exchange_ns);
    counter("sessions_total", "Wire sessions ever opened.", m.sessions_total);
    counter("sessions_shed_total", "Connections shed with a busy frame.", m.sessions_shed);
    counter("wire_frames_total", "Request frames read across all sessions.", m.wire_frames);
    counter("wire_solves_total", "Solve frames answered with a solution.", m.wire_solves);
    counter("wire_errors_total", "Error frames written across all sessions.", m.wire_errors);
    counter("wire_ingest_ns_total", "Profiled nanoseconds decoding request frames.", m.wire_ingest_ns);
    counter("wire_encode_ns_total", "Profiled nanoseconds encoding response frames.", m.wire_encode_ns);
    counter("binary_sessions_total", "Sessions that negotiated the binary frame encoding.", m.binary_sessions);
    counter("wire_bytes_in_total", "Transport bytes read from peers, both frame formats.", m.wire_bytes_in);
    counter("wire_bytes_out_total", "Transport bytes written to peers, both frame formats.", m.wire_bytes_out);
    let mut gauge = |name: &str, help: &str, v: f64| {
        let _ = writeln!(out, "# HELP ebv_{name} {help}");
        let _ = writeln!(out, "# TYPE ebv_{name} gauge");
        let _ = writeln!(out, "ebv_{name} {v}");
    };
    gauge("mean_batch", "Mean requests per executed batch.", m.mean_batch);
    gauge("latency_mean_seconds", "Mean solve latency.", m.lat_mean_s);
    gauge("latency_p50_seconds", "Median solve latency (histogram bound).", m.lat_p50_s);
    gauge("latency_p99_seconds", "p99 solve latency (histogram bound).", m.lat_p99_s);
    gauge("dense_latency_mean_seconds", "Mean dense solve latency.", m.dense_lat_mean_s);
    gauge("dense_latency_p99_seconds", "p99 dense solve latency.", m.dense_lat_p99_s);
    gauge("sparse_latency_mean_seconds", "Mean sparse solve latency.", m.sparse_lat_mean_s);
    gauge("sparse_latency_p99_seconds", "p99 sparse solve latency.", m.sparse_lat_p99_s);
    gauge(
        "measured_lane_imbalance",
        "Measured max/mean per-lane busy time (FactorPlan counterpart).",
        m.measured_imbalance,
    );
    gauge(
        "measured_device_imbalance",
        "Measured max/mean per-device busy time (DevicePlan counterpart).",
        m.device_measured_imbalance,
    );
    gauge("active_sessions", "Wire sessions currently open.", m.active_sessions as f64);
    gauge("peak_sessions", "High-water mark of concurrent sessions.", m.peak_sessions as f64);
    // Info-style gauge: the kernel name rides in a label so the value
    // stays a constant 1 (Prometheus has no string samples).
    let _ = writeln!(out, "# HELP ebv_kernel Resolved trailing-update microkernel.");
    let _ = writeln!(out, "# TYPE ebv_kernel gauge");
    let _ = writeln!(out, "ebv_kernel{{kernel=\"{}\"}} 1", m.kernel.name());
    let _ = writeln!(out, "# HELP ebv_schedule Lane scheduling discipline.");
    let _ = writeln!(out, "# TYPE ebv_schedule gauge");
    let _ = writeln!(out, "ebv_schedule{{schedule=\"{}\"}} 1", m.schedule.name());
    out
}

/// The single-line digest a profiled session prints to stderr on
/// shutdown: traffic, engine, and measured-balance headline numbers.
pub fn summary_line(m: &MetricsSnapshot) -> String {
    format!(
        "obs: completed={} failed={} dense={} sparse={} engine_jobs={} \
         busy_ms={:.1} wait_ms={:.1} exchange_ms={:.1} \
         lane_imbalance={:.3} device_imbalance={:.3}",
        m.completed,
        m.failed,
        m.dense_solves,
        m.sparse_solves,
        m.engine_jobs,
        m.busy_ns as f64 / 1e6,
        m.wait_ns as f64 / 1e6,
        m.exchange_ns as f64 / 1e6,
        m.measured_imbalance,
        m.device_measured_imbalance,
    )
}

/// Append-only JSONL event log: one compact JSON document per line.
/// Writes go through a mutex-guarded `BufWriter`, so one log can be
/// shared across worker threads; every append ends with a newline and
/// [`EventLog::flush`] (called on drop) pushes the tail to disk.
#[derive(Debug)]
pub struct EventLog {
    writer: Mutex<BufWriter<File>>,
}

impl EventLog {
    /// Open `path` for appending, creating it if absent.
    pub fn open(path: &Path) -> Result<EventLog> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| EbvError::io(format!("open event log {}", path.display()), e))?;
        Ok(EventLog { writer: Mutex::new(BufWriter::new(file)) })
    }

    /// Append one event as a single compact JSON line.
    pub fn append(&self, event: &Json) -> Result<()> {
        let mut line = event.emit();
        line.push('\n');
        let mut w = self.writer.lock().expect("event log poisoned");
        w.write_all(line.as_bytes())
            .map_err(|e| EbvError::io("append event log", e))
    }

    /// Flush buffered lines to disk.
    pub fn flush(&self) -> Result<()> {
        let mut w = self.writer.lock().expect("event log poisoned");
        w.flush().map_err(|e| EbvError::io("flush event log", e))
    }
}

impl Drop for EventLog {
    fn drop(&mut self) {
        if let Ok(mut w) = self.writer.lock() {
            let _ = w.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn distinct_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 1,
            rejected: 2,
            completed: 3,
            failed: 4,
            batches: 5,
            batched_requests: 6,
            factor_hits: 7,
            factor_misses: 8,
            symbolic_reuse: 9,
            numeric_refactor: 10,
            mean_batch: 11.5,
            lat_mean_s: 12.5,
            lat_p50_s: 13.5,
            lat_p99_s: 14.5,
            engine_lanes: 15,
            engine_jobs: 16,
            engine_steps: 17,
            engine_barrier_waits: 18,
            panel_width: 19,
            kernel: crate::solver::Kernel::Tiled,
            schedule: crate::exec::Schedule::Dataflow,
            devices: 20,
            device_lanes: 21,
            device_jobs: 22,
            exchange_steps: 23,
            exchange_elems: 24,
            dense_solves: 25,
            sparse_solves: 26,
            dense_lat_mean_s: 27.5,
            dense_lat_p99_s: 28.5,
            sparse_lat_mean_s: 29.5,
            sparse_lat_p99_s: 30.5,
            busy_ns: 31,
            wait_ns: 32,
            profiled_jobs: 33,
            measured_imbalance: 34.5,
            device_busy_ns: 35,
            exchange_ns: 36,
            device_measured_imbalance: 37.5,
            sessions_total: 38,
            active_sessions: 39,
            peak_sessions: 40,
            sessions_shed: 41,
            wire_frames: 42,
            wire_solves: 43,
            wire_errors: 44,
            wire_ingest_ns: 45,
            wire_encode_ns: 46,
            binary_sessions: 47,
            wire_bytes_in: 48,
            wire_bytes_out: 49,
        }
    }

    #[test]
    fn prometheus_exposition_has_headers_and_samples() {
        let text = prometheus(&distinct_snapshot());
        for needle in [
            "# TYPE ebv_submitted_total counter",
            "ebv_submitted_total 1",
            "ebv_factor_misses_total 8",
            "# TYPE ebv_measured_lane_imbalance gauge",
            "ebv_measured_lane_imbalance 34.5",
            "ebv_exchange_ns_total 36",
            "ebv_sparse_latency_p99_seconds 30.5",
            "ebv_sessions_total 38",
            "# TYPE ebv_active_sessions gauge",
            "ebv_active_sessions 39",
            "ebv_peak_sessions 40",
            "ebv_sessions_shed_total 41",
            "ebv_wire_frames_total 42",
            "ebv_wire_solves_total 43",
            "ebv_wire_errors_total 44",
            "ebv_wire_ingest_ns_total 45",
            "ebv_wire_encode_ns_total 46",
            "ebv_binary_sessions_total 47",
            "ebv_wire_bytes_in_total 48",
            "ebv_wire_bytes_out_total 49",
            "ebv_kernel{kernel=\"tiled\"} 1",
            "ebv_schedule{schedule=\"dataflow\"} 1",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Every line is a comment or a `name value` sample.
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.splitn(2, ' ').count() == 2,
                "malformed line {line:?}"
            );
        }
    }

    #[test]
    fn summary_line_carries_the_headline_numbers() {
        let s = summary_line(&distinct_snapshot());
        assert!(s.starts_with("obs: "), "{s}");
        assert!(s.contains("completed=3"), "{s}");
        assert!(s.contains("lane_imbalance=34.500"), "{s}");
        assert!(s.contains("device_imbalance=37.500"), "{s}");
    }

    #[test]
    fn event_log_appends_parseable_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("ebv_obs_eventlog_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let log = EventLog::open(&path).unwrap();
            log.append(&Json::obj([("event", Json::from("start")), ("n", Json::from(64.0))]))
                .unwrap();
            log.append(&Json::obj([("event", Json::from("stop"))])).unwrap();
            log.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "{text:?}");
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("event").and_then(Json::as_str), Some("start"));
        assert_eq!(first.get("n").and_then(Json::as_f64), Some(64.0));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("event").and_then(Json::as_str), Some("stop"));
        std::fs::remove_file(&path).unwrap();
    }
}
