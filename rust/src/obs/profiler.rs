//! Measured lane/device load profiler.
//!
//! The paper's claim is *predicted* by `FactorPlan::lane_imbalance` /
//! `DevicePlan::device_imbalance` (max/mean of scheduled flops). This
//! module measures the realized counterpart: per-lane busy nanoseconds
//! (compute inside a step) vs barrier-wait nanoseconds, accumulated by
//! the [`LaneTeam`](crate::exec) workers while profiling is on, and
//! folded into the same max/mean statistic
//! ([`crate::ebv::equalize::max_mean_imbalance`]) so predicted and
//! measured imbalance are directly comparable numbers.
//!
//! Recording is batched: each lane accumulates into locals for a whole
//! job and flushes once (two relaxed `fetch_add`s per lane per job), so
//! the profiler never adds per-step shared-memory traffic.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::ebv::equalize::max_mean_imbalance;

/// Per-lane busy/wait accumulators of one engine. Lives alongside the
/// engine's [`EngineStats`](crate::exec::EngineStats); written only
/// while profiling is on.
#[derive(Debug)]
pub struct LaneProfile {
    busy: Vec<AtomicU64>,
    wait: Vec<AtomicU64>,
    jobs: AtomicU64,
}

impl LaneProfile {
    pub fn new(lanes: usize) -> LaneProfile {
        LaneProfile {
            busy: (0..lanes.max(1)).map(|_| AtomicU64::new(0)).collect(),
            wait: (0..lanes.max(1)).map(|_| AtomicU64::new(0)).collect(),
            jobs: AtomicU64::new(0),
        }
    }

    pub fn lanes(&self) -> usize {
        self.busy.len()
    }

    /// Flush one lane's job-local accumulators.
    #[inline]
    pub fn record(&self, lane: usize, busy_ns: u64, wait_ns: u64) {
        self.busy[lane].fetch_add(busy_ns, Ordering::Relaxed);
        self.wait[lane].fetch_add(wait_ns, Ordering::Relaxed);
    }

    /// Count one profiled job (pooled or inline).
    pub fn record_job(&self) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LaneProfileSnapshot {
        LaneProfileSnapshot {
            busy_ns: self.busy.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            wait_ns: self.wait.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            jobs: self.jobs.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`LaneProfile`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LaneProfileSnapshot {
    /// Per-lane compute nanoseconds (inside barrier-stepped jobs).
    pub busy_ns: Vec<u64>,
    /// Per-lane barrier-wait nanoseconds.
    pub wait_ns: Vec<u64>,
    /// Jobs profiled into these accumulators.
    pub jobs: u64,
}

impl LaneProfileSnapshot {
    pub fn total_busy_ns(&self) -> u64 {
        self.busy_ns.iter().sum()
    }

    pub fn total_wait_ns(&self) -> u64 {
        self.wait_ns.iter().sum()
    }

    /// Measured max/mean imbalance of per-lane busy time — the runtime
    /// counterpart of `FactorPlan::lane_imbalance()`, computed by the
    /// same statistic. `1.0` when nothing was profiled (perfect
    /// balance, vacuously).
    pub fn measured_imbalance(&self) -> f64 {
        let loads: Vec<usize> = self.busy_ns.iter().map(|&ns| ns as usize).collect();
        max_mean_imbalance(&loads)
    }

    /// Per-lane delta against an earlier snapshot of the same profile —
    /// what the ablation benches use to attribute busy/wait nanoseconds
    /// to one measured region (e.g. a single factor under one schedule)
    /// on a long-lived engine whose accumulators never reset. Lane
    /// vectors of different lengths (different engines) are truncated to
    /// the shorter; counters that went backwards saturate to zero.
    pub fn delta_since(&self, base: &LaneProfileSnapshot) -> LaneProfileSnapshot {
        let delta = |now: &[u64], then: &[u64]| -> Vec<u64> {
            now.iter()
                .zip(then.iter().chain(std::iter::repeat(&0)))
                .map(|(&a, &b)| a.saturating_sub(b))
                .collect()
        };
        LaneProfileSnapshot {
            busy_ns: delta(&self.busy_ns, &base.busy_ns),
            wait_ns: delta(&self.wait_ns, &base.wait_ns),
            jobs: self.jobs.saturating_sub(base.jobs),
        }
    }

    /// Barrier-wait share of total lane time, in `[0, 1]`.
    pub fn wait_fraction(&self) -> f64 {
        let busy = self.total_busy_ns() as f64;
        let wait = self.total_wait_ns() as f64;
        if busy + wait == 0.0 {
            0.0
        } else {
            wait / (busy + wait)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_accumulates_per_lane() {
        let p = LaneProfile::new(3);
        p.record(0, 100, 10);
        p.record(1, 50, 60);
        p.record(0, 100, 10);
        p.record_job();
        let s = p.snapshot();
        assert_eq!(s.busy_ns, vec![200, 50, 0]);
        assert_eq!(s.wait_ns, vec![20, 60, 0]);
        assert_eq!(s.jobs, 1);
        assert_eq!(s.total_busy_ns(), 250);
        assert_eq!(s.total_wait_ns(), 80);
    }

    #[test]
    fn measured_imbalance_reuses_the_plan_statistic() {
        // Perfectly balanced lanes -> 1.0 (the FactorPlan convention).
        let s = LaneProfileSnapshot { busy_ns: vec![100, 100], wait_ns: vec![0, 0], jobs: 1 };
        assert_eq!(s.measured_imbalance(), 1.0);
        // One hot lane: max/mean = 300 / 200 = 1.5.
        let s = LaneProfileSnapshot { busy_ns: vec![300, 100], wait_ns: vec![0, 0], jobs: 1 };
        assert!((s.measured_imbalance() - 1.5).abs() < 1e-12);
        // Untouched profile: vacuously balanced, matching
        // max_mean_imbalance's zero-mean convention.
        let s = LaneProfileSnapshot::default();
        assert_eq!(s.measured_imbalance(), 1.0);
    }

    #[test]
    fn delta_since_isolates_a_region() {
        let p = LaneProfile::new(2);
        p.record(0, 100, 10);
        p.record(1, 50, 5);
        p.record_job();
        let base = p.snapshot();
        p.record(0, 40, 4);
        p.record(1, 60, 6);
        p.record_job();
        let d = p.snapshot().delta_since(&base);
        assert_eq!(d.busy_ns, vec![40, 60]);
        assert_eq!(d.wait_ns, vec![4, 6]);
        assert_eq!(d.jobs, 1);
        // Delta against a fresh baseline is the snapshot itself.
        assert_eq!(p.snapshot().delta_since(&LaneProfileSnapshot::default()), p.snapshot());
    }

    #[test]
    fn wait_fraction_is_bounded() {
        let s = LaneProfileSnapshot { busy_ns: vec![75], wait_ns: vec![25], jobs: 1 };
        assert!((s.wait_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(LaneProfileSnapshot::default().wait_fraction(), 0.0);
    }

    #[test]
    fn zero_lane_profile_clamps_to_one() {
        assert_eq!(LaneProfile::new(0).lanes(), 1);
    }
}
