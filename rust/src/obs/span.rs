//! Span-structured solve timeline.
//!
//! Every profiled request is described by a [`SolveTrace`]: a list of
//! typed [`Span`]s, one per solve phase (ingest → cache lookup →
//! symbolic → numeric factor → trisolve → encode), recorded by cheap
//! RAII [`SpanTimer`]s into a lock-free per-thread sink.
//!
//! **Zero-overhead contract.** Profiling is off by default. With it
//! off, [`SpanTimer::start`] is a single relaxed atomic load and a
//! branch — no clock read, no allocation, no thread-local write — so
//! instrumented hot paths (the dense factorization, the level
//! trisolves) cost nothing measurable. The `ablation_obs` bench pins
//! this (< 2% on the dense hot path). With it on, each span costs two
//! monotonic clock reads and one `Vec` push on the recording thread.
//!
//! **Threading.** Spans land in a `thread_local!` sink: recording never
//! takes a lock or touches shared state. Whoever owns a request's
//! lifecycle (the coordinator worker, or `ebv-solve solve --profile`)
//! drains its thread's spans with [`take_thread_spans`] and folds them
//! into the request's [`SolveTrace`]. Phases executed on other threads
//! (the wire session's ingest/encode) are drained there and merged.
//!
//! Timestamps are nanoseconds since a process-local epoch (first use),
//! so spans from different threads of one process share a timeline.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::error::{EbvError, Result};
use crate::util::json::Json;

/// The six phases of a solve's lifecycle, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Request decode / matrix construction.
    Ingest,
    /// Factor/symbolic cache probe.
    CacheLookup,
    /// Structure analysis: sparse fill/DAG analysis, or the dense
    /// lane-schedule construction (the EBV equalized deal).
    Symbolic,
    /// The numeric factorization sweep.
    NumericFactor,
    /// Forward/backward substitution.
    Trisolve,
    /// Response encode / output formatting.
    Encode,
}

impl Phase {
    /// Every phase, in pipeline order.
    pub const ALL: [Phase; 6] = [
        Phase::Ingest,
        Phase::CacheLookup,
        Phase::Symbolic,
        Phase::NumericFactor,
        Phase::Trisolve,
        Phase::Encode,
    ];

    /// Stable wire/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Ingest => "ingest",
            Phase::CacheLookup => "cache_lookup",
            Phase::Symbolic => "symbolic",
            Phase::NumericFactor => "numeric_factor",
            Phase::Trisolve => "trisolve",
            Phase::Encode => "encode",
        }
    }

    /// Inverse of [`Phase::name`].
    pub fn from_name(name: &str) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| p.name() == name)
    }
}

/// One timed phase occurrence. `start_ns` is relative to the process
/// epoch (see [`now_ns`]); multiple spans of one phase may appear in a
/// trace (e.g. forward and backward trisolve).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub phase: Phase,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// Process-global profiling switch. Relaxed is sufficient: the flag
/// gates *observation*, never correctness, and hot loops load it once
/// per job into a local.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether profiling is on (one relaxed load — the whole cost of the
/// instrumentation when off).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn profiling on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-local epoch (monotonic).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

thread_local! {
    static SINK: RefCell<Vec<Span>> = const { RefCell::new(Vec::new()) };
}

/// Record a pre-measured span into the calling thread's sink. No-op
/// when profiling is off.
#[inline]
pub fn record(phase: Phase, start_ns: u64, dur_ns: u64) {
    if enabled() {
        SINK.with(|s| s.borrow_mut().push(Span { phase, start_ns, dur_ns }));
    }
}

/// Drain the calling thread's recorded spans (oldest first).
pub fn take_thread_spans() -> Vec<Span> {
    SINK.with(|s| std::mem::take(&mut *s.borrow_mut()))
}

/// RAII phase timer: starts on construction, records a [`Span`] into
/// the thread sink on drop. When profiling is off, construction is a
/// relaxed load + branch and drop is a branch — nothing else.
#[must_use = "a SpanTimer records its span when dropped"]
pub struct SpanTimer(Option<(Phase, u64)>);

impl SpanTimer {
    #[inline]
    pub fn start(phase: Phase) -> SpanTimer {
        if enabled() {
            SpanTimer(Some((phase, now_ns())))
        } else {
            SpanTimer(None)
        }
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        if let Some((phase, start_ns)) = self.0.take() {
            let dur_ns = now_ns().saturating_sub(start_ns);
            SINK.with(|s| s.borrow_mut().push(Span { phase, start_ns, dur_ns }));
        }
    }
}

/// The span timeline of one solve request.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolveTrace {
    pub spans: Vec<Span>,
}

impl SolveTrace {
    /// Drain the calling thread's sink into a trace.
    pub fn from_thread() -> SolveTrace {
        SolveTrace { spans: take_thread_spans() }
    }

    /// Append spans recorded elsewhere (e.g. the wire session thread's
    /// ingest/encode), keeping start order.
    pub fn merge(&mut self, spans: Vec<Span>) {
        self.spans.extend(spans);
        self.spans.sort_by_key(|s| s.start_ns);
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Sum of all span durations.
    pub fn total_ns(&self) -> u64 {
        self.spans.iter().map(|s| s.dur_ns).sum()
    }

    /// Summed duration of one phase.
    pub fn phase_ns(&self, phase: Phase) -> u64 {
        self.spans.iter().filter(|s| s.phase == phase).map(|s| s.dur_ns).sum()
    }

    /// Phases with at least one span, in pipeline order.
    pub fn phases_present(&self) -> Vec<Phase> {
        Phase::ALL
            .into_iter()
            .filter(|p| self.spans.iter().any(|s| s.phase == *p))
            .collect()
    }

    /// JSON form (`{version, spans: [{phase, start_ns, dur_ns}]}`) —
    /// the shape the JSONL event log writes per request.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("version", Json::from(1usize)),
            (
                "spans",
                Json::arr(self.spans.iter().map(|s| {
                    Json::obj([
                        ("phase", Json::from(s.phase.name())),
                        ("start_ns", Json::from(s.start_ns as f64)),
                        ("dur_ns", Json::from(s.dur_ns as f64)),
                    ])
                })),
            ),
        ])
    }

    /// Parse the [`SolveTrace::to_json`] shape back.
    pub fn from_json(v: &Json) -> Result<SolveTrace> {
        let version = v.require("version")?.as_usize().ok_or_else(bad("version"))?;
        if version != 1 {
            return Err(EbvError::Json(format!("solve trace: unknown version {version}")));
        }
        let mut spans = Vec::new();
        for s in v.require("spans")?.as_arr().ok_or_else(bad("spans"))? {
            let name = s.require("phase")?.as_str().ok_or_else(bad("phase"))?;
            let phase = Phase::from_name(name)
                .ok_or_else(|| EbvError::Json(format!("solve trace: unknown phase {name:?}")))?;
            let start_ns = s.require("start_ns")?.as_f64().ok_or_else(bad("start_ns"))? as u64;
            let dur_ns = s.require("dur_ns")?.as_f64().ok_or_else(bad("dur_ns"))? as u64;
            spans.push(Span { phase, start_ns, dur_ns });
        }
        Ok(SolveTrace { spans })
    }

    /// Human-readable timeline table: one row per phase (summed),
    /// with duration and share of the traced total.
    pub fn render_timeline(&self) -> String {
        let total = self.total_ns().max(1);
        let t0 = self.spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
        let rows: Vec<Vec<String>> = Phase::ALL
            .iter()
            .filter(|&&p| self.spans.iter().any(|s| s.phase == p))
            .map(|&p| {
                let start =
                    self.spans.iter().filter(|s| s.phase == p).map(|s| s.start_ns).min().unwrap();
                let dur = self.phase_ns(p);
                vec![
                    p.name().to_string(),
                    format!("{:.1}", (start - t0) as f64 / 1e3),
                    format!("{:.1}", dur as f64 / 1e3),
                    format!("{:.1}%", 100.0 * dur as f64 / total as f64),
                ]
            })
            .collect();
        let mut out = crate::util::fmt::table(&["phase", "start µs", "dur µs", "share"], &rows);
        out.push_str(&format!("total traced: {:.1} µs\n", self.total_ns() as f64 / 1e3));
        out
    }
}

fn bad(field: &'static str) -> impl Fn() -> EbvError {
    move || EbvError::Json(format!("solve trace: bad {field}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::testhooks::{Enabled, OBS_LOCK};

    #[test]
    fn disabled_timers_record_nothing() {
        let _g = OBS_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        set_enabled(false);
        let _ = take_thread_spans();
        {
            let _t = SpanTimer::start(Phase::NumericFactor);
            record(Phase::Ingest, 0, 10);
        }
        assert!(take_thread_spans().is_empty());
    }

    #[test]
    fn enabled_timers_record_ordered_spans() {
        let _on = Enabled::new();
        {
            let _t = SpanTimer::start(Phase::Ingest);
        }
        {
            let _t = SpanTimer::start(Phase::Encode);
        }
        let trace = SolveTrace::from_thread();
        assert_eq!(
            trace.spans.iter().map(|s| s.phase).collect::<Vec<_>>(),
            vec![Phase::Ingest, Phase::Encode]
        );
        assert!(trace.spans[0].start_ns <= trace.spans[1].start_ns);
        assert_eq!(trace.phases_present(), vec![Phase::Ingest, Phase::Encode]);
    }

    #[test]
    fn phase_names_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_name(p.name()), Some(p));
        }
        assert_eq!(Phase::from_name("nope"), None);
    }

    #[test]
    fn trace_json_round_trips() {
        let trace = SolveTrace {
            spans: vec![
                Span { phase: Phase::Ingest, start_ns: 10, dur_ns: 5 },
                Span { phase: Phase::Symbolic, start_ns: 20, dur_ns: 7 },
                Span { phase: Phase::NumericFactor, start_ns: 30, dur_ns: 400 },
                Span { phase: Phase::Trisolve, start_ns: 430, dur_ns: 60 },
            ],
        };
        let back = SolveTrace::from_json(&trace.to_json()).unwrap();
        assert_eq!(back, trace);
        assert_eq!(back.total_ns(), 472);
        assert_eq!(back.phase_ns(Phase::NumericFactor), 400);
        // Text parse of the emitted form too (the JSONL log path).
        let text = trace.to_json().emit();
        let re = SolveTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(re, trace);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let v = Json::parse(r#"{"version": 2, "spans": []}"#).unwrap();
        assert!(SolveTrace::from_json(&v).is_err());
        let v = Json::parse(
            r#"{"version": 1, "spans": [{"phase": "warp", "start_ns": 0, "dur_ns": 0}]}"#,
        )
        .unwrap();
        assert!(SolveTrace::from_json(&v).is_err());
    }

    #[test]
    fn timeline_renders_phases_and_shares() {
        let trace = SolveTrace {
            spans: vec![
                Span { phase: Phase::Ingest, start_ns: 0, dur_ns: 250 },
                Span { phase: Phase::NumericFactor, start_ns: 250, dur_ns: 750 },
            ],
        };
        let text = trace.render_timeline();
        assert!(text.contains("ingest"), "{text}");
        assert!(text.contains("numeric_factor"), "{text}");
        assert!(text.contains("75.0%"), "{text}");
        assert!(text.contains("total traced"), "{text}");
    }

    #[test]
    fn merge_interleaves_by_start() {
        let mut trace = SolveTrace {
            spans: vec![Span { phase: Phase::NumericFactor, start_ns: 50, dur_ns: 10 }],
        };
        trace.merge(vec![
            Span { phase: Phase::Ingest, start_ns: 10, dur_ns: 5 },
            Span { phase: Phase::Encode, start_ns: 90, dur_ns: 2 },
        ]);
        let phases: Vec<Phase> = trace.spans.iter().map(|s| s.phase).collect();
        assert_eq!(phases, vec![Phase::Ingest, Phase::NumericFactor, Phase::Encode]);
    }
}
