//! Command-line parsing (offline substitute for `clap`).
//!
//! Supports subcommands with `--key value` / `--key=value` options,
//! `--flag` booleans, and positional arguments, plus generated help.

use std::collections::BTreeMap;

use crate::util::error::{EbvError, Result};

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut out = Args { command, ..Default::default() };
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positionals.
                    out.positionals.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    /// Typed option with default.
    pub fn opt_parsed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| EbvError::Config(format!("--{name}: cannot parse `{v}`"))),
        }
    }

    /// Positive (`>= 1`) count option with default. Rejects an
    /// *explicit* zero at parse time — `--panel-width 0` has no
    /// meaning and used to surface as a late solver error. Callers
    /// whose internal default is a zero sentinel (`--engine-lanes`
    /// auto) still get it by omitting the flag.
    pub fn opt_positive(&self, name: &str, default: usize) -> Result<usize> {
        let v = self.opt_parsed(name, default)?;
        if self.opts.contains_key(name) && v == 0 {
            return Err(EbvError::Config(format!("--{name} must be >= 1")));
        }
        Ok(v)
    }

    /// Comma-separated list option.
    pub fn opt_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| EbvError::Config(format!("--{name}: bad entry `{t}`")))
                })
                .collect(),
        }
    }
}

/// Top-level usage text for the `ebv-solve` binary.
pub const USAGE: &str = "\
ebv-solve — Equal bi-Vectorized LU solver (paper reproduction)

USAGE:
    ebv-solve <COMMAND> [OPTIONS]

COMMANDS:
    solve     Generate a system and solve it
              --kind dense|sparse|poisson   (default dense)
              --n <size>                    (default 512)
              --solver seq|ebv|blocked|gauss-jordan|refined (default ebv;
                                             refined = ebv + iterative
                                             refinement)
              --lanes <k>                   (default #cpus)
              --panel-width <nb>            (blocked EBV panel width;
                                             default 64, 1 = exact
                                             column-at-a-time path)
              --kernel <k>                  (trailing-update microkernel:
                                             auto|unroll4|unroll8|tiled;
                                             default auto — EBV_KERNEL
                                             env or tiled)
              --schedule <s>                (lane scheduling discipline:
                                             barrier|dataflow; default
                                             barrier, dataflow swaps the
                                             per-step/per-level barriers
                                             for dependency-counted tasks
                                             with panel lookahead —
                                             bitwise-identical results)
              --sparse-parallel <bool>      (sparse kinds: symbolic/numeric
                                             split with level-parallel
                                             refactorization; default true,
                                             false = monolithic factor)
              --devices <D>                 (two-level device-sharded
                                             execution; default 1 = flat,
                                             D > 1 shards rows across D
                                             device groups — bitwise
                                             identical results)
              --seed <u64>                  (default 7)
              --profile                     (run through an in-process
                                             profiled service: print the
                                             span timeline and measured
                                             vs planned lane/device
                                             imbalance)
              --events <path>               (with --profile: append the
                                             solve trace to a JSONL
                                             event log)
              --binary                      (drive an in-process wire
                                             session that negotiates the
                                             length-prefixed binary frame
                                             format — NDJSON offer, binary
                                             repeat solve, metrics — and
                                             print NDJSON-vs-binary frame
                                             sizes; see docs/PROTOCOL.md
                                             §Binary frames)
    serve     Serve solves over the NDJSON wire protocol — stdin/stdout
              by default, or concurrent TCP sessions with --listen;
              sessions that offer `accept_binary` get the length-prefixed
              binary frame format for payload-heavy frames
              (both formats specified in docs/PROTOCOL.md)
              --listen <addr>               (e.g. 127.0.0.1:7070; accept
                                             concurrent sessions instead
                                             of serving stdio; SIGINT
                                             drains gracefully)
              --max-sessions <k>            (with --listen: concurrent
                                             session ceiling, default 8;
                                             excess connections get a
                                             `busy` error frame)
              --deadline-ms <ms>            (per-request solve deadline;
                                             expired requests answer
                                             with a `deadline` error
                                             frame; default none)
              --max-frame-bytes <k>         (cap on one request line;
                                             over-cap lines answer with
                                             an `oversized` error frame;
                                             default 64 MiB on TCP,
                                             unlimited on stdio)
              --lanes <k> --batch <k> --window-us <µs> --queue <k>
              --engine-lanes <k>            (resident lanes in the shared
                                             execution engine; omit for
                                             all cores, see README.md
                                             §Execution engine)
              --devices <D>                 (device shards of the two-level
                                             runtime; default 1 = flat,
                                             D > 1 partitions the engine
                                             lanes into D device groups)
              --panel-width <nb>            (blocked factorization panel
                                             width; default 64)
              --kernel <k>                  (trailing-update microkernel:
                                             auto|unroll4|unroll8|tiled)
              --schedule <s>                (lane scheduling discipline:
                                             barrier|dataflow; default
                                             barrier)
              --sparse-parallel <bool>      (sparse symbolic/numeric split
                                             with pattern-keyed symbolic
                                             caching; default true)
              --allow-mtx-path              (let frames reference local
                                             .mtx files; trusted peers only)
              --runtime                     (use PJRT artifacts)
              --trace                       (replay a synthetic trace
                                             instead of serving stdio)
              --requests <k> --rate <r/s>   (trace mode volume)
              --profile                     (enable solve tracing and the
                                             lane/device profiler; prints
                                             an obs summary on stderr)
    metrics   Run probe solves on an in-process profiled service and
              print a Prometheus-style text exposition on stdout
              --n <size> --probes <k>       (probe volume; default 192/2)
              --lanes <k> --devices <D> --panel-width <nb> --kernel <k>
              --no-profile                  (leave the obs subsystem off:
                                             counters only, no measured
                                             imbalance)
              --events <path>               (append a metrics event to a
                                             JSONL event log)
    tables    Regenerate the paper's tables via the cost model
              --table 1|2|3|all             (default all)
    schedule  Print equalization diagnostics for a size
              --n <size> --lanes <k>
    info      Print version, artifact inventory and device models
    help      Show this help
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags_positionals() {
        // NOTE: a bare `--flag` immediately followed by a positional is
        // ambiguous without a schema (clap disambiguates via derive); the
        // convention here is positionals-first or `--` before them.
        let a = parse("solve input.mtx --n 128 --solver=ebv --verbose");
        assert_eq!(a.command, "solve");
        assert_eq!(a.opt("n"), Some("128"));
        assert_eq!(a.opt("solver"), Some("ebv"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["input.mtx"]);
    }

    #[test]
    fn typed_options_with_defaults() {
        let a = parse("solve --n 64");
        assert_eq!(a.opt_parsed("n", 0usize).unwrap(), 64);
        assert_eq!(a.opt_parsed("lanes", 4usize).unwrap(), 4);
        assert!(parse("solve --n x").opt_parsed("n", 0usize).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse("tables --sizes 500,1000,2000");
        assert_eq!(a.opt_list("sizes", &[1]).unwrap(), vec![500, 1000, 2000]);
        assert_eq!(a.opt_list("other", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn positive_options_reject_explicit_zero() {
        // An explicit zero is a parse-time error with the flag named...
        for flag in ["panel-width", "engine-lanes", "devices"] {
            let a = parse(&format!("serve --{flag} 0"));
            let err = a.opt_positive(flag, 64).unwrap_err();
            assert_eq!(err.to_string(), format!("config: --{flag} must be >= 1"));
        }
        // ...while an omitted flag still yields the caller's default,
        // including a zero sentinel (`--engine-lanes` auto).
        let a = parse("serve");
        assert_eq!(a.opt_positive("panel-width", 64).unwrap(), 64);
        assert_eq!(a.opt_positive("engine-lanes", 0).unwrap(), 0);
        // Unparseable values keep the opt_parsed message.
        let err = parse("serve --devices two").opt_positive("devices", 1).unwrap_err();
        assert_eq!(err.to_string(), "config: --devices: cannot parse `two`");
    }

    #[test]
    fn usage_documents_the_kernel_knob() {
        assert!(USAGE.contains("--kernel"), "solve/serve/metrics should list --kernel");
        assert!(USAGE.contains("auto|unroll4|unroll8|tiled"));
    }

    #[test]
    fn usage_documents_the_schedule_knob() {
        assert!(USAGE.contains("--schedule"), "solve/serve should list --schedule");
        assert!(USAGE.contains("barrier|dataflow"));
    }

    #[test]
    fn usage_documents_the_serving_edge_knobs() {
        for knob in ["--listen", "--max-sessions", "--deadline-ms", "--max-frame-bytes"] {
            assert!(USAGE.contains(knob), "serve should list {knob}");
        }
        assert!(USAGE.contains("docs/PROTOCOL.md"), "serve should point at the wire spec");
    }

    #[test]
    fn usage_documents_the_binary_wire_demo() {
        assert!(USAGE.contains("--binary"), "solve should list --binary");
        assert!(USAGE.contains("§Binary frames"), "--binary should point at the spec section");
    }

    #[test]
    fn double_dash_stops_option_parsing() {
        let a = parse("solve -- --not-an-option");
        assert_eq!(a.positionals, vec!["--not-an-option"]);
    }

    #[test]
    fn empty_args_default_to_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn flag_followed_by_option_style_value() {
        // `--verbose` followed by another `--opt` stays a flag.
        let a = parse("solve --verbose --n 8");
        assert!(a.flag("verbose"));
        assert_eq!(a.opt("n"), Some("8"));
    }
}
