//! Coordinate-format sparse matrix (assembly format).

use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};

/// COO (triplet) sparse matrix. Duplicates are allowed during assembly
/// and summed on conversion to CSR.
#[derive(Debug, Clone, PartialEq)]
pub struct CooMatrix {
    rows: usize,
    cols: usize,
    entries: Vec<(usize, usize, f64)>,
}

impl CooMatrix {
    pub fn new(rows: usize, cols: usize) -> Self {
        CooMatrix { rows, cols, entries: Vec::new() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored (possibly duplicate) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn entries(&self) -> &[(usize, usize, f64)] {
        &self.entries
    }

    /// Add `v` at `(i, j)`. Duplicate coordinates accumulate.
    pub fn push(&mut self, i: usize, j: usize, v: f64) -> Result<()> {
        if i >= self.rows || j >= self.cols {
            return Err(EbvError::Shape(format!(
                "entry ({i},{j}) out of bounds for {}x{}",
                self.rows, self.cols
            )));
        }
        self.entries.push((i, j, v));
        Ok(())
    }

    /// Convert to CSR, summing duplicates and dropping exact zeros.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut sorted = self.entries.clone();
        sorted.sort_unstable_by_key(|&(i, j, _)| (i, j));

        // Merge duplicates into (i, j, v) runs.
        let mut merged: Vec<(usize, usize, f64)> = Vec::with_capacity(sorted.len());
        for (i, j, v) in sorted {
            match merged.last_mut() {
                Some((li, lj, lv)) if *li == i && *lj == j => *lv += v,
                _ => merged.push((i, j, v)),
            }
        }

        let mut row_ptr = vec![0usize; self.rows + 1];
        let mut col_idx = Vec::with_capacity(merged.len());
        let mut values = Vec::with_capacity(merged.len());
        for (i, j, v) in merged {
            row_ptr[i + 1] += 1;
            col_idx.push(j);
            values.push(v);
        }
        for i in 0..self.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        CsrMatrix::from_raw(self.rows, self.cols, row_ptr, col_idx, values)
            .expect("COO->CSR produced invalid CSR")
            .drop_zeros()
    }

    /// Convert to dense (duplicates accumulate).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for &(i, j, v) in &self.entries {
            m.set(i, j, m.get(i, j) + v);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_validates_bounds() {
        let mut m = CooMatrix::new(2, 2);
        assert!(m.push(0, 0, 1.0).is_ok());
        assert!(m.push(2, 0, 1.0).is_err());
        assert!(m.push(0, 2, 1.0).is_err());
    }

    #[test]
    fn to_dense_accumulates_duplicates() {
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 0, 1.0).unwrap();
        m.push(0, 0, 2.0).unwrap();
        m.push(1, 1, 4.0).unwrap();
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 3.0);
        assert_eq!(d.get(1, 1), 4.0);
    }

    #[test]
    fn to_csr_matches_dense() {
        let mut m = CooMatrix::new(3, 3);
        // Deliberately unsorted with a duplicate.
        m.push(2, 1, 5.0).unwrap();
        m.push(0, 0, 1.0).unwrap();
        m.push(1, 2, 3.0).unwrap();
        m.push(0, 2, 2.0).unwrap();
        m.push(2, 1, -1.0).unwrap();
        let csr = m.to_csr();
        assert_eq!(csr.to_dense().max_abs_diff(&m.to_dense()), 0.0);
        assert_eq!(csr.nnz(), 4); // duplicate merged
    }

    #[test]
    fn to_csr_drops_cancelled_entries() {
        let mut m = CooMatrix::new(2, 2);
        m.push(0, 1, 2.0).unwrap();
        m.push(0, 1, -2.0).unwrap();
        m.push(1, 0, 1.0).unwrap();
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 1);
    }

    #[test]
    fn empty_matrix_converts() {
        let m = CooMatrix::new(3, 4);
        let csr = m.to_csr();
        assert_eq!(csr.nnz(), 0);
        assert_eq!((csr.rows(), csr.cols()), (3, 4));
    }
}
