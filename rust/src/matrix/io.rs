//! MatrixMarket (.mtx) I/O for sparse matrices and simple vector files.
//!
//! Supports the `matrix coordinate real general|symmetric` header, which
//! covers the CFD matrices the paper's workloads represent. Used by the
//! examples to persist/reload systems and by the test suite for
//! round-trip checks.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use crate::matrix::{CooMatrix, CsrMatrix};
use crate::util::error::{EbvError, Result};

/// Read a MatrixMarket coordinate file into CSR.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let f = std::fs::File::open(path)
        .map_err(|e| EbvError::io(format!("open {}", path.display()), e))?;
    parse_matrix_market(BufReader::new(f))
}

/// Parse MatrixMarket text from any reader.
pub fn parse_matrix_market<R: BufRead>(reader: R) -> Result<CsrMatrix> {
    let mut lines = reader.lines();

    // An empty file is a malformed input, not a JSON problem — report it
    // in the same error class as every other header defect so callers
    // (and wire-protocol error frames) classify it correctly.
    let header = lines
        .next()
        .ok_or_else(|| EbvError::Config("empty MatrixMarket file".into()))
        .and_then(|l| l.map_err(|e| EbvError::io("read header", e)))?;
    let head_lc = header.to_ascii_lowercase();
    if !head_lc.starts_with("%%matrixmarket") {
        return Err(EbvError::Config("missing %%MatrixMarket header".into()));
    }
    let symmetric = if head_lc.contains("general") {
        false
    } else if head_lc.contains("symmetric") {
        true
    } else {
        return Err(EbvError::Config(format!("unsupported MatrixMarket variant: {header}")));
    };
    if !head_lc.contains("coordinate") || !head_lc.contains("real") {
        return Err(EbvError::Config(format!("only `coordinate real` supported: {header}")));
    }

    // Skip comments, read the size line.
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line.map_err(|e| EbvError::io("read size line", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| EbvError::Config("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>().map_err(|_| EbvError::Config(format!("bad size line: {size_line}"))))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(EbvError::Config(format!("size line needs 3 fields: {size_line}")));
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = CooMatrix::new(rows, cols);
    let mut seen = 0usize;
    for line in lines {
        let line = line.map_err(|e| EbvError::io("read entry", e))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (i, j, v) = match (it.next(), it.next(), it.next()) {
            (Some(i), Some(j), Some(v)) => (i, j, v),
            _ => return Err(EbvError::Config(format!("bad entry line: {t}"))),
        };
        let i: usize = i.parse().map_err(|_| EbvError::Config(format!("bad row index: {t}")))?;
        let j: usize = j.parse().map_err(|_| EbvError::Config(format!("bad col index: {t}")))?;
        let v: f64 = v.parse().map_err(|_| EbvError::Config(format!("bad value: {t}")))?;
        if i == 0 || j == 0 {
            return Err(EbvError::Config("MatrixMarket indices are 1-based".into()));
        }
        coo.push(i - 1, j - 1, v)?;
        if symmetric && i != j {
            coo.push(j - 1, i - 1, v)?;
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(EbvError::Config(format!("expected {nnz} entries, found {seen}")));
    }
    Ok(coo.to_csr())
}

/// Write CSR to a MatrixMarket `general` coordinate file.
pub fn write_matrix_market(m: &CsrMatrix, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .map_err(|e| EbvError::io(format!("create {}", path.display()), e))?;
    let mut buf = String::new();
    buf.push_str("%%MatrixMarket matrix coordinate real general\n");
    buf.push_str("% written by ebv-solve\n");
    buf.push_str(&format!("{} {} {}\n", m.rows(), m.cols(), m.nnz()));
    for r in 0..m.rows() {
        let (cols, vals) = m.row(r);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            buf.push_str(&format!("{} {} {:.17e}\n", r + 1, j + 1, v));
        }
    }
    f.write_all(buf.as_bytes())
        .map_err(|e| EbvError::io(format!("write {}", path.display()), e))
}

/// Write a vector as one value per line (examples' RHS/solution dumps).
pub fn write_vector(x: &[f64], path: &Path) -> Result<()> {
    let body: String = x.iter().map(|v| format!("{v:.17e}\n")).collect();
    std::fs::write(path, body).map_err(|e| EbvError::io(format!("write {}", path.display()), e))
}

/// Read a one-value-per-line vector file.
pub fn read_vector(path: &Path) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| EbvError::io(format!("read {}", path.display()), e))?;
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(|l| l.parse::<f64>().map_err(|_| EbvError::Config(format!("bad vector entry: {l}"))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_sparse, GenSeed};
    use std::io::Cursor;

    #[test]
    fn parse_general_matrix() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    2 2 3\n\
                    1 1 4.0\n\
                    1 2 -1.0\n\
                    2 2 3.0\n";
        let m = parse_matrix_market(Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 0), 4.0);
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), 0.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn parse_symmetric_mirrors_entries() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 2\n\
                    1 1 4.0\n\
                    2 1 -1.0\n";
        let m = parse_matrix_market(Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn empty_file_is_a_config_error_not_json() {
        // Regression: this used to surface as `EbvError::Json`, which
        // misled callers into treating a truncated .mtx as a JSON bug.
        let err = parse_matrix_market(Cursor::new("")).unwrap_err();
        assert!(matches!(err, EbvError::Config(_)), "got {err:?}");
        assert!(err.to_string().contains("empty MatrixMarket"), "{err}");
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse_matrix_market(Cursor::new("not a header\n")).is_err());
        let missing = "%%MatrixMarket matrix coordinate real general\n2 2 5\n1 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(missing)).is_err());
        let zero_based = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(zero_based)).is_err());
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("ebv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.mtx");
        let m = diag_dominant_sparse(20, 4, GenSeed(7));
        write_matrix_market(&m, &path).unwrap();
        let back = read_matrix_market(&path).unwrap();
        assert_eq!(back.to_dense().max_abs_diff(&m.to_dense()), 0.0);
    }

    #[test]
    fn vector_round_trip() {
        let dir = std::env::temp_dir().join("ebv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v.txt");
        let x = vec![1.5, -2.25, 1e-17, 3.0];
        write_vector(&x, &path).unwrap();
        let back = read_vector(&path).unwrap();
        assert_eq!(back, x);
    }
}
