//! Banded matrix storage (CFD-style discretizations).
//!
//! The paper's motivation is CFD linear systems, which are typically
//! banded (tridiagonal from 1-D, pentadiagonal from 2-D stencils). This
//! format stores only the diagonals in `[-kl, +ku]`.

use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};

/// Banded square matrix with `kl` sub- and `ku` super-diagonals.
/// Diagonal `d ∈ [-kl, ku]` is stored as a dense vector of length `n`
/// (entries outside the matrix are 0 and ignored).
#[derive(Debug, Clone, PartialEq)]
pub struct BandedMatrix {
    n: usize,
    kl: usize,
    ku: usize,
    /// `bands[d + kl][i]` = A[i, i + d - kl ... ] — see `get`.
    bands: Vec<Vec<f64>>,
}

impl BandedMatrix {
    pub fn zeros(n: usize, kl: usize, ku: usize) -> Result<Self> {
        if kl >= n.max(1) || ku >= n.max(1) {
            return Err(EbvError::Shape(format!("bandwidths kl={kl}, ku={ku} too large for n={n}")));
        }
        Ok(BandedMatrix { n, kl, ku, bands: vec![vec![0.0; n]; kl + ku + 1] })
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn kl(&self) -> usize {
        self.kl
    }

    #[inline]
    pub fn ku(&self) -> usize {
        self.ku
    }

    /// Total bandwidth (number of stored diagonals).
    #[inline]
    pub fn bandwidth(&self) -> usize {
        self.kl + self.ku + 1
    }

    fn band_of(&self, i: usize, j: usize) -> Option<usize> {
        let d = j as isize - i as isize;
        if d < -(self.kl as isize) || d > self.ku as isize {
            None
        } else {
            Some((d + self.kl as isize) as usize)
        }
    }

    /// Element access; positions outside the band read as 0.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.n && j < self.n);
        match self.band_of(i, j) {
            Some(b) => self.bands[b][i],
            None => 0.0,
        }
    }

    /// Set an element; writing outside the band is an error.
    pub fn set(&mut self, i: usize, j: usize, v: f64) -> Result<()> {
        if i >= self.n || j >= self.n {
            return Err(EbvError::Shape(format!("({i},{j}) out of bounds for n={}", self.n)));
        }
        match self.band_of(i, j) {
            Some(b) => {
                self.bands[b][i] = v;
                Ok(())
            }
            None => Err(EbvError::Shape(format!(
                "({i},{j}) outside band [-{}, +{}]",
                self.kl, self.ku
            ))),
        }
    }

    /// Banded matvec `y = A x` in O(n · bandwidth).
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.n {
            return Err(EbvError::Shape("matvec length mismatch".into()));
        }
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let jlo = i.saturating_sub(self.kl);
            let jhi = (i + self.ku).min(self.n.saturating_sub(1));
            let mut acc = 0.0;
            for j in jlo..=jhi {
                acc += self.get(i, j) * x[j];
            }
            y[i] = acc;
        }
        Ok(y)
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.n, self.n);
        for i in 0..self.n {
            let jlo = i.saturating_sub(self.kl);
            let jhi = (i + self.ku).min(self.n.saturating_sub(1));
            for j in jlo..=jhi {
                m.set(i, j, self.get(i, j));
            }
        }
        m
    }

    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix::from_dense(&self.to_dense(), 0.0)
    }

    /// Tridiagonal constructor (`sub`, `diag`, `sup` of lengths n-1, n, n-1).
    pub fn tridiagonal(sub: &[f64], diag: &[f64], sup: &[f64]) -> Result<Self> {
        let n = diag.len();
        if sub.len() + 1 != n || sup.len() + 1 != n {
            return Err(EbvError::Shape("tridiagonal band lengths".into()));
        }
        let mut m = BandedMatrix::zeros(n, 1, 1)?;
        for i in 0..n {
            m.set(i, i, diag[i])?;
            if i + 1 < n {
                m.set(i + 1, i, sub[i])?;
                m.set(i, i + 1, sup[i])?;
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_validates_bandwidth() {
        assert!(BandedMatrix::zeros(4, 4, 0).is_err());
        assert!(BandedMatrix::zeros(4, 3, 3).is_ok());
    }

    #[test]
    fn get_set_in_and_out_of_band() {
        let mut m = BandedMatrix::zeros(4, 1, 1).unwrap();
        m.set(1, 2, 5.0).unwrap();
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 3), 0.0); // outside band reads 0
        assert!(m.set(0, 3, 1.0).is_err()); // outside band write errors
    }

    #[test]
    fn tridiagonal_layout() {
        let m = BandedMatrix::tridiagonal(&[1.0, 2.0], &[4.0, 5.0, 6.0], &[7.0, 8.0]).unwrap();
        let d = m.to_dense();
        assert_eq!(d.get(0, 0), 4.0);
        assert_eq!(d.get(1, 0), 1.0);
        assert_eq!(d.get(0, 1), 7.0);
        assert_eq!(d.get(2, 1), 2.0);
        assert_eq!(d.get(2, 2), 6.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = BandedMatrix::tridiagonal(&[1.0, 2.0], &[4.0, 5.0, 6.0], &[7.0, 8.0]).unwrap();
        let x = vec![1.0, -1.0, 2.0];
        assert_eq!(m.matvec(&x).unwrap(), m.to_dense().matvec(&x).unwrap());
    }

    #[test]
    fn csr_round_trip_preserves_values() {
        let m = BandedMatrix::tridiagonal(&[1.0, 2.0], &[4.0, 5.0, 6.0], &[7.0, 8.0]).unwrap();
        assert_eq!(m.to_csr().to_dense(), m.to_dense());
        assert_eq!(m.to_csr().nnz(), 7);
    }
}
