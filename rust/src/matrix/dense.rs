//! Row-major dense matrix.

use crate::util::error::{EbvError, Result};

/// Row-major dense `f64` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(EbvError::Shape(format!(
                "buffer length {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        if rows.iter().any(|row| row.len() != c) {
            return Err(EbvError::Shape("ragged rows".into()));
        }
        Ok(DenseMatrix { rows: r, cols: c, data: rows.concat() })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Matrix-vector product `A x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(EbvError::Shape(format!(
                "matvec: x has length {}, expected {}",
                x.len(),
                self.cols
            )));
        }
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x.iter()) {
                acc += a * b;
            }
            y[i] = acc;
        }
        Ok(y)
    }

    /// Dense matmul `A B` (naive ikj loop; test/oracle use only).
    pub fn matmul(&self, rhs: &DenseMatrix) -> Result<DenseMatrix> {
        if self.cols != rhs.rows {
            return Err(EbvError::Shape(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, rhs.rows, rhs.cols
            )));
        }
        let mut out = DenseMatrix::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow = out.row_mut(i);
                for (o, r) in orow.iter_mut().zip(rrow.iter()) {
                    *o += aik * r;
                }
            }
        }
        Ok(out)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> DenseMatrix {
        let mut t = DenseMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// ∞-norm residual `max_i |A x - b|_i` — the acceptance metric used
    /// throughout the tests and examples.
    pub fn residual(&self, x: &[f64], b: &[f64]) -> f64 {
        let ax = self.matvec(x).expect("residual: shape mismatch");
        ax.iter().zip(b.iter()).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
    }

    /// Is the matrix strictly diagonally dominant by rows?
    pub fn is_diag_dominant(&self) -> bool {
        if !self.is_square() {
            return false;
        }
        for i in 0..self.rows {
            let row = self.row(i);
            let off: f64 =
                row.iter().enumerate().filter(|(j, _)| *j != i).map(|(_, v)| v.abs()).sum();
            if row[i].abs() <= off {
                return false;
            }
        }
        true
    }

    /// Apply a row permutation: `out[i] = self[perm[i]]`.
    pub fn permute_rows(&self, perm: &[usize]) -> Result<DenseMatrix> {
        if perm.len() != self.rows {
            return Err(EbvError::Shape("permutation length mismatch".into()));
        }
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        Ok(out)
    }

    /// Max absolute element-wise difference (test helper).
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Convert the row-major f64 buffer to f32 (for the PJRT/f32 path).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_identity_get_set() {
        let mut m = DenseMatrix::zeros(2, 3);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        let i = DenseMatrix::identity(3);
        assert_eq!(i.get(0, 0), 1.0);
        assert_eq!(i.get(0, 1), 0.0);
    }

    #[test]
    fn from_vec_validates_shape() {
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(DenseMatrix::from_vec(2, 2, vec![1.0; 4]).is_ok());
    }

    #[test]
    fn from_rows_rejects_ragged() {
        assert!(DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0]]).is_err());
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let y = a.matvec(&[1.0, 1.0]).unwrap();
        assert_eq!(y, vec![3.0, 7.0]);
        assert!(a.matvec(&[1.0]).is_err());
    }

    #[test]
    fn matmul_identity_is_noop() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let i = DenseMatrix::identity(2);
        assert_eq!(a.matmul(&i).unwrap(), a);
        assert_eq!(i.matmul(&a).unwrap(), a);
    }

    #[test]
    fn matmul_hand_case() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]).unwrap();
        let b = DenseMatrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c, DenseMatrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]).unwrap());
    }

    #[test]
    fn transpose_involution() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]).unwrap();
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(2, 1), 6.0);
    }

    #[test]
    fn residual_zero_for_exact_solution() {
        let a = DenseMatrix::from_rows(&[&[2.0, 0.0], &[0.0, 4.0]]).unwrap();
        let r = a.residual(&[1.0, 0.5], &[2.0, 2.0]);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn diag_dominance_detection() {
        let yes = DenseMatrix::from_rows(&[&[3.0, 1.0], &[-1.0, 2.5]]).unwrap();
        let no = DenseMatrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]).unwrap();
        assert!(yes.is_diag_dominant());
        assert!(!no.is_diag_dominant());
    }

    #[test]
    fn permute_rows_reverses() {
        let a = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]).unwrap();
        let p = a.permute_rows(&[1, 0]).unwrap();
        assert_eq!(p.get(0, 1), 2.0);
        assert_eq!(p.get(1, 0), 1.0);
        assert!(a.permute_rows(&[0]).is_err());
    }
}
