//! Matrix storage formats, generators, I/O and norms.
//!
//! Dense matrices are row-major `f64`. Sparse matrices use CSR for
//! compute and COO for assembly, with lossless conversions between all
//! formats. [`generate`] builds the diagonally-dominant dense/sparse
//! systems the paper evaluates on (Eq. 2 assumes diagonal dominance,
//! which makes pivot-free elimination well-defined), plus Poisson-2D
//! stencil systems for the CFD-flavoured examples.

pub mod banded;
pub mod coo;
pub mod csr;
pub mod dense;
pub mod generate;
pub mod io;
pub mod norms;

pub use banded::BandedMatrix;
pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use dense::DenseMatrix;
