//! Workload matrix generators.
//!
//! The paper evaluates on diagonally-dominant dense and sparse systems
//! (its Eq. 2 assumes unit-diagonal dominance so pivot-free elimination
//! is well-defined). These generators produce such systems
//! deterministically from a seed, plus the Poisson-2D and
//! convection–diffusion systems used by the CFD-flavoured examples —
//! the paper's authors are a CFD group and motivate the method with CFD
//! workloads.

use crate::matrix::{BandedMatrix, CooMatrix, CsrMatrix, DenseMatrix};
use crate::rng::Rng;

/// Newtype for generator seeds so call sites read clearly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenSeed(pub u64);

/// Dense, strictly diagonally dominant `n×n` system.
///
/// Off-diagonals are uniform in `[-1, 1]`; each diagonal is the row's
/// off-diagonal absolute sum plus a margin in `[1, 2]`, guaranteeing
/// strict dominance (and hence a pivot-free LU).
pub fn diag_dominant_dense(n: usize, seed: GenSeed) -> DenseMatrix {
    let mut rng = Rng::seed_from(seed.0);
    let mut m = DenseMatrix::zeros(n, n);
    for i in 0..n {
        let mut off_sum = 0.0;
        for j in 0..n {
            if i == j {
                continue;
            }
            let v = rng.range(-1.0, 1.0);
            m.set(i, j, v);
            off_sum += v.abs();
        }
        m.set(i, i, off_sum + rng.range(1.0, 2.0));
    }
    m
}

/// Sparse, strictly diagonally dominant `n×n` system with roughly
/// `nnz_per_row` off-diagonal entries per row (CFD-stencil-like density;
/// the paper's sparse tests use unstructured CFD matrices).
pub fn diag_dominant_sparse(n: usize, nnz_per_row: usize, seed: GenSeed) -> CsrMatrix {
    let mut rng = Rng::seed_from(seed.0);
    let k = nnz_per_row.min(n.saturating_sub(1));
    let mut coo = CooMatrix::new(n, n);
    for i in 0..n {
        let mut off_sum = 0.0;
        // Sample k distinct off-diagonal columns.
        let mut picked = 0;
        let mut cols = Vec::with_capacity(k);
        while picked < k {
            let j = rng.below(n);
            if j != i && !cols.contains(&j) {
                cols.push(j);
                picked += 1;
            }
        }
        for j in cols {
            let v = rng.range(-1.0, 1.0);
            coo.push(i, j, v).unwrap();
            off_sum += v.abs();
        }
        coo.push(i, i, off_sum + rng.range(1.0, 2.0)).unwrap();
    }
    coo.to_csr()
}

/// 2-D Poisson (5-point Laplacian) on a `g×g` grid → `n = g²` system.
/// Weakly diagonally dominant with dominance strict at the boundary —
/// the canonical CFD pressure-solve matrix.
pub fn poisson_2d(grid: usize) -> CsrMatrix {
    let n = grid * grid;
    let mut coo = CooMatrix::new(n, n);
    let idx = |r: usize, c: usize| r * grid + c;
    for r in 0..grid {
        for c in 0..grid {
            let i = idx(r, c);
            coo.push(i, i, 4.0).unwrap();
            if r > 0 {
                coo.push(i, idx(r - 1, c), -1.0).unwrap();
            }
            if r + 1 < grid {
                coo.push(i, idx(r + 1, c), -1.0).unwrap();
            }
            if c > 0 {
                coo.push(i, idx(r, c - 1), -1.0).unwrap();
            }
            if c + 1 < grid {
                coo.push(i, idx(r, c + 1), -1.0).unwrap();
            }
        }
    }
    coo.to_csr()
}

/// 1-D steady convection–diffusion discretized with central differences:
/// tridiagonal, diagonally dominant for `peclet < 2`.
pub fn convection_diffusion_1d(n: usize, peclet: f64) -> BandedMatrix {
    let sub = vec![-(1.0 + peclet / 2.0); n - 1];
    let diag = vec![2.0; n];
    let sup = vec![-(1.0 - peclet / 2.0); n - 1];
    BandedMatrix::tridiagonal(&sub, &diag, &sup).expect("valid tridiagonal")
}

/// Random right-hand side vector in `[-1, 1]`.
pub fn rhs(n: usize, seed: GenSeed) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed.0 ^ 0xB5D4_F00D);
    (0..n).map(|_| rng.range(-1.0, 1.0)).collect()
}

/// A known solution + matching RHS (for exactness tests):
/// returns `(x_true, b = A x_true)`.
pub fn manufactured_solution(a: &CsrMatrix, seed: GenSeed) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Rng::seed_from(seed.0 ^ 0x50_1u64);
    let x: Vec<f64> = (0..a.cols()).map(|_| rng.range(-1.0, 1.0)).collect();
    let b = a.matvec(&x).expect("square matrix");
    (x, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_generator_is_dominant_and_deterministic() {
        let a = diag_dominant_dense(32, GenSeed(1));
        let b = diag_dominant_dense(32, GenSeed(1));
        let c = diag_dominant_dense(32, GenSeed(2));
        assert!(a.is_diag_dominant());
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn sparse_generator_is_dominant_with_expected_density() {
        let a = diag_dominant_sparse(100, 5, GenSeed(3));
        assert!(a.is_diag_dominant());
        // 5 off-diagonals + 1 diagonal per row (a few may collide/cancel).
        assert!(a.nnz() >= 100 * 5 && a.nnz() <= 100 * 6, "nnz={}", a.nnz());
    }

    #[test]
    fn sparse_generator_handles_tiny_n() {
        let a = diag_dominant_sparse(2, 5, GenSeed(4));
        assert!(a.is_diag_dominant());
        assert_eq!(a.rows(), 2);
    }

    #[test]
    fn poisson_2d_structure() {
        let a = poisson_2d(4);
        assert_eq!(a.rows(), 16);
        assert_eq!(a.get(0, 0), 4.0);
        assert_eq!(a.get(0, 1), -1.0);
        assert_eq!(a.get(0, 4), -1.0);
        assert_eq!(a.get(0, 5), 0.0); // no diagonal coupling
        // Interior row has 5 entries, corner row has 3.
        assert_eq!(a.row_nnz(5), 5);
        assert_eq!(a.row_nnz(0), 3);
        // Symmetric.
        assert_eq!(a.transpose().to_dense(), a.to_dense());
    }

    #[test]
    fn convection_diffusion_dominance_threshold() {
        let ok = convection_diffusion_1d(16, 1.0);
        assert!(ok.to_dense().is_diag_dominant() || {
            // central rows: |−1.5| + |−0.5| = 2.0 == diag — weak dominance;
            // accept weak here by checking no row exceeds the diagonal.
            let d = ok.to_dense();
            (0..16).all(|i| {
                let off: f64 = (0..16).filter(|&j| j != i).map(|j| d.get(i, j).abs()).sum();
                d.get(i, i).abs() >= off
            })
        });
    }

    #[test]
    fn manufactured_solution_is_consistent() {
        let a = diag_dominant_sparse(50, 4, GenSeed(9));
        let (x, b) = manufactured_solution(&a, GenSeed(10));
        assert!(a.residual(&x, &b) < 1e-12);
    }

    #[test]
    fn rhs_is_deterministic() {
        assert_eq!(rhs(8, GenSeed(5)), rhs(8, GenSeed(5)));
        assert_ne!(rhs(8, GenSeed(5)), rhs(8, GenSeed(6)));
    }
}
