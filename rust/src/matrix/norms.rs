//! Vector and matrix norms + residual helpers.

use crate::matrix::{CsrMatrix, DenseMatrix};

/// Euclidean norm ‖x‖₂.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Max norm ‖x‖∞.
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).fold(0.0, f64::max)
}

/// 1-norm ‖x‖₁.
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Element-wise difference norm ‖a − b‖∞ (panics on length mismatch).
pub fn diff_inf(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "diff_inf: length mismatch");
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Relative residual ‖Ax − b‖₂ / ‖b‖₂ for a dense system.
pub fn rel_residual_dense(a: &DenseMatrix, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x).expect("shape");
    let r: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
    let nb = norm2(b);
    if nb == 0.0 {
        norm2(&r)
    } else {
        norm2(&r) / nb
    }
}

/// Relative residual for a sparse system.
pub fn rel_residual_csr(a: &CsrMatrix, x: &[f64], b: &[f64]) -> f64 {
    let ax = a.matvec(x).expect("shape");
    let r: Vec<f64> = ax.iter().zip(b.iter()).map(|(p, q)| p - q).collect();
    let nb = norm2(b);
    if nb == 0.0 {
        norm2(&r)
    } else {
        norm2(&r) / nb
    }
}

/// Matrix ∞-norm (max row sum of absolute values).
pub fn matrix_norm_inf(a: &DenseMatrix) -> f64 {
    (0..a.rows())
        .map(|i| a.row(i).iter().map(|v| v.abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Frobenius norm.
pub fn frobenius(a: &DenseMatrix) -> f64 {
    a.data().iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::DenseMatrix;

    #[test]
    fn vector_norms() {
        let x = [3.0, -4.0];
        assert_eq!(norm2(&x), 5.0);
        assert_eq!(norm_inf(&x), 4.0);
        assert_eq!(norm1(&x), 7.0);
    }

    #[test]
    fn diff_inf_basic() {
        assert_eq!(diff_inf(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
    }

    #[test]
    fn relative_residual_zero_for_exact() {
        let a = DenseMatrix::from_rows(&[&[2.0, 0.0], &[0.0, 2.0]]).unwrap();
        assert_eq!(rel_residual_dense(&a, &[1.0, 1.0], &[2.0, 2.0]), 0.0);
    }

    #[test]
    fn matrix_norms() {
        let a = DenseMatrix::from_rows(&[&[1.0, -2.0], &[3.0, 4.0]]).unwrap();
        assert_eq!(matrix_norm_inf(&a), 7.0);
        assert!((frobenius(&a) - (30.0f64).sqrt()).abs() < 1e-12);
    }
}
