//! Compressed sparse row matrix (compute format).

use crate::matrix::DenseMatrix;
use crate::util::error::{EbvError, Result};

/// CSR sparse matrix: `row_ptr` (len `rows+1`), `col_idx`/`values`
/// (len `nnz`), column indices strictly increasing within each row.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from raw arrays, validating the CSR invariants.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Result<Self> {
        if row_ptr.len() != rows + 1 {
            return Err(EbvError::Shape(format!(
                "row_ptr length {} != rows+1 ({})",
                row_ptr.len(),
                rows + 1
            )));
        }
        if col_idx.len() != values.len() {
            return Err(EbvError::Shape("col_idx/values length mismatch".into()));
        }
        if row_ptr[0] != 0 || *row_ptr.last().unwrap() != col_idx.len() {
            return Err(EbvError::Shape("row_ptr endpoints invalid".into()));
        }
        for w in row_ptr.windows(2) {
            if w[1] < w[0] {
                return Err(EbvError::Shape("row_ptr not monotone".into()));
            }
        }
        for r in 0..rows {
            let idx = &col_idx[row_ptr[r]..row_ptr[r + 1]];
            for w in idx.windows(2) {
                if w[1] <= w[0] {
                    return Err(EbvError::Shape(format!(
                        "row {r}: column indices not strictly increasing"
                    )));
                }
            }
            if let Some(&last) = idx.last() {
                if last >= cols {
                    return Err(EbvError::Shape(format!("row {r}: column index {last} >= {cols}")));
                }
            }
        }
        Ok(CsrMatrix { rows, cols, row_ptr, col_idx, values })
    }

    /// Empty matrix with no stored entries.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CsrMatrix {
            rows,
            cols,
            row_ptr: vec![0; rows + 1],
            col_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    #[inline]
    pub fn row_ptr(&self) -> &[usize] {
        &self.row_ptr
    }

    #[inline]
    pub fn col_idx(&self) -> &[usize] {
        &self.col_idx
    }

    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Entries of row `r` as parallel (col, value) slices.
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let span = self.row_ptr[r]..self.row_ptr[r + 1];
        (&self.col_idx[span.clone()], &self.values[span])
    }

    /// Number of stored entries in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Value at `(i, j)` (binary search; 0.0 if not stored).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Sparse matrix–vector product `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(EbvError::Shape(format!(
                "spmv: x has length {}, expected {}",
                x.len(),
                self.cols
            )));
        }
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                acc += v * x[j];
            }
            y[r] = acc;
        }
        Ok(y)
    }

    /// ∞-norm residual `max_i |A x - b|_i`.
    pub fn residual(&self, x: &[f64], b: &[f64]) -> f64 {
        let ax = self.matvec(x).expect("residual: shape mismatch");
        ax.iter().zip(b.iter()).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
    }

    /// Densify (test/oracle use).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                m.set(r, j, v);
            }
        }
        m
    }

    /// Build from dense, keeping entries with `|a_ij| > tol`.
    pub fn from_dense(m: &DenseMatrix, tol: f64) -> CsrMatrix {
        let mut row_ptr = vec![0usize; m.rows() + 1];
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for i in 0..m.rows() {
            for j in 0..m.cols() {
                let v = m.get(i, j);
                if v.abs() > tol {
                    col_idx.push(j);
                    values.push(v);
                    row_ptr[i + 1] += 1;
                }
            }
        }
        for i in 0..m.rows() {
            row_ptr[i + 1] += row_ptr[i];
        }
        CsrMatrix { rows: m.rows(), cols: m.cols(), row_ptr, col_idx, values }
    }

    /// Copy without exact-zero stored entries.
    pub fn drop_zeros(&self) -> CsrMatrix {
        let mut row_ptr = vec![0usize; self.rows + 1];
        let mut col_idx = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if v != 0.0 {
                    col_idx.push(j);
                    values.push(v);
                    row_ptr[r + 1] += 1;
                }
            }
        }
        for i in 0..self.rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        CsrMatrix { rows: self.rows, cols: self.cols, row_ptr, col_idx, values }
    }

    /// Transposed copy (CSR of Aᵀ, i.e. CSC view of A).
    pub fn transpose(&self) -> CsrMatrix {
        let mut row_ptr = vec![0usize; self.cols + 1];
        for &j in &self.col_idx {
            row_ptr[j + 1] += 1;
        }
        for j in 0..self.cols {
            row_ptr[j + 1] += row_ptr[j];
        }
        let mut cursor = row_ptr.clone();
        let mut col_idx = vec![0usize; self.nnz()];
        let mut values = vec![0.0f64; self.nnz()];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                let slot = cursor[j];
                col_idx[slot] = i;
                values[slot] = v;
                cursor[j] += 1;
            }
        }
        CsrMatrix { rows: self.cols, cols: self.rows, row_ptr, col_idx, values }
    }

    /// Is the sparse matrix strictly diagonally dominant by rows?
    pub fn is_diag_dominant(&self) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                if j == i {
                    diag = v.abs();
                } else {
                    off += v.abs();
                }
            }
            if diag <= off {
                return false;
            }
        }
        true
    }

    /// Density `nnz / (rows*cols)`.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [ 4 0 1 ]
        // [ 0 3 0 ]
        // [ 2 0 5 ]
        CsrMatrix::from_raw(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![4.0, 1.0, 3.0, 2.0, 5.0],
        )
        .unwrap()
    }

    #[test]
    fn from_raw_validates_invariants() {
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // row_ptr len
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 2], vec![1, 0], vec![1.0, 2.0]).is_err()); // unsorted
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 2], vec![0, 0], vec![1.0, 2.0]).is_err()); // dup col
        assert!(CsrMatrix::from_raw(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err()); // col oob
        assert!(CsrMatrix::from_raw(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err()); // not monotone
    }

    #[test]
    fn get_and_row_access() {
        let m = sample();
        assert_eq!(m.get(0, 0), 4.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.get(2, 2), 5.0);
        assert_eq!(m.row_nnz(1), 1);
        let (cols, vals) = m.row(2);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, 5.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let x = vec![1.0, 2.0, 3.0];
        let y = m.matvec(&x).unwrap();
        let yd = m.to_dense().matvec(&x).unwrap();
        assert_eq!(y, yd);
        assert!(m.matvec(&[1.0]).is_err());
    }

    #[test]
    fn dense_round_trip() {
        let m = sample();
        let back = CsrMatrix::from_dense(&m.to_dense(), 0.0);
        assert_eq!(back, m);
    }

    #[test]
    fn transpose_round_trip() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(2, 0), 1.0);
    }

    #[test]
    fn drop_zeros_removes_stored_zeros() {
        let m = CsrMatrix::from_raw(2, 2, vec![0, 2, 3], vec![0, 1, 1], vec![1.0, 0.0, 2.0])
            .unwrap();
        let d = m.drop_zeros();
        assert_eq!(d.nnz(), 2);
        assert_eq!(d.get(0, 1), 0.0);
        assert_eq!(d.get(1, 1), 2.0);
    }

    #[test]
    fn diag_dominance() {
        let m = sample(); // |4|>1, |3|>0, |5|>2 -> dominant
        assert!(m.is_diag_dominant());
        let not = CsrMatrix::from_raw(2, 2, vec![0, 2, 3], vec![0, 1, 1], vec![1.0, 2.0, 1.0])
            .unwrap();
        assert!(!not.is_diag_dominant());
    }

    #[test]
    fn density_is_fractional() {
        assert!((sample().density() - 5.0 / 9.0).abs() < 1e-12);
        assert_eq!(CsrMatrix::zeros(0, 0).density(), 0.0);
    }
}
