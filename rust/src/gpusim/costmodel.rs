//! Per-kernel cost model: roofline with occupancy and launch overhead.

use crate::gpusim::device::GpuModel;

/// Cost of one kernel launch (one elimination step, one solve sweep…).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Floating-point operations in the kernel.
    pub flops: f64,
    /// Bytes moved to/from device memory.
    pub bytes: f64,
    /// Independent work items available (threads the kernel can fill).
    pub parallel_width: f64,
    /// Load imbalance factor (`max/mean` lane work, ≥ 1.0). The
    /// equalization ablation enters the simulation through this term.
    pub imbalance: f64,
}

impl KernelCost {
    /// Execution time on `gpu` under the roofline-with-occupancy model:
    ///
    /// `t = max(flops / (peak · util · eff), bytes / bw) · imbalance + launch`
    ///
    /// where `util = min(1, width / cores)` — a kernel with fewer
    /// independent items than cores cannot fill the device, which is
    /// exactly why the paper's speedups shrink for small `n`.
    pub fn time_on(&self, gpu: &GpuModel) -> f64 {
        let util = (self.parallel_width / gpu.cores as f64).min(1.0).max(1e-9);
        let flop_time = self.flops / (gpu.peak_flops() * util * gpu.efficiency);
        // DRAM traffic is reduced by shared-memory tiling (`smem_reuse`).
        let mem_time = self.bytes / (gpu.mem_bw * gpu.smem_reuse.max(1.0));
        flop_time.max(mem_time) * self.imbalance.max(1.0) + gpu.launch_overhead
    }
}

/// Sum the cost of a sequence of kernels.
pub fn total_time(kernels: &[KernelCost], gpu: &GpuModel) -> f64 {
    kernels.iter().map(|k| k.time_on(gpu)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(flops: f64, bytes: f64, width: f64) -> KernelCost {
        KernelCost { flops, bytes, parallel_width: width, imbalance: 1.0 }
    }

    #[test]
    fn tiny_kernel_is_launch_bound() {
        let g = GpuModel::gtx280();
        let t = k(100.0, 400.0, 100.0).time_on(&g);
        assert!((t - g.launch_overhead).abs() / g.launch_overhead < 0.5, "t={t}");
    }

    #[test]
    fn big_kernel_approaches_roofline() {
        let g = GpuModel::gtx280();
        let flops = 1e12;
        let t = k(flops, 1e9, 1e9).time_on(&g);
        let ideal = flops / (g.peak_flops() * g.efficiency);
        assert!((t - ideal).abs() / ideal < 0.05, "t={t} ideal={ideal}");
    }

    #[test]
    fn narrow_kernel_pays_occupancy_penalty() {
        let g = GpuModel::gtx280();
        let wide = k(1e9, 1e6, 1e6).time_on(&g);
        let narrow = k(1e9, 1e6, 24.0).time_on(&g); // 10% of cores
        assert!(narrow > 5.0 * wide, "narrow={narrow} wide={wide}");
    }

    #[test]
    fn memory_bound_kernel_tracks_effective_bandwidth() {
        let g = GpuModel::gtx280();
        let bytes = 1e12;
        let t = k(1.0, bytes, 1e9).time_on(&g);
        let ideal = bytes / (g.mem_bw * g.smem_reuse);
        assert!((t - ideal).abs() / ideal < 0.05, "t={t} ideal={ideal}");
    }

    #[test]
    fn imbalance_scales_time() {
        let g = GpuModel::gtx280();
        let base = k(1e10, 1e6, 1e6);
        let skewed = KernelCost { imbalance: 2.0, ..base };
        let r = skewed.time_on(&g) / base.time_on(&g);
        assert!((r - 2.0).abs() < 0.1, "r={r}");
    }

    #[test]
    fn total_time_sums() {
        let g = GpuModel::gtx280();
        let ks = vec![k(1e9, 1e6, 1e6); 4];
        let t = total_time(&ks, &g);
        assert!((t - 4.0 * ks[0].time_on(&g)).abs() < 1e-12);
    }
}
