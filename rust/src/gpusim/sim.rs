//! End-to-end simulated runtimes for the paper's workloads.
//!
//! Feeds the *real* per-step op counts from [`crate::ebv::plan`] into
//! the kernel cost model, one kernel per elimination step (the paper's
//! per-vector-pair dispatch), plus the substitution sweeps.

use crate::ebv::plan::{FactorPlan, SolvePlan};
use crate::ebv::schedule::{LaneSchedule, RowDist};
use crate::gpusim::costmodel::{total_time, KernelCost};
use crate::gpusim::device::{CpuModel, GpuModel};
use crate::matrix::CsrMatrix;

/// Simulated runtime decomposition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimResult {
    pub factor_time: f64,
    pub solve_time: f64,
}

impl SimResult {
    pub fn total(&self) -> f64 {
        self.factor_time + self.solve_time
    }
}

const F32: f64 = 4.0;

/// Simulated GPU time for a dense `n×n` EBV factorization + solve.
/// `dist` controls the lane-imbalance factor fed to the cost model —
/// the equalization ablation in simulation space.
pub fn simulate_gpu_dense(n: usize, gpu: &GpuModel, dist: RowDist) -> SimResult {
    // The imbalance penalty of the static distribution, from the actual
    // schedule over a GPU-scale lane count (one lane per core).
    let sched = LaneSchedule::build(n, gpu.cores.min(n.max(1)), dist);
    let imbalance = sched.work_imbalance();
    let plan = FactorPlan::dense(n, &sched);

    let kernels: Vec<KernelCost> = plan
        .steps
        .iter()
        .map(|s| KernelCost {
            flops: (s.scale_flops + s.update_flops) as f64,
            bytes: s.elems_moved as f64 * F32,
            // One thread per trailing-block element (the bi-vector pair
            // grid): m² items at step with trailing size m.
            parallel_width: (s.trailing * s.trailing).max(1) as f64,
            imbalance,
        })
        .collect();
    let factor_time = total_time(&kernels, gpu);

    let sp = SolvePlan::dense(n);
    // Substitution: n column sweeps, each an axpy of shrinking width —
    // the equalized pairing keeps each sweep's width ~n/2.
    let solve_kernels: Vec<KernelCost> = (0..n.saturating_sub(1))
        .map(|r| KernelCost {
            flops: sp.flops as f64 / n.max(1) as f64,
            bytes: (2 * (n - r)) as f64 * F32,
            parallel_width: (n / 2).max(1) as f64,
            imbalance,
        })
        .collect();
    let solve_time = total_time(&solve_kernels, gpu);
    SimResult { factor_time, solve_time }
}

/// Simulated GPU time for a sparse factorization + level-scheduled solve,
/// from the **actual factored pattern** of the workload.
pub fn simulate_gpu_sparse(
    l: &CsrMatrix,
    u: &CsrMatrix,
    levels: usize,
    gpu: &GpuModel,
    dist: RowDist,
) -> SimResult {
    let n = l.rows();
    let sched = LaneSchedule::build(n, gpu.cores.min(n.max(1)), dist);
    let imbalance = sched.work_imbalance();
    let plan = FactorPlan::sparse(l, u, &sched);

    let kernels: Vec<KernelCost> = plan
        .steps
        .iter()
        .map(|s| KernelCost {
            flops: (s.scale_flops + s.update_flops) as f64,
            bytes: s.elems_moved as f64 * F32,
            parallel_width: (s.scale_flops * s.scale_flops.max(1)).max(1) as f64,
            imbalance,
        })
        .collect();
    let factor_time = total_time(&kernels, gpu);

    // Level-scheduled triangular solves: one kernel per level, width =
    // rows in the level (averaged), traffic = factor nnz once through.
    let sp = SolvePlan::sparse(l, u);
    let levels = levels.max(1);
    let rows_per_level = (n as f64 / levels as f64).max(1.0);
    let solve_kernels: Vec<KernelCost> = (0..levels)
        .map(|_| KernelCost {
            flops: sp.flops as f64 / levels as f64,
            bytes: sp.elems_moved as f64 * F32 / levels as f64,
            parallel_width: rows_per_level,
            imbalance,
        })
        .collect();
    let solve_time = total_time(&solve_kernels, gpu);
    SimResult { factor_time, solve_time }
}

/// Simulated single-thread CPU time for the dense factorization + solve.
pub fn simulate_cpu_dense(n: usize, cpu: &CpuModel) -> SimResult {
    let flops = (0..n.saturating_sub(1))
        .map(|r| {
            let m = n - 1 - r;
            (m + 2 * m * m) as f64
        })
        .sum::<f64>();
    // Roofline against single-core bandwidth: the trailing block is
    // streamed once per step.
    let bytes: f64 = (0..n.saturating_sub(1))
        .map(|r| {
            let m = (n - 1 - r) as f64;
            (m * m + 3.0 * m) * 8.0
        })
        .sum();
    let factor_time =
        (flops / cpu.dense_rate()).max(bytes / (cpu.mem_bw * cpu.cache_reuse.max(1.0)));
    let sp = SolvePlan::dense(n);
    let solve_time = sp.flops as f64 / cpu.dense_rate();
    SimResult { factor_time, solve_time }
}

/// Simulated single-thread CPU time for the sparse factorization + solve,
/// from the actual factored pattern.
pub fn simulate_cpu_sparse(l: &CsrMatrix, u: &CsrMatrix, cpu: &CpuModel) -> SimResult {
    let n = l.rows();
    let sched = LaneSchedule::build(n, 1, RowDist::Block);
    let plan = FactorPlan::sparse(l, u, &sched);
    let factor_time = plan.total_flops() as f64 / cpu.sparse_rate();
    let sp = SolvePlan::sparse(l, u);
    let solve_time = sp.flops as f64 / cpu.sparse_rate();
    SimResult { factor_time, solve_time }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_sparse, GenSeed};
    use crate::solver::SparseLu;

    #[test]
    fn gpu_speedup_grows_with_n_dense() {
        let gpu = GpuModel::gtx280();
        let cpu = CpuModel::i7_single();
        let speedup = |n: usize| {
            simulate_cpu_dense(n, &cpu).total()
                / simulate_gpu_dense(n, &gpu, RowDist::EbvFold).total()
        };
        let s500 = speedup(500);
        let s4000 = speedup(4000);
        let s16000 = speedup(16000);
        assert!(s500 < s4000 && s4000 < s16000, "{s500} {s4000} {s16000}");
        // Table 2's qualitative scale: single digits at 500, tens at 16000.
        assert!(s500 > 1.0 && s500 < 15.0, "s500={s500}");
        assert!(s16000 > 15.0, "s16000={s16000}");
    }

    #[test]
    fn equalized_dist_beats_block_in_simulation() {
        let gpu = GpuModel::gtx280();
        let fold = simulate_gpu_dense(2000, &gpu, RowDist::EbvFold).total();
        let block = simulate_gpu_dense(2000, &gpu, RowDist::Block).total();
        assert!(fold < block, "fold={fold} block={block}");
    }

    #[test]
    fn sparse_simulation_runs_on_real_pattern() {
        let a = diag_dominant_sparse(200, 5, GenSeed(71));
        let f = SparseLu::new().factor(&a).unwrap();
        let gpu = GpuModel::gtx280();
        let cpu = CpuModel::i7_single();
        let g = simulate_gpu_sparse(f.l(), f.u(), f.level_count(), &gpu, RowDist::EbvFold);
        let c = simulate_cpu_sparse(f.l(), f.u(), &cpu);
        assert!(g.total() > 0.0 && c.total() > 0.0);
    }

    #[test]
    fn cpu_dense_time_is_cubic_ish() {
        let cpu = CpuModel::i7_single();
        let t1 = simulate_cpu_dense(1000, &cpu).total();
        let t2 = simulate_cpu_dense(2000, &cpu).total();
        let ratio = t2 / t1;
        assert!(ratio > 6.0 && ratio < 10.0, "ratio={ratio}");
    }

    #[test]
    fn a100_is_faster_than_gtx280() {
        let old = simulate_gpu_dense(4000, &GpuModel::gtx280(), RowDist::EbvFold).total();
        let new = simulate_gpu_dense(4000, &GpuModel::a100_like(), RowDist::EbvFold).total();
        assert!(new < old);
    }
}
