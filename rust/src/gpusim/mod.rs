//! GPU/CPU cost models used to regenerate the paper's evaluation.
//!
//! The paper's testbed (NVIDIA GTX280 + single-thread Core i7, CUDA 3.2)
//! is not available; per DESIGN.md §Substitutions we reproduce the
//! *shape* of Tables 1–3 by driving a calibrated analytic cost model
//! with the **actual op counts of the real schedules** produced by
//! [`crate::ebv::plan`]. Nothing in here curve-fits the published
//! numbers: who wins, how speedup grows with `n`, and the sparse/dense
//! gap all emerge from the algorithm's op stream and the device
//! parameters.

pub mod cluster;
pub mod costmodel;
pub mod device;
pub mod sim;
pub mod transfer;

pub use costmodel::KernelCost;
pub use device::{CpuModel, GpuModel};
pub use sim::{simulate_cpu_dense, simulate_cpu_sparse, simulate_gpu_dense, simulate_gpu_sparse, SimResult};
pub use transfer::{transfer_times, TransferTimes};
