//! Host↔device transfer model (the paper's Table 3).
//!
//! PCIe 2.0 ×16: ~8 GB/s raw, ~5.5 GB/s effective H2D, slightly lower
//! D2H on GT200-era parts, with a fixed per-transfer latency. "To GPU"
//! carries the matrix + RHS; "From GPU" carries only the solution
//! vector — which is why the paper's From column barely grows.

/// PCIe link parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct PcieModel {
    /// Host→device effective bandwidth, bytes/s.
    pub h2d_bw: f64,
    /// Device→host effective bandwidth, bytes/s.
    pub d2h_bw: f64,
    /// Fixed per-transfer setup latency, seconds.
    pub latency: f64,
}

impl PcieModel {
    /// PCIe 2.0 ×16 as on the paper's testbed.
    pub fn gen2_x16() -> Self {
        PcieModel { h2d_bw: 5.5e9, d2h_bw: 5.0e9, latency: 1.0e-4 }
    }
}

/// Simulated transfer times for one solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferTimes {
    pub to_gpu: f64,
    pub from_gpu: f64,
}

/// Transfer cost for an `n×n` system with `payload_elems` matrix elements
/// (dense: n²; sparse: nnz + index arrays) plus the RHS up and the
/// solution down, in f32.
pub fn transfer_times(n: usize, payload_elems: usize, pcie: &PcieModel) -> TransferTimes {
    let up_bytes = (payload_elems + n) as f64 * 4.0;
    let down_bytes = n as f64 * 4.0;
    TransferTimes {
        to_gpu: up_bytes / pcie.h2d_bw + pcie.latency,
        from_gpu: down_bytes / pcie.d2h_bw + pcie.latency,
    }
}

/// Payload size of a CSR matrix in elements-equivalent (values + column
/// indices as 4-byte words + row pointers).
pub fn csr_payload_elems(rows: usize, nnz: usize) -> usize {
    2 * nnz + rows + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_gpu_is_latency_dominated_and_flat() {
        let pcie = PcieModel::gen2_x16();
        let small = transfer_times(500, 500 * 500, &pcie);
        let large = transfer_times(16000, 16000 * 16000, &pcie);
        // Paper Table 3: From column grows only ~2.5x over a 32x size range.
        let growth = large.from_gpu / small.from_gpu;
        assert!(growth < 3.0, "growth={growth}");
    }

    #[test]
    fn to_gpu_grows_with_payload() {
        let pcie = PcieModel::gen2_x16();
        let small = transfer_times(500, 500 * 500, &pcie);
        let large = transfer_times(16000, 16000 * 16000, &pcie);
        assert!(large.to_gpu > 20.0 * small.to_gpu);
    }

    #[test]
    fn to_exceeds_from_at_every_size() {
        let pcie = PcieModel::gen2_x16();
        for n in [500usize, 1000, 2000, 4000, 8000, 16000] {
            let t = transfer_times(n, n * n, &pcie);
            assert!(t.to_gpu > t.from_gpu, "n={n}");
        }
    }

    #[test]
    fn transfers_are_negligible_vs_solve() {
        // The paper's point: transfer ≪ compute. 16000² f32 upload is
        // ~0.19s vs 11s GPU solve.
        let pcie = PcieModel::gen2_x16();
        let t = transfer_times(16000, 16000 * 16000, &pcie);
        assert!(t.to_gpu < 0.5);
    }

    #[test]
    fn csr_payload_counts_indices() {
        assert_eq!(csr_payload_elems(10, 50), 111);
    }
}
