//! Device descriptors.
//!
//! Parameters come from public spec sheets, not from fitting the paper's
//! tables: GTX280 = 240 scalar cores @ 1.296 GHz (the paper says "256
//! single cores"; 240 is the actual part), 141.7 GB/s GDDR3, PCIe 2.0
//! ×16. CPU = one core of a 3.2 GHz Core i7 (Bloomfield era) running
//! compiler-vectorized C.

/// GPU execution model parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuModel {
    pub name: &'static str,
    /// Scalar cores (CUDA SPs).
    pub cores: usize,
    /// Core clock in Hz.
    pub clock_hz: f64,
    /// Flops per core per cycle (MAD = 2).
    pub flops_per_cycle: f64,
    /// Device memory bandwidth, bytes/s (effective, ~80% of peak).
    pub mem_bw: f64,
    /// Per-kernel-launch overhead, seconds.
    pub launch_overhead: f64,
    /// Fraction of peak attainable by well-tuned elimination kernels
    /// (coalescing, occupancy headroom).
    pub efficiency: f64,
    /// Effective DRAM-traffic reduction from shared-memory tiling — the
    /// paper stresses it uses shared memory "efficiently"; a 16-wide
    /// panel held in shared memory cuts trailing-update traffic ~8×.
    pub smem_reuse: f64,
}

impl GpuModel {
    /// The paper's device.
    pub fn gtx280() -> Self {
        GpuModel {
            name: "GTX280",
            cores: 240,
            clock_hz: 1.296e9,
            flops_per_cycle: 2.0,
            mem_bw: 0.8 * 141.7e9,
            launch_overhead: 6e-6,
            efficiency: 0.55,
            smem_reuse: 8.0,
        }
    }

    /// A modern-ish comparison point for the extension benches.
    pub fn a100_like() -> Self {
        GpuModel {
            name: "A100-like",
            cores: 6912,
            clock_hz: 1.41e9,
            flops_per_cycle: 2.0,
            mem_bw: 0.85 * 1.555e12,
            launch_overhead: 3e-6,
            efficiency: 0.6,
            smem_reuse: 16.0,
        }
    }

    /// Peak f32 throughput in FLOP/s.
    pub fn peak_flops(&self) -> f64 {
        self.cores as f64 * self.clock_hz * self.flops_per_cycle
    }
}

/// CPU execution model parameters (single thread, as in the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuModel {
    pub name: &'static str,
    pub clock_hz: f64,
    /// Sustained flops/cycle for the regular (dense, unit-stride)
    /// elimination loop — SSE2-era compiler vectorization.
    pub dense_flops_per_cycle: f64,
    /// Sustained flops/cycle for irregular (sparse, indexed) loops —
    /// dominated by cache misses and dependent loads.
    pub sparse_flops_per_cycle: f64,
    /// Main-memory bandwidth available to one core, bytes/s.
    pub mem_bw: f64,
    /// Effective traffic reduction from the L2/L3 cache on the blocked
    /// trailing update (the paper's VS2008 baseline is at least mildly
    /// cache-friendly).
    pub cache_reuse: f64,
}

impl CpuModel {
    /// The paper's host: Core i7 @ 3.2 GHz, one thread, VS2008 C.
    pub fn i7_single() -> Self {
        CpuModel {
            name: "i7-3.2GHz(1T)",
            clock_hz: 3.2e9,
            dense_flops_per_cycle: 2.2,
            sparse_flops_per_cycle: 0.35,
            mem_bw: 8e9,
            cache_reuse: 4.0,
        }
    }

    pub fn dense_rate(&self) -> f64 {
        self.clock_hz * self.dense_flops_per_cycle
    }

    pub fn sparse_rate(&self) -> f64 {
        self.clock_hz * self.sparse_flops_per_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gtx280_peak_is_about_620_gflops() {
        let g = GpuModel::gtx280();
        let peak = g.peak_flops();
        assert!((peak - 622e9).abs() / 622e9 < 0.01, "peak={peak:e}");
    }

    #[test]
    fn cpu_rates_are_ordered() {
        let c = CpuModel::i7_single();
        assert!(c.dense_rate() > c.sparse_rate());
        // Dense ~7 GFLOP/s, the scale the paper's Table 2 CPU column implies.
        assert!(c.dense_rate() > 5e9 && c.dense_rate() < 10e9);
    }

    #[test]
    fn a100_outclasses_gtx280() {
        assert!(GpuModel::a100_like().peak_flops() > 20.0 * GpuModel::gtx280().peak_flops());
    }
}
