//! Multi-device extension: the paper's conclusion claims the method
//! "is able to use another parallel device like CPU clusters". This
//! module models that claim: a cluster of devices running the EBV
//! schedule with fold-distributed row ownership *across devices*, plus
//! an interconnect cost for the per-step pivot-row broadcast.
//!
//! The key structural fact the simulation exposes: per elimination step
//! the pivot row (O(n) bytes) must reach every device, so scaling stops
//! paying once `n³/devices` compute shrinks to the `n² · log(devices)`
//! broadcast term — the strong-scaling knee the `ablation_multidevice`
//! bench sweeps.
//!
//! Since the device layer landed this is no longer the only home of
//! the claim: `exec::DeviceSet` *executes* the same schedule
//! device-sharded, staging the pivot-row broadcast per step, and the
//! bench reports this model and the measured runtime side by side
//! (the measured exchange traffic is pinned against
//! `FactorPlan::multi_device`, which prices exactly the broadcast
//! this module integrates over time).

use crate::ebv::schedule::{LaneSchedule, RowDist};
use crate::gpusim::costmodel::KernelCost;
use crate::gpusim::device::GpuModel;

/// Interconnect between devices.
#[derive(Debug, Clone, PartialEq)]
pub struct Interconnect {
    /// Point-to-point bandwidth, bytes/s.
    pub bw: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
}

impl Interconnect {
    /// PCIe-era host-mediated GPU↔GPU (the paper's 2009 testbed could
    /// only have staged through the host).
    pub fn pcie_staged() -> Self {
        Interconnect { bw: 4.0e9, latency: 2.0e-5 }
    }

    /// Gigabit-ethernet CPU cluster (the conclusion's explicit target).
    pub fn gigabit_cluster() -> Self {
        Interconnect { bw: 0.125e9, latency: 5.0e-5 }
    }

    /// Time to broadcast `bytes` to `peers` devices (binomial tree).
    pub fn broadcast(&self, bytes: f64, peers: usize) -> f64 {
        if peers == 0 {
            return 0.0;
        }
        let rounds = (peers as f64 + 1.0).log2().ceil();
        rounds * (self.latency + bytes / self.bw)
    }
}

/// Simulated multi-device dense EBV factorization time.
///
/// Rows are fold-distributed across `devices` (the EBV pairing applied
/// at cluster scope); each step costs the per-device trailing update
/// (same roofline as single-device, at 1/devices the width) plus the
/// pivot-row broadcast.
pub fn simulate_cluster_dense(
    n: usize,
    devices: usize,
    gpu: &GpuModel,
    link: &Interconnect,
    dist: RowDist,
) -> f64 {
    assert!(devices >= 1);
    let sched = LaneSchedule::build(n, devices, dist);
    let imbalance = sched.work_imbalance();
    let mut total = 0.0;
    for r in 0..n.saturating_sub(1) {
        let m = (n - 1 - r) as f64;
        // Per-device share of the rank-1 update.
        let share = KernelCost {
            flops: (m + 2.0 * m * m) / devices as f64,
            bytes: (2.0 * m * m + 3.0 * m) * 4.0 / devices as f64,
            parallel_width: (m * m / devices as f64).max(1.0),
            imbalance,
        };
        let compute = share.time_on(gpu);
        let broadcast = link.broadcast(m * 4.0, devices - 1);
        total += compute.max(broadcast) + if devices > 1 { link.latency } else { 0.0 };
    }
    total
}

/// Strong-scaling efficiency: `t(1) / (devices · t(devices))`.
pub fn scaling_efficiency(n: usize, devices: usize, gpu: &GpuModel, link: &Interconnect) -> f64 {
    let t1 = simulate_cluster_dense(n, 1, gpu, link, RowDist::EbvFold);
    let td = simulate_cluster_dense(n, devices, gpu, link, RowDist::EbvFold);
    t1 / (devices as f64 * td)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_scales_with_tree_depth() {
        let link = Interconnect::pcie_staged();
        let one = link.broadcast(1e6, 1);
        let seven = link.broadcast(1e6, 7);
        assert!(seven > one);
        assert!(seven < 7.0 * one, "tree broadcast beats linear");
        assert_eq!(link.broadcast(1e6, 0), 0.0);
    }

    #[test]
    fn two_devices_beat_one_at_scale() {
        let gpu = GpuModel::gtx280();
        let link = Interconnect::pcie_staged();
        let t1 = simulate_cluster_dense(8000, 1, &gpu, &link, RowDist::EbvFold);
        let t2 = simulate_cluster_dense(8000, 2, &gpu, &link, RowDist::EbvFold);
        assert!(t2 < t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn small_systems_do_not_scale() {
        // The broadcast term dominates for small n: adding devices hurts.
        let gpu = GpuModel::gtx280();
        let link = Interconnect::gigabit_cluster();
        let t1 = simulate_cluster_dense(500, 1, &gpu, &link, RowDist::EbvFold);
        let t8 = simulate_cluster_dense(500, 8, &gpu, &link, RowDist::EbvFold);
        assert!(t8 > t1, "small systems must not strong-scale: t1={t1} t8={t8}");
    }

    #[test]
    fn efficiency_decays_with_device_count() {
        let gpu = GpuModel::gtx280();
        let link = Interconnect::pcie_staged();
        let e2 = scaling_efficiency(8000, 2, &gpu, &link);
        let e16 = scaling_efficiency(8000, 16, &gpu, &link);
        assert!(e2 > e16, "e2={e2} e16={e16}");
        assert!(e2 > 0.5, "2-device efficiency should be decent: {e2}");
    }

    #[test]
    fn fold_distribution_not_worse_than_block_on_cluster() {
        let gpu = GpuModel::gtx280();
        let link = Interconnect::pcie_staged();
        let fold = simulate_cluster_dense(4000, 4, &gpu, &link, RowDist::EbvFold);
        let block = simulate_cluster_dense(4000, 4, &gpu, &link, RowDist::Block);
        assert!(fold <= block * 1.001, "fold={fold} block={block}");
    }
}
