//! Triangular solves: dense forward/backward substitution (sequential
//! and EBV-parallel) plus level-scheduled sparse variants.
//!
//! The parallel dense substitution is the paper's Eq. (4-b/4-c) read
//! literally: applying `A⁻¹` is a sequence of elementary vector updates
//! (one axpy per pivot), whose lengths shrink `n-1 … 1` — exactly the
//! unequal bi-vector stream that equalization balances across lanes.

use std::sync::Barrier;

use crate::ebv::schedule::LaneSchedule;
use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};

fn check_dims(lu: &DenseMatrix, b: &[f64]) -> Result<usize> {
    if !lu.is_square() {
        return Err(EbvError::Shape("triangular solve needs a square matrix".into()));
    }
    if b.len() != lu.rows() {
        return Err(EbvError::Shape(format!(
            "rhs length {} != matrix size {}",
            b.len(),
            lu.rows()
        )));
    }
    Ok(lu.rows())
}

/// Forward substitution with a **unit** lower triangle packed in `lu`
/// (Doolittle): solves `L y = b`.
pub fn forward_unit_dense(lu: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_dims(lu, b)?;
    let mut y = b.to_vec();
    for i in 0..n {
        let row = lu.row(i);
        let mut acc = y[i];
        for (j, &l_ij) in row[..i].iter().enumerate() {
            acc -= l_ij * y[j];
        }
        y[i] = acc;
    }
    Ok(y)
}

/// Backward substitution with the upper triangle (including diagonal)
/// packed in `lu`: solves `U x = y`.
pub fn backward_dense(lu: &DenseMatrix, y: &[f64]) -> Result<Vec<f64>> {
    let n = check_dims(lu, y)?;
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let row = lu.row(i);
        let mut acc = x[i];
        for (k, &u_ij) in row[i + 1..].iter().enumerate() {
            acc -= u_ij * x[i + 1 + k];
        }
        let d = row[i];
        if d == 0.0 {
            return Err(EbvError::SingularPivot { step: i, value: 0.0, tol: 0.0 });
        }
        x[i] = acc / d;
    }
    Ok(x)
}

/// Column-oriented (right-looking) parallel forward substitution: after
/// `y[j]` finalizes, every lane applies the axpy `b[i] -= L[i,j] y[j]`
/// to its owned rows — the bi-vector apply, equalized by `schedule`.
///
/// A per-column barrier makes this profitable only for large `n`; the
/// benches report the crossover honestly.
pub fn forward_unit_dense_par(
    lu: &DenseMatrix,
    b: &[f64],
    schedule: &LaneSchedule,
) -> Result<Vec<f64>> {
    let n = check_dims(lu, b)?;
    if schedule.n() != n {
        return Err(EbvError::Shape("schedule size mismatch".into()));
    }
    let lanes = schedule.lanes();
    if lanes == 1 || n < 2 {
        return forward_unit_dense(lu, b);
    }
    let mut y = b.to_vec();
    let barrier = Barrier::new(lanes);
    let y_ptr = SharedVec(y.as_mut_ptr());

    std::thread::scope(|s| {
        for lane in 0..lanes {
            let barrier = &barrier;
            let schedule = &schedule;
            let y_ptr = &y_ptr;
            s.spawn(move || {
                for j in 0..n - 1 {
                    barrier.wait();
                    // y[j] is final: all updates to it came from columns < j.
                    let yj = unsafe { *y_ptr.0.add(j) };
                    for &i in schedule.active_rows_of(lane, j) {
                        let l_ij = lu.get(i, j);
                        if l_ij != 0.0 {
                            unsafe {
                                *y_ptr.0.add(i) -= l_ij * yj;
                            }
                        }
                    }
                }
            });
        }
    });
    Ok(y)
}

/// Wrapper making a raw pointer Send+Sync for scoped disjoint-row writes.
struct SharedVec(*mut f64);
unsafe impl Send for SharedVec {}
unsafe impl Sync for SharedVec {}

// ---- sparse ----------------------------------------------------------------

/// Sparse forward substitution `L y = b` with `l` strictly lower
/// triangular (unit diagonal implicit).
pub fn sparse_forward_unit(l: &CsrMatrix, b: &[f64]) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let mut y = b.to_vec();
    for i in 0..l.rows() {
        let (cols, vals) = l.row(i);
        let mut acc = y[i];
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            debug_assert!(j < i, "L must be strictly lower triangular");
            acc -= v * y[j];
        }
        y[i] = acc;
    }
    Ok(y)
}

/// Sparse backward substitution `U x = y` with `u` upper triangular
/// including the diagonal.
pub fn sparse_backward(u: &CsrMatrix, y: &[f64]) -> Result<Vec<f64>> {
    if y.len() != u.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let n = u.rows();
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let (cols, vals) = u.row(i);
        let mut acc = x[i];
        let mut diag = 0.0;
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j == i {
                diag = v;
            } else {
                debug_assert!(j > i, "U must be upper triangular");
                acc -= v * x[j];
            }
        }
        if diag == 0.0 {
            return Err(EbvError::SingularPivot { step: i, value: 0.0, tol: 0.0 });
        }
        x[i] = acc / diag;
    }
    Ok(x)
}

/// Level schedule of a strictly-lower-triangular CSR matrix: rows in the
/// same level have no dependencies among themselves and can be solved in
/// parallel. Returns `(level_of_row, rows_by_level)` — the classic GPU
/// sparse-trisolve structure the paper's sparse speedups rely on.
pub fn levels_of_lower(l: &CsrMatrix) -> (Vec<usize>, Vec<Vec<usize>>) {
    let n = l.rows();
    let mut level = vec![0usize; n];
    let mut max_level = 0usize;
    for i in 0..n {
        let (cols, _) = l.row(i);
        let lv = cols.iter().map(|&j| level[j] + 1).max().unwrap_or(0);
        level[i] = lv;
        max_level = max_level.max(lv);
    }
    let mut by_level = vec![Vec::new(); max_level + 1];
    for (i, &lv) in level.iter().enumerate() {
        by_level[lv].push(i);
    }
    (level, by_level)
}

/// Level-scheduled parallel sparse forward substitution. Within each
/// level, rows are split across `lanes` with nnz-equalized chunks
/// (the EBV balance criterion applied to sparse work).
pub fn sparse_forward_unit_levels(
    l: &CsrMatrix,
    b: &[f64],
    by_level: &[Vec<usize>],
    lanes: usize,
) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    if lanes <= 1 {
        return sparse_forward_unit(l, b);
    }
    let mut y = b.to_vec();
    let y_ptr = SharedVec(y.as_mut_ptr());

    for rows in by_level {
        if rows.len() < lanes * 4 {
            // Small level: not worth spawning.
            for &i in rows {
                let (cols, vals) = l.row(i);
                let mut acc = unsafe { *y_ptr.0.add(i) };
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    acc -= v * unsafe { *y_ptr.0.add(j) };
                }
                unsafe { *y_ptr.0.add(i) = acc };
            }
            continue;
        }
        // Equalize nnz across lane chunks.
        let chunks = equalize_rows_by_nnz(l, rows, lanes);
        std::thread::scope(|s| {
            for chunk in &chunks {
                let y_ptr = &y_ptr;
                s.spawn(move || {
                    for &i in chunk {
                        let (cols, vals) = l.row(i);
                        let mut acc = unsafe { *y_ptr.0.add(i) };
                        for (&j, &v) in cols.iter().zip(vals.iter()) {
                            acc -= v * unsafe { *y_ptr.0.add(j) };
                        }
                        unsafe { *y_ptr.0.add(i) = acc };
                    }
                });
            }
        });
    }
    Ok(y)
}

/// Split `rows` into `lanes` chunks with near-equal total nnz (greedy,
/// preserving order within a chunk).
fn equalize_rows_by_nnz(m: &CsrMatrix, rows: &[usize], lanes: usize) -> Vec<Vec<usize>> {
    let total: usize = rows.iter().map(|&i| m.row_nnz(i).max(1)).sum();
    let target = total.div_ceil(lanes);
    let mut chunks = Vec::with_capacity(lanes);
    let mut cur = Vec::new();
    let mut acc = 0usize;
    for &i in rows {
        cur.push(i);
        acc += m.row_nnz(i).max(1);
        if acc >= target && chunks.len() + 1 < lanes {
            chunks.push(std::mem::take(&mut cur));
            acc = 0;
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ebv::schedule::{LaneSchedule, RowDist};
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};
    use crate::matrix::norms::diff_inf;
    use crate::solver::sparse_lu::SparseLu;
    use crate::solver::{LuSolver, SeqLu};

    #[test]
    fn forward_backward_on_hand_case() {
        // L = [[1,0],[2,1]], U = [[3,1],[0,4]] packed:
        let lu = DenseMatrix::from_rows(&[&[3.0, 1.0], &[2.0, 4.0]]).unwrap();
        // Solve L y = [3, 10]: y = [3, 4]; U x = y: x2 = 1, x1 = (3-1)/3.
        let y = forward_unit_dense(&lu, &[3.0, 10.0]).unwrap();
        assert_eq!(y, vec![3.0, 4.0]);
        let x = backward_dense(&lu, &y).unwrap();
        assert!((x[1] - 1.0).abs() < 1e-15);
        assert!((x[0] - 2.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn dims_validated() {
        let lu = DenseMatrix::zeros(3, 3);
        assert!(forward_unit_dense(&lu, &[1.0, 2.0]).is_err());
        let rect = DenseMatrix::zeros(2, 3);
        assert!(backward_dense(&rect, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn backward_detects_zero_diagonal() {
        let lu = DenseMatrix::from_rows(&[&[0.0, 1.0], &[0.0, 1.0]]).unwrap();
        assert!(matches!(
            backward_dense(&lu, &[1.0, 1.0]),
            Err(EbvError::SingularPivot { .. })
        ));
    }

    #[test]
    fn parallel_forward_matches_sequential() {
        let a = diag_dominant_dense(64, GenSeed(11));
        let f = SeqLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..64).map(|i| (i as f64).sin()).collect();
        let seq = forward_unit_dense(f.packed(), &b).unwrap();
        for dist in RowDist::ALL {
            for lanes in [1usize, 2, 4] {
                let sched = LaneSchedule::build(64, lanes, dist);
                let par = forward_unit_dense_par(f.packed(), &b, &sched).unwrap();
                assert!(
                    diff_inf(&seq, &par) < 1e-12,
                    "{dist:?} lanes={lanes}"
                );
            }
        }
    }

    #[test]
    fn sparse_solves_match_dense() {
        let a = diag_dominant_sparse(40, 4, GenSeed(12));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..40).map(|i| 1.0 + i as f64 * 0.1).collect();
        let y = sparse_forward_unit(f.l(), &b).unwrap();
        let yd = forward_unit_dense(
            &{
                // pack L+U densely for the oracle
                let mut lu = f.u().to_dense();
                let ld = f.l().to_dense();
                for i in 0..40 {
                    for j in 0..i {
                        lu.set(i, j, ld.get(i, j));
                    }
                }
                lu
            },
            &b,
        )
        .unwrap();
        assert!(diff_inf(&y, &yd) < 1e-12);
        let x = sparse_backward(f.u(), &y).unwrap();
        assert!(a.residual(&x, &b) < 1e-9);
    }

    #[test]
    fn levels_respect_dependencies() {
        let a = diag_dominant_sparse(50, 4, GenSeed(13));
        let f = SparseLu::new().factor(&a).unwrap();
        let (level, by_level) = levels_of_lower(f.l());
        // Every dependency j of row i satisfies level[j] < level[i].
        for i in 0..50 {
            let (cols, _) = f.l().row(i);
            for &j in cols {
                assert!(level[j] < level[i]);
            }
        }
        // Levels partition rows.
        let total: usize = by_level.iter().map(|v| v.len()).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn level_scheduled_solve_matches_sequential() {
        let a = diag_dominant_sparse(80, 5, GenSeed(14));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..80).map(|i| (i as f64 * 0.3).cos()).collect();
        let (_, by_level) = levels_of_lower(f.l());
        let seq = sparse_forward_unit(f.l(), &b).unwrap();
        for lanes in [1usize, 2, 4] {
            let par = sparse_forward_unit_levels(f.l(), &b, &by_level, lanes).unwrap();
            assert!(diff_inf(&seq, &par) < 1e-12, "lanes={lanes}");
        }
    }

    #[test]
    fn nnz_chunks_cover_all_rows() {
        let a = diag_dominant_sparse(30, 3, GenSeed(15));
        let rows: Vec<usize> = (0..30).collect();
        let chunks = equalize_rows_by_nnz(&a, &rows, 4);
        let mut all: Vec<usize> = chunks.concat();
        all.sort_unstable();
        assert_eq!(all, rows);
        assert!(chunks.len() <= 4);
    }
}
