//! Triangular solves: dense forward/backward substitution (sequential
//! and EBV-parallel) plus level-scheduled sparse variants.
//!
//! The parallel dense substitution is the paper's Eq. (4-b/4-c) read
//! literally: applying `A⁻¹` is a sequence of elementary vector updates
//! (one axpy per pivot), whose lengths shrink `n-1 … 1` — exactly the
//! unequal bi-vector stream that equalization balances across lanes.
//!
//! All parallel variants submit step-loop jobs to a persistent
//! [`LaneEngine`] (one barrier-separated step per column, or per level
//! for the sparse solve) instead of spawning thread scopes per call —
//! see `rust/DESIGN.md` §Execution engine.

use std::sync::Mutex;

use crate::ebv::schedule::LaneSchedule;
use crate::exec::{run_dataflow, DepGraph, DeviceSet, LaneEngine, StepCtl};
use crate::matrix::{CsrMatrix, DenseMatrix};
use crate::util::error::{EbvError, Result};

fn check_dims(lu: &DenseMatrix, b: &[f64]) -> Result<usize> {
    if !lu.is_square() {
        return Err(EbvError::Shape("triangular solve needs a square matrix".into()));
    }
    if b.len() != lu.rows() {
        return Err(EbvError::Shape(format!(
            "rhs length {} != matrix size {}",
            b.len(),
            lu.rows()
        )));
    }
    Ok(lu.rows())
}

/// Forward substitution with a **unit** lower triangle packed in `lu`
/// (Doolittle): solves `L y = b`.
pub fn forward_unit_dense(lu: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let n = check_dims(lu, b)?;
    let mut y = b.to_vec();
    for i in 0..n {
        let row = lu.row(i);
        let mut acc = y[i];
        for (j, &l_ij) in row[..i].iter().enumerate() {
            acc -= l_ij * y[j];
        }
        y[i] = acc;
    }
    Ok(y)
}

/// Backward substitution with the upper triangle (including diagonal)
/// packed in `lu`: solves `U x = y`.
pub fn backward_dense(lu: &DenseMatrix, y: &[f64]) -> Result<Vec<f64>> {
    let n = check_dims(lu, y)?;
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let row = lu.row(i);
        let mut acc = x[i];
        for (k, &u_ij) in row[i + 1..].iter().enumerate() {
            acc -= u_ij * x[i + 1 + k];
        }
        let d = row[i];
        if d == 0.0 {
            return Err(EbvError::SingularPivot { step: i, value: 0.0, tol: 0.0 });
        }
        x[i] = acc / d;
    }
    Ok(x)
}

/// Column-oriented (right-looking) parallel forward substitution: after
/// `y[j]` finalizes, every lane applies the axpy `b[i] -= L[i,j] y[j]`
/// to its owned rows — the bi-vector apply, equalized by `schedule`,
/// one engine step per column.
///
/// A per-column barrier makes this profitable only for large `n`; the
/// benches report the crossover honestly.
pub fn forward_unit_dense_par(
    lu: &DenseMatrix,
    b: &[f64],
    schedule: &LaneSchedule,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    let n = check_dims(lu, b)?;
    if schedule.n() != n {
        return Err(EbvError::Shape("schedule size mismatch".into()));
    }
    let lanes = schedule.lanes();
    if lanes == 1 || n < 2 {
        return forward_unit_dense(lu, b);
    }
    let mut y = b.to_vec();
    let y_ptr = SharedVec(y.as_mut_ptr());

    engine.run_steps(lanes, n - 1, |lane, j| {
        // y[j] is final: all updates to it came from columns < j,
        // applied at earlier steps and published by the step barrier.
        let yj = unsafe { *y_ptr.0.add(j) };
        for &i in schedule.active_rows_of(lane, j) {
            let l_ij = lu.get(i, j);
            if l_ij != 0.0 {
                unsafe {
                    *y_ptr.0.add(i) -= l_ij * yj;
                }
            }
        }
        StepCtl::Continue
    });
    Ok(y)
}

/// Column-oriented parallel backward substitution: solves `U x = y`
/// (Eq. 4-c, the mirrored bi-vector stream) with two engine sub-steps
/// per column `j = n-1 … 0`:
///
/// 1. the owner of row `j` finalizes `x[j] = x[j] / u_jj` (every update
///    from columns `> j` landed at earlier steps);
/// 2. after the barrier publishes `x[j]`, every lane applies
///    `x[i] -= U[i,j] x[j]` to its owned rows above `j`.
///
/// Per-element update order is descending in `j` regardless of the
/// partition, so results are bitwise identical across lane counts and
/// distributions (and agree with [`backward_dense`] to rounding, which
/// accumulates the same terms in the opposite order).
pub fn backward_dense_par(
    lu: &DenseMatrix,
    y: &[f64],
    schedule: &LaneSchedule,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    let n = check_dims(lu, y)?;
    if schedule.n() != n {
        return Err(EbvError::Shape("schedule size mismatch".into()));
    }
    let lanes = schedule.lanes();
    if lanes == 1 || n < 2 {
        return backward_dense(lu, y);
    }
    let mut x = y.to_vec();
    let x_ptr = SharedVec(x.as_mut_ptr());
    // Zero diagonal found by row j's owner — the heterogeneous stop
    // case the engine's break protocol exists for: only one lane sees
    // it, everyone halts on the same sub-step.
    let bad = Mutex::new(None::<usize>);

    engine.run_steps(lanes, 2 * n, |lane, step| {
        let j = n - 1 - step / 2;
        if step % 2 == 0 {
            // Divide sub-step: single writer, nobody reads x[j] until
            // the barrier publishes it.
            if schedule.owner(j) == lane {
                let d = lu.get(j, j);
                if d == 0.0 {
                    let mut slot = bad.lock().expect("diag slot");
                    if slot.is_none() {
                        *slot = Some(j);
                    }
                    return StepCtl::Break;
                }
                unsafe {
                    *x_ptr.0.add(j) /= d;
                }
            }
            StepCtl::Continue
        } else {
            // Axpy sub-step: x[j] is final; update owned rows above j.
            let xj = unsafe { *x_ptr.0.add(j) };
            for &i in schedule.upper_rows_of(lane, j) {
                let u_ij = lu.get(i, j);
                if u_ij != 0.0 {
                    unsafe {
                        *x_ptr.0.add(i) -= u_ij * xj;
                    }
                }
            }
            StepCtl::Continue
        }
    });

    if let Some(step) = bad.into_inner().expect("diag slot") {
        return Err(EbvError::SingularPivot { step, value: 0.0, tol: 0.0 });
    }
    Ok(x)
}

/// Wrapper making a raw pointer Send+Sync for disjoint-row lane writes.
struct SharedVec(*mut f64);
unsafe impl Send for SharedVec {}
unsafe impl Sync for SharedVec {}

// ---- sparse ----------------------------------------------------------------

/// Sparse forward substitution `L y = b` with `l` strictly lower
/// triangular (unit diagonal implicit).
pub fn sparse_forward_unit(l: &CsrMatrix, b: &[f64]) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let mut y = b.to_vec();
    for i in 0..l.rows() {
        let (cols, vals) = l.row(i);
        let mut acc = y[i];
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            debug_assert!(j < i, "L must be strictly lower triangular");
            acc -= v * y[j];
        }
        y[i] = acc;
    }
    Ok(y)
}

/// Sparse backward substitution `U x = y` with `u` upper triangular
/// including the diagonal.
pub fn sparse_backward(u: &CsrMatrix, y: &[f64]) -> Result<Vec<f64>> {
    if y.len() != u.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let n = u.rows();
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let (cols, vals) = u.row(i);
        let mut acc = x[i];
        let mut diag = 0.0;
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j == i {
                diag = v;
            } else {
                debug_assert!(j > i, "U must be upper triangular");
                acc -= v * x[j];
            }
        }
        if diag == 0.0 {
            return Err(EbvError::SingularPivot { step: i, value: 0.0, tol: 0.0 });
        }
        x[i] = acc / diag;
    }
    Ok(x)
}

/// Level schedule of a strictly-lower-triangular CSR matrix: rows in the
/// same level have no dependencies among themselves and can be solved in
/// parallel. Returns `(level_of_row, rows_by_level)` — the classic GPU
/// sparse-trisolve structure the paper's sparse speedups rely on.
pub fn levels_of_lower(l: &CsrMatrix) -> (Vec<usize>, Vec<Vec<usize>>) {
    let n = l.rows();
    let mut level = vec![0usize; n];
    let mut max_level = 0usize;
    for i in 0..n {
        let (cols, _) = l.row(i);
        let lv = cols.iter().map(|&j| level[j] + 1).max().unwrap_or(0);
        level[i] = lv;
        max_level = max_level.max(lv);
    }
    let mut by_level = vec![Vec::new(); max_level + 1];
    for (i, &lv) in level.iter().enumerate() {
        by_level[lv].push(i);
    }
    (level, by_level)
}

/// Level schedule of an upper-triangular CSR matrix (diagonal
/// included): the backward-substitution mirror of [`levels_of_lower`].
/// Row `i` depends on every `x[j]` with `j > i` present in its row, so
/// levels are computed bottom-up (`i = n-1 … 0`); rows within a level
/// are mutually independent and stored in descending row order (the
/// sequential sweep direction). Returns `(level_of_row, rows_by_level)`.
pub fn levels_of_upper(u: &CsrMatrix) -> (Vec<usize>, Vec<Vec<usize>>) {
    let n = u.rows();
    let mut level = vec![0usize; n];
    let mut max_level = 0usize;
    for i in (0..n).rev() {
        let (cols, _) = u.row(i);
        let lv = cols.iter().filter(|&&j| j > i).map(|&j| level[j] + 1).max().unwrap_or(0);
        level[i] = lv;
        max_level = max_level.max(lv);
    }
    let mut by_level = vec![Vec::new(); max_level + 1];
    for i in (0..n).rev() {
        by_level[level[i]].push(i);
    }
    (level, by_level)
}

/// Per-level work assignment for the engine job.
enum LevelChunks<'a> {
    /// Too small to split profitably: lane 0 walks the whole level in
    /// row order (borrowed — no per-solve copy of the level structure).
    Single(&'a [usize]),
    /// nnz-equalized chunks, one per lane.
    Split(Vec<Vec<usize>>),
}

/// Level-scheduled parallel sparse forward substitution as one engine
/// job: one barrier-separated step per level; within a level, rows are
/// split across `lanes` with nnz-equalized chunks (the EBV balance
/// criterion applied to sparse work). Small levels keep a single chunk
/// — lane 0 walks them in row order, so per-row arithmetic matches the
/// sequential solve exactly — and when *no* level is big enough to
/// split (long dependency chains), the whole solve keeps the seed's
/// zero-synchronization sequential path instead of paying a barrier
/// per level for nothing.
pub fn sparse_forward_unit_levels(
    l: &CsrMatrix,
    b: &[f64],
    by_level: &[Vec<usize>],
    lanes: usize,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    // Covers the sequential fall-throughs too (those record no span of
    // their own).
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    if lanes <= 1 {
        return sparse_forward_unit(l, b);
    }
    let chunks: Vec<LevelChunks<'_>> = by_level
        .iter()
        .map(|rows| {
            if rows.len() < lanes * 4 {
                LevelChunks::Single(rows)
            } else {
                LevelChunks::Split(equalize_rows_by_nnz(l, rows, lanes))
            }
        })
        .collect();
    if chunks.iter().all(|c| matches!(c, LevelChunks::Single(_))) {
        return sparse_forward_unit(l, b);
    }
    let mut y = b.to_vec();
    let y_ptr = SharedVec(y.as_mut_ptr());

    engine.run_steps(lanes, chunks.len(), |lane, level| {
        let chunk: Option<&[usize]> = match &chunks[level] {
            LevelChunks::Single(rows) => (lane == 0).then_some(*rows),
            LevelChunks::Split(cs) => cs.get(lane).map(Vec::as_slice),
        };
        if let Some(chunk) = chunk {
            for &i in chunk {
                let (cols, vals) = l.row(i);
                // Dependencies of row i live in earlier levels, whose
                // writes the step barrier has published.
                let mut acc = unsafe { *y_ptr.0.add(i) };
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    acc -= v * unsafe { *y_ptr.0.add(j) };
                }
                unsafe { *y_ptr.0.add(i) = acc };
            }
        }
        StepCtl::Continue
    });
    Ok(y)
}

/// Level-scheduled parallel sparse backward substitution `U x = y`,
/// mirroring [`sparse_forward_unit_levels`]: one barrier-separated step
/// per level of `by_level` (as computed by [`levels_of_upper`] — deep
/// rows first), nnz-equalized chunks within a level, single-chunk
/// fall-through for small levels, and the fully sequential path when no
/// level is worth splitting. Each row performs the exact op sequence of
/// [`sparse_backward`], so results are bitwise identical to the
/// sequential solve for every lane count and engine size.
///
/// A zero diagonal ends the job through the engine's break protocol
/// (only the affected row's lane sees it; everyone halts on the same
/// level) and reports `SingularPivot` — the step reported is the
/// lowest-level failing row, which may differ from the sequential
/// sweep's first-in-descending-order row when several diagonals are
/// zero.
pub fn sparse_backward_levels(
    u: &CsrMatrix,
    y: &[f64],
    by_level: &[Vec<usize>],
    lanes: usize,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    if y.len() != u.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    // Covers the sequential fall-throughs too (those record no span of
    // their own).
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    if lanes <= 1 {
        return sparse_backward(u, y);
    }
    let chunks: Vec<LevelChunks<'_>> = by_level
        .iter()
        .map(|rows| {
            if rows.len() < lanes * 4 {
                LevelChunks::Single(rows)
            } else {
                LevelChunks::Split(equalize_rows_by_nnz(u, rows, lanes))
            }
        })
        .collect();
    if chunks.iter().all(|c| matches!(c, LevelChunks::Single(_))) {
        return sparse_backward(u, y);
    }
    let mut x = y.to_vec();
    let x_ptr = SharedVec(x.as_mut_ptr());
    let bad = Mutex::new(None::<usize>);

    engine.run_steps(lanes, chunks.len(), |lane, level| {
        let chunk: Option<&[usize]> = match &chunks[level] {
            LevelChunks::Single(rows) => (lane == 0).then_some(*rows),
            LevelChunks::Split(cs) => cs.get(lane).map(Vec::as_slice),
        };
        if let Some(chunk) = chunk {
            for &i in chunk {
                let (cols, vals) = u.row(i);
                // Dependencies (j > i) live in earlier levels, whose
                // writes the step barrier has published.
                let mut acc = unsafe { *x_ptr.0.add(i) };
                let mut diag = 0.0;
                for (&j, &v) in cols.iter().zip(vals.iter()) {
                    if j == i {
                        diag = v;
                    } else {
                        debug_assert!(j > i, "U must be upper triangular");
                        acc -= v * unsafe { *x_ptr.0.add(j) };
                    }
                }
                if diag == 0.0 {
                    let mut slot = bad.lock().expect("diag slot");
                    if slot.is_none() {
                        *slot = Some(i);
                    }
                    return StepCtl::Break;
                }
                unsafe { *x_ptr.0.add(i) = acc / diag };
            }
        }
        StepCtl::Continue
    });

    if let Some(step) = bad.into_inner().expect("diag slot") {
        return Err(EbvError::SingularPivot { step, value: 0.0, tol: 0.0 });
    }
    Ok(x)
}

/// Dataflow parallel sparse forward substitution: one task per row
/// whose dependency counter is its `L`-row length (children are the
/// pattern transpose), self-scheduled by the engine's lanes — the
/// GPU-style self-scheduling trisolve, one barrier entry per solve
/// instead of one per level. Each row performs the exact op sequence of
/// [`sparse_forward_unit`] against dependencies its counters prove
/// finalized, so results are **bitwise identical** to the sequential
/// and level-stepped solves for every lane count and engine size.
/// Small systems (`n < lanes * 4`) and `lanes <= 1` keep the
/// sequential sweep, mirroring the level path's fall-through policy.
pub fn sparse_forward_unit_dataflow(
    l: &CsrMatrix,
    b: &[f64],
    lanes: usize,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    let n = l.rows();
    if lanes <= 1 || n < lanes * 4 {
        return sparse_forward_unit(l, b);
    }
    let mut graph = DepGraph::new(n);
    for i in 0..n {
        let (cols, _) = l.row(i);
        for &j in cols {
            debug_assert!(j < i, "L must be strictly lower triangular");
            graph.add_edge(j, i);
        }
    }
    let mut y = b.to_vec();
    let y_ptr = SharedVec(y.as_mut_ptr());

    run_dataflow(engine, &graph, |_worker, i| {
        let (cols, vals) = l.row(i);
        // SAFETY: row i is written by this task alone; every y[j] it
        // reads was finalized by a parent task and published through
        // the dep counters' AcqRel chain.
        let mut acc = unsafe { *y_ptr.0.add(i) };
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            acc -= v * unsafe { *y_ptr.0.add(j) };
        }
        unsafe { *y_ptr.0.add(i) = acc };
        StepCtl::Continue
    });
    Ok(y)
}

/// Dataflow parallel sparse backward substitution `U x = y`: the
/// bottom-up mirror of [`sparse_forward_unit_dataflow`] — row `i`
/// depends on every `x[j]`, `j > i`, in its `U` row. Bitwise identical
/// to [`sparse_backward`] for every lane count and engine size; same
/// sequential fall-throughs as the forward solve.
///
/// A zero diagonal stops the run through the scheduler's break
/// protocol; with several zero diagonals the **lowest failing row** is
/// reported (concurrent failures race, so the minimum is kept — the
/// level-stepped path's lowest-level row may differ, which callers
/// must not pin).
pub fn sparse_backward_dataflow(
    u: &CsrMatrix,
    y: &[f64],
    lanes: usize,
    engine: &LaneEngine,
) -> Result<Vec<f64>> {
    if y.len() != u.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    let n = u.rows();
    if lanes <= 1 || n < lanes * 4 {
        return sparse_backward(u, y);
    }
    let mut graph = DepGraph::new(n);
    for i in 0..n {
        let (cols, _) = u.row(i);
        for &j in cols.iter().filter(|&&j| j > i) {
            graph.add_edge(j, i);
        }
    }
    let mut x = y.to_vec();
    let x_ptr = SharedVec(x.as_mut_ptr());
    let bad = Mutex::new(None::<usize>);

    run_dataflow(engine, &graph, |_worker, i| {
        let (cols, vals) = u.row(i);
        // SAFETY: as the forward solve — exclusive write to x[i],
        // finalized reads of x[j > i].
        let mut acc = unsafe { *x_ptr.0.add(i) };
        let mut diag = 0.0;
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            if j == i {
                diag = v;
            } else {
                debug_assert!(j > i, "U must be upper triangular");
                acc -= v * unsafe { *x_ptr.0.add(j) };
            }
        }
        if diag == 0.0 {
            let mut slot = bad.lock().expect("diag slot");
            if slot.map_or(true, |s| i < s) {
                *slot = Some(i);
            }
            return StepCtl::Break;
        }
        unsafe { *x_ptr.0.add(i) = acc / diag };
        StepCtl::Continue
    });

    if let Some(step) = bad.into_inner().expect("diag slot") {
        return Err(EbvError::SingularPivot { step, value: 0.0, tol: 0.0 });
    }
    Ok(x)
}

/// Per-level work assignment of a device-sharded solve: rows of a
/// level go first to devices, then to vlanes within a device — both
/// splits nnz-equalized and order-preserving, so each row's op
/// sequence (and therefore every bit of the result) is unchanged.
enum ShardedChunks<'a> {
    /// Too small to shard: device 0's vlane 0 walks the level in row
    /// order (bitwise the sequential sweep).
    Single(&'a [usize]),
    /// `chunks[device][vlane]` row lists.
    Split(Vec<Vec<Vec<usize>>>),
}

/// Build the per-level sharded chunking shared by the forward and
/// backward device solves: a level splits only when it has at least
/// `4` rows per virtual lane (the flat policy lifted to the total
/// vlane count). Returns `None` when *no* level is worth sharding —
/// the caller keeps the zero-synchronization sequential path.
fn sharded_level_chunks<'a>(
    m: &CsrMatrix,
    by_level: &'a [Vec<usize>],
    devices: usize,
    lanes_per_device: usize,
) -> Option<Vec<ShardedChunks<'a>>> {
    let total = devices * lanes_per_device;
    let chunks: Vec<ShardedChunks<'a>> = by_level
        .iter()
        .map(|rows| {
            if rows.len() < total * 4 {
                ShardedChunks::Single(rows)
            } else {
                ShardedChunks::Split(
                    equalize_rows_by_nnz(m, rows, devices)
                        .into_iter()
                        .map(|dev_rows| equalize_rows_by_nnz(m, &dev_rows, lanes_per_device))
                        .collect(),
                )
            }
        })
        .collect();
    chunks.iter().any(|c| matches!(c, ShardedChunks::Split(_))).then_some(chunks)
}

impl ShardedChunks<'_> {
    /// Rows a given (device, vlane) walks at this level.
    fn rows_of(&self, dev: usize, vlane: usize) -> Option<&[usize]> {
        match self {
            ShardedChunks::Single(rows) => (dev == 0 && vlane == 0).then_some(*rows),
            ShardedChunks::Split(cs) => {
                cs.get(dev).and_then(|d| d.get(vlane)).map(Vec::as_slice)
            }
        }
    }
}

/// Device-sharded level-scheduled sparse forward substitution: one
/// sharded step per level on a [`DeviceSet`], rows dealt devices-first
/// with nnz-equalized chunks, the previous level's results accounted as
/// the per-step exchange broadcast. Bitwise identical to
/// [`sparse_forward_unit`] — each row performs the exact sequential op
/// sequence — for every device count, lane count and engine size. A
/// single-device set falls through to the flat engine path.
pub fn sparse_forward_unit_levels_sharded(
    l: &CsrMatrix,
    b: &[f64],
    by_level: &[Vec<usize>],
    lanes: usize,
    set: &DeviceSet,
) -> Result<Vec<f64>> {
    if b.len() != l.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let d = set.devices();
    if d <= 1 {
        // Falls through to the flat levels solve, which records its own
        // Trisolve span — so this timer starts after the branch.
        return sparse_forward_unit_levels(l, b, by_level, lanes, set.engine(0).as_ref());
    }
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    let lpd = lanes.div_ceil(d).max(1);
    let Some(chunks) = sharded_level_chunks(l, by_level, d, lpd) else {
        return sparse_forward_unit(l, b);
    };
    let mut y = b.to_vec();
    let y_ptr = SharedVec(y.as_mut_ptr());

    set.run_sharded(
        lpd,
        chunks.len(),
        |level| {
            if level > 0 {
                // The previous level's solved entries travel to every
                // device before this level reads them.
                set.record_exchange(by_level[level - 1].len());
            }
            StepCtl::Continue
        },
        |dev, vlane, level| {
            if let Some(chunk) = chunks[level].rows_of(dev, vlane) {
                for &i in chunk {
                    let (cols, vals) = l.row(i);
                    // Dependencies live in earlier levels, published by
                    // the cross-device step barrier.
                    let mut acc = unsafe { *y_ptr.0.add(i) };
                    for (&j, &v) in cols.iter().zip(vals.iter()) {
                        acc -= v * unsafe { *y_ptr.0.add(j) };
                    }
                    unsafe { *y_ptr.0.add(i) = acc };
                }
            }
            StepCtl::Continue
        },
    );
    Ok(y)
}

/// Device-sharded level-scheduled sparse backward substitution, the
/// mirror of [`sparse_forward_unit_levels_sharded`] over `U`'s levels
/// (as computed by [`levels_of_upper`]). Bitwise identical to
/// [`sparse_backward`] for every device count; a zero diagonal ends
/// the job through the sharded break protocol (all devices stop on the
/// same level) and reports `SingularPivot`.
pub fn sparse_backward_levels_sharded(
    u: &CsrMatrix,
    y: &[f64],
    by_level: &[Vec<usize>],
    lanes: usize,
    set: &DeviceSet,
) -> Result<Vec<f64>> {
    if y.len() != u.rows() {
        return Err(EbvError::Shape("rhs length mismatch".into()));
    }
    let d = set.devices();
    if d <= 1 {
        // The flat levels solve records its own Trisolve span.
        return sparse_backward_levels(u, y, by_level, lanes, set.engine(0).as_ref());
    }
    let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Trisolve);
    let lpd = lanes.div_ceil(d).max(1);
    let Some(chunks) = sharded_level_chunks(u, by_level, d, lpd) else {
        return sparse_backward(u, y);
    };
    let mut x = y.to_vec();
    let x_ptr = SharedVec(x.as_mut_ptr());
    let bad = Mutex::new(None::<usize>);

    set.run_sharded(
        lpd,
        chunks.len(),
        |level| {
            if level > 0 {
                set.record_exchange(by_level[level - 1].len());
            }
            StepCtl::Continue
        },
        |dev, vlane, level| {
            if let Some(chunk) = chunks[level].rows_of(dev, vlane) {
                for &i in chunk {
                    let (cols, vals) = u.row(i);
                    let mut acc = unsafe { *x_ptr.0.add(i) };
                    let mut diag = 0.0;
                    for (&j, &v) in cols.iter().zip(vals.iter()) {
                        if j == i {
                            diag = v;
                        } else {
                            debug_assert!(j > i, "U must be upper triangular");
                            acc -= v * unsafe { *x_ptr.0.add(j) };
                        }
                    }
                    if diag == 0.0 {
                        let mut slot = bad.lock().expect("diag slot");
                        if slot.is_none() {
                            *slot = Some(i);
                        }
                        return StepCtl::Break;
                    }
                    unsafe { *x_ptr.0.add(i) = acc / diag };
                }
            }
            StepCtl::Continue
        },
    );

    if let Some(step) = bad.into_inner().expect("diag slot") {
        return Err(EbvError::SingularPivot { step, value: 0.0, tol: 0.0 });
    }
    Ok(x)
}

/// Split `rows` into `lanes` chunks with near-equal total nnz (greedy,
/// preserving order within a chunk).
fn equalize_rows_by_nnz(m: &CsrMatrix, rows: &[usize], lanes: usize) -> Vec<Vec<usize>> {
    let total: usize = rows.iter().map(|&i| m.row_nnz(i).max(1)).sum();
    let target = total.div_ceil(lanes);
    let mut chunks = Vec::with_capacity(lanes);
    let mut cur = Vec::new();
    let mut acc = 0usize;
    for &i in rows {
        cur.push(i);
        acc += m.row_nnz(i).max(1);
        if acc >= target && chunks.len() + 1 < lanes {
            chunks.push(std::mem::take(&mut cur));
            acc = 0;
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ebv::schedule::{LaneSchedule, RowDist};
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};
    use crate::matrix::norms::diff_inf;
    use crate::solver::sparse_lu::SparseLu;
    use crate::solver::{LuSolver, SeqLu};

    fn engine() -> &'static LaneEngine {
        crate::exec::global()
    }

    #[test]
    fn forward_backward_on_hand_case() {
        // L = [[1,0],[2,1]], U = [[3,1],[0,4]] packed:
        let lu = DenseMatrix::from_rows(&[&[3.0, 1.0], &[2.0, 4.0]]).unwrap();
        // Solve L y = [3, 10]: y = [3, 4]; U x = y: x2 = 1, x1 = (3-1)/3.
        let y = forward_unit_dense(&lu, &[3.0, 10.0]).unwrap();
        assert_eq!(y, vec![3.0, 4.0]);
        let x = backward_dense(&lu, &y).unwrap();
        assert!((x[1] - 1.0).abs() < 1e-15);
        assert!((x[0] - 2.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn dims_validated() {
        let lu = DenseMatrix::zeros(3, 3);
        assert!(forward_unit_dense(&lu, &[1.0, 2.0]).is_err());
        let rect = DenseMatrix::zeros(2, 3);
        assert!(backward_dense(&rect, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn backward_detects_zero_diagonal() {
        let lu = DenseMatrix::from_rows(&[&[0.0, 1.0], &[0.0, 1.0]]).unwrap();
        assert!(matches!(
            backward_dense(&lu, &[1.0, 1.0]),
            Err(EbvError::SingularPivot { .. })
        ));
    }

    #[test]
    fn parallel_forward_matches_sequential() {
        let a = diag_dominant_dense(64, GenSeed(11));
        let f = SeqLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..64).map(|i| (i as f64).sin()).collect();
        let seq = forward_unit_dense(f.packed(), &b).unwrap();
        for dist in RowDist::ALL {
            for lanes in [1usize, 2, 4] {
                let sched = LaneSchedule::build(64, lanes, dist);
                let par = forward_unit_dense_par(f.packed(), &b, &sched, engine()).unwrap();
                assert!(
                    diff_inf(&seq, &par) < 1e-12,
                    "{dist:?} lanes={lanes}"
                );
            }
        }
    }

    #[test]
    fn parallel_backward_matches_sequential() {
        let a = diag_dominant_dense(64, GenSeed(16));
        let f = SeqLu::new().factor(&a).unwrap();
        let y: Vec<f64> = (0..64).map(|i| (i as f64 * 0.7).cos()).collect();
        let seq = backward_dense(f.packed(), &y).unwrap();
        for dist in RowDist::ALL {
            for lanes in [1usize, 2, 4] {
                let sched = LaneSchedule::build(64, lanes, dist);
                let par = backward_dense_par(f.packed(), &y, &sched, engine()).unwrap();
                assert!(
                    diff_inf(&seq, &par) < 1e-11,
                    "{dist:?} lanes={lanes}: diff {}",
                    diff_inf(&seq, &par)
                );
            }
        }
    }

    #[test]
    fn parallel_backward_bitwise_stable_across_lane_counts() {
        // The per-element update order is fixed by the column sweep, not
        // the partition — any lane count gives identical bits.
        let a = diag_dominant_dense(48, GenSeed(17));
        let f = SeqLu::new().factor(&a).unwrap();
        let y: Vec<f64> = (0..48).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let sched2 = LaneSchedule::build(48, 2, RowDist::EbvFold);
        let reference = backward_dense_par(f.packed(), &y, &sched2, engine()).unwrap();
        for lanes in [3usize, 5, 8] {
            for dist in RowDist::ALL {
                let sched = LaneSchedule::build(48, lanes, dist);
                let par = backward_dense_par(f.packed(), &y, &sched, engine()).unwrap();
                assert_eq!(diff_inf(&reference, &par), 0.0, "{dist:?} lanes={lanes}");
            }
        }
    }

    #[test]
    fn parallel_backward_detects_zero_diagonal() {
        let mut lu = diag_dominant_dense(32, GenSeed(18));
        lu.set(20, 20, 0.0);
        let y = vec![1.0; 32];
        let sched = LaneSchedule::build(32, 4, RowDist::Cyclic);
        let err = backward_dense_par(&lu, &y, &sched, engine());
        assert!(
            matches!(err, Err(EbvError::SingularPivot { step: 20, .. })),
            "{err:?}"
        );
    }

    #[test]
    fn sparse_solves_match_dense() {
        let a = diag_dominant_sparse(40, 4, GenSeed(12));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..40).map(|i| 1.0 + i as f64 * 0.1).collect();
        let y = sparse_forward_unit(f.l(), &b).unwrap();
        let yd = forward_unit_dense(
            &{
                // pack L+U densely for the oracle
                let mut lu = f.u().to_dense();
                let ld = f.l().to_dense();
                for i in 0..40 {
                    for j in 0..i {
                        lu.set(i, j, ld.get(i, j));
                    }
                }
                lu
            },
            &b,
        )
        .unwrap();
        assert!(diff_inf(&y, &yd) < 1e-12);
        let x = sparse_backward(f.u(), &y).unwrap();
        assert!(a.residual(&x, &b) < 1e-9);
    }

    #[test]
    fn levels_respect_dependencies() {
        let a = diag_dominant_sparse(50, 4, GenSeed(13));
        let f = SparseLu::new().factor(&a).unwrap();
        let (level, by_level) = levels_of_lower(f.l());
        // Every dependency j of row i satisfies level[j] < level[i].
        for i in 0..50 {
            let (cols, _) = f.l().row(i);
            for &j in cols {
                assert!(level[j] < level[i]);
            }
        }
        // Levels partition rows.
        let total: usize = by_level.iter().map(|v| v.len()).sum();
        assert_eq!(total, 50);
    }

    #[test]
    fn level_scheduled_solve_matches_sequential() {
        let a = diag_dominant_sparse(80, 5, GenSeed(14));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..80).map(|i| (i as f64 * 0.3).cos()).collect();
        let (_, by_level) = levels_of_lower(f.l());
        let seq = sparse_forward_unit(f.l(), &b).unwrap();
        for lanes in [1usize, 2, 4] {
            let par =
                sparse_forward_unit_levels(f.l(), &b, &by_level, lanes, engine()).unwrap();
            assert!(diff_inf(&seq, &par) < 1e-12, "lanes={lanes}");
        }
    }

    #[test]
    fn upper_levels_respect_dependencies() {
        let a = diag_dominant_sparse(50, 4, GenSeed(19));
        let f = SparseLu::new().factor(&a).unwrap();
        let (level, by_level) = levels_of_upper(f.u());
        // Every dependency j > i of row i satisfies level[j] < level[i].
        for i in 0..50 {
            let (cols, _) = f.u().row(i);
            for &j in cols.iter().filter(|&&j| j > i) {
                assert!(level[j] < level[i], "row {i} dep {j}");
            }
        }
        // Levels partition rows; the last row has no deps -> level 0.
        let total: usize = by_level.iter().map(Vec::len).sum();
        assert_eq!(total, 50);
        assert_eq!(level[49], 0);
    }

    #[test]
    fn level_scheduled_backward_matches_sequential_bitwise() {
        let a = diag_dominant_sparse(90, 5, GenSeed(20));
        let f = SparseLu::new().factor(&a).unwrap();
        let y: Vec<f64> = (0..90).map(|i| (i as f64 * 0.4).sin()).collect();
        let (_, by_level) = levels_of_upper(f.u());
        let seq = sparse_backward(f.u(), &y).unwrap();
        for lanes in [1usize, 2, 4, 7] {
            for engine_lanes in [1usize, 2, 3] {
                let engine = LaneEngine::new(engine_lanes);
                let par =
                    sparse_backward_levels(f.u(), &y, &by_level, lanes, &engine).unwrap();
                assert_eq!(par, seq, "lanes={lanes} engine={engine_lanes}");
            }
        }
    }

    #[test]
    fn level_scheduled_backward_detects_zero_diagonal() {
        // Diagonal U with one zero: no dependencies, so all eight rows
        // share level 0 — big enough that two lanes split the level and
        // the zero diagonal is found on the engine path.
        let mut vals = vec![2.0; 8];
        vals[5] = 0.0;
        let u =
            CsrMatrix::from_raw(8, 8, (0..=8).collect(), (0..8).collect(), vals).unwrap();
        let (_, by_level) = levels_of_upper(&u);
        assert_eq!(by_level.len(), 1);
        let err = sparse_backward_levels(&u, &[1.0; 8], &by_level, 2, engine());
        assert!(
            matches!(err, Err(EbvError::SingularPivot { step: 5, .. })),
            "{err:?}"
        );
    }

    #[test]
    fn dataflow_solves_are_bitwise_sequential() {
        // Self-scheduled rows replace the level barriers; per-row op
        // sequences are unchanged, so both substitutions reproduce the
        // sequential bits for every lane count and engine size.
        let a = diag_dominant_sparse(90, 5, GenSeed(23));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..90).map(|i| (i as f64 * 0.5).sin()).collect();
        let seq_y = sparse_forward_unit(f.l(), &b).unwrap();
        let seq_x = sparse_backward(f.u(), &seq_y).unwrap();
        for lanes in [2usize, 4, 7] {
            for engine_lanes in [1usize, 2, 3] {
                let engine = LaneEngine::new(engine_lanes);
                let y = sparse_forward_unit_dataflow(f.l(), &b, lanes, &engine).unwrap();
                assert_eq!(y, seq_y, "fwd lanes={lanes} engine={engine_lanes}");
                let x = sparse_backward_dataflow(f.u(), &y, lanes, &engine).unwrap();
                assert_eq!(x, seq_x, "bwd lanes={lanes} engine={engine_lanes}");
            }
        }
        // lanes <= 1 and tiny systems keep the sequential sweep.
        let y = sparse_forward_unit_dataflow(f.l(), &b, 1, engine()).unwrap();
        assert_eq!(y, seq_y);
    }

    #[test]
    fn dataflow_solves_cost_one_engine_step_each() {
        let a = diag_dominant_sparse(90, 5, GenSeed(24));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..90).map(|i| (i as f64 * 0.7).cos()).collect();
        let engine = LaneEngine::new(3);
        let before = engine.stats();
        let dep_before = engine.dep_stats();
        let y = sparse_forward_unit_dataflow(f.l(), &b, 4, &engine).unwrap();
        sparse_backward_dataflow(f.u(), &y, 4, &engine).unwrap();
        let after = engine.stats();
        let dep_after = engine.dep_stats();
        assert_eq!(after.steps - before.steps, 2, "one barrier entry per solve");
        assert_eq!(dep_after.runs - dep_before.runs, 2);
    }

    #[test]
    fn dataflow_backward_detects_zero_diagonal() {
        // Diagonal U (no deps, all rows ready at once) with one zero —
        // big enough for the dataflow path to engage on 2 lanes.
        let mut vals = vec![2.0; 16];
        vals[5] = 0.0;
        let u =
            CsrMatrix::from_raw(16, 16, (0..=16).collect(), (0..16).collect(), vals).unwrap();
        let err = sparse_backward_dataflow(&u, &[1.0; 16], 2, &LaneEngine::new(2));
        assert!(
            matches!(err, Err(EbvError::SingularPivot { step: 5, .. })),
            "{err:?}"
        );
    }

    #[test]
    fn sharded_level_solves_are_bitwise_sequential() {
        let a = diag_dominant_sparse(120, 5, GenSeed(21));
        let f = SparseLu::new().factor(&a).unwrap();
        let b: Vec<f64> = (0..120).map(|i| (i as f64 * 0.6).sin()).collect();
        let (_, fwd_levels) = levels_of_lower(f.l());
        let (_, bwd_levels) = levels_of_upper(f.u());
        let seq_y = sparse_forward_unit(f.l(), &b).unwrap();
        let seq_x = sparse_backward(f.u(), &seq_y).unwrap();
        for devices in [1usize, 2, 4] {
            let set = DeviceSet::new(devices, 2);
            let y =
                sparse_forward_unit_levels_sharded(f.l(), &b, &fwd_levels, 4, &set).unwrap();
            assert_eq!(y, seq_y, "forward devices={devices}");
            let x =
                sparse_backward_levels_sharded(f.u(), &y, &bwd_levels, 4, &set).unwrap();
            assert_eq!(x, seq_x, "backward devices={devices}");
        }
    }

    #[test]
    fn sharded_backward_detects_zero_diagonal() {
        // Diagonal U with one zero: all rows share level 0, large
        // enough (16 >= 2*2*4) that the sharded path engages.
        let n = 16;
        let mut vals = vec![2.0; n];
        vals[11] = 0.0;
        let u = CsrMatrix::from_raw(n, n, (0..=n).collect(), (0..n).collect(), vals).unwrap();
        let (_, by_level) = levels_of_upper(&u);
        assert_eq!(by_level.len(), 1);
        let set = DeviceSet::new(2, 2);
        let err = sparse_backward_levels_sharded(&u, &vec![1.0; n], &by_level, 2, &set);
        assert!(
            matches!(err, Err(EbvError::SingularPivot { step: 11, .. })),
            "{err:?}"
        );
    }

    #[test]
    fn nnz_chunks_cover_all_rows() {
        let a = diag_dominant_sparse(30, 3, GenSeed(15));
        let rows: Vec<usize> = (0..30).collect();
        let chunks = equalize_rows_by_nnz(&a, &rows, 4);
        let mut all: Vec<usize> = chunks.concat();
        all.sort_unstable();
        assert_eq!(all, rows);
        assert!(chunks.len() <= 4);
    }
}
