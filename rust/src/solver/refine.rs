//! Iterative refinement on top of any LU factorization.
//!
//! One factorization, repeated cheap solves: `x ← x + A⁻¹(b − A x)`.
//! Recovers accuracy lost to dropped fill (`SparseLu::with_drop_tol`) or
//! to the f32 PJRT artifacts (the runtime path solves in f32; refinement
//! against the f64 matrix restores f64-level residuals — this is how the
//! end-to-end example composes the compiled kernels with the rust side).

use crate::matrix::norms::{norm2, rel_residual_dense};
use crate::matrix::DenseMatrix;
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::Result;

/// Refinement report: iterations taken and final relative residual.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RefineReport {
    pub iterations: usize,
    pub rel_residual: f64,
    pub converged: bool,
}

/// A solver wrapped with iterative refinement.
pub struct Refined<S: LuSolver> {
    inner: S,
    max_iters: usize,
    tol: f64,
}

impl<S: LuSolver> Refined<S> {
    pub fn new(inner: S) -> Self {
        Refined { inner, max_iters: 10, tol: 1e-12 }
    }

    pub fn max_iters(mut self, iters: usize) -> Self {
        self.max_iters = iters;
        self
    }

    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Solve with refinement, returning the solution and a report.
    pub fn solve_reported(&self, a: &DenseMatrix, b: &[f64]) -> Result<(Vec<f64>, RefineReport)> {
        let factors = self.inner.factor(a)?;
        refine_with_factors(&factors, a, b, self.max_iters, self.tol)
    }
}

impl<S: LuSolver> LuSolver for Refined<S> {
    fn name(&self) -> &'static str {
        "refined"
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        self.inner.factor(a)
    }

    fn solve(&self, a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
        Ok(self.solve_reported(a, b)?.0)
    }
}

/// Refine `x` from existing factors against the *original* matrix `a`
/// (which may be more accurate than what was factored — e.g. f64 matrix
/// vs f32-computed factors).
pub fn refine_with_factors(
    factors: &DenseLuFactors,
    a: &DenseMatrix,
    b: &[f64],
    max_iters: usize,
    tol: f64,
) -> Result<(Vec<f64>, RefineReport)> {
    let mut x = factors.solve(b)?;
    let nb = norm2(b).max(f64::MIN_POSITIVE);
    let mut report = RefineReport {
        iterations: 0,
        rel_residual: rel_residual_dense(a, &x, b),
        converged: false,
    };
    for it in 0..max_iters {
        if report.rel_residual <= tol {
            report.converged = true;
            break;
        }
        let ax = a.matvec(&x)?;
        let r: Vec<f64> = b.iter().zip(ax.iter()).map(|(bb, aa)| bb - aa).collect();
        // Stagnation guard: residual no longer improving in norm.
        if norm2(&r) / nb >= report.rel_residual && it > 0 {
            break;
        }
        let dx = factors.solve(&r)?;
        for (xi, di) in x.iter_mut().zip(dx.iter()) {
            *xi += di;
        }
        report.iterations = it + 1;
        report.rel_residual = rel_residual_dense(a, &x, b);
    }
    report.converged = report.rel_residual <= tol;
    Ok((x, report))
}

/// Refine a solution obtained externally (e.g. from the f32 PJRT
/// artifact) using a freshly factored f64 system.
pub fn refine_external_solution(
    solver: &dyn LuSolver,
    a: &DenseMatrix,
    b: &[f64],
    x0: &[f64],
    max_iters: usize,
    tol: f64,
) -> Result<(Vec<f64>, RefineReport)> {
    let factors = solver.factor(a)?;
    let mut x = x0.to_vec();
    let mut report = RefineReport {
        iterations: 0,
        rel_residual: rel_residual_dense(a, &x, b),
        converged: false,
    };
    for it in 0..max_iters {
        if report.rel_residual <= tol {
            break;
        }
        let ax = a.matvec(&x)?;
        let r: Vec<f64> = b.iter().zip(ax.iter()).map(|(bb, aa)| bb - aa).collect();
        let dx = factors.solve(&r)?;
        for (xi, di) in x.iter_mut().zip(dx.iter()) {
            *xi += di;
        }
        report.iterations = it + 1;
        report.rel_residual = rel_residual_dense(a, &x, b);
    }
    report.converged = report.rel_residual <= tol;
    Ok((x, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::solver::SeqLu;

    #[test]
    fn exact_solver_converges_immediately() {
        let a = diag_dominant_dense(40, GenSeed(61));
        let b = rhs(40, GenSeed(62));
        let (x, rep) = Refined::new(SeqLu::new()).solve_reported(&a, &b).unwrap();
        assert!(rep.converged);
        assert!(rep.iterations <= 1, "{rep:?}");
        assert!(a.residual(&x, &b) < 1e-10);
    }

    #[test]
    fn recovers_f32_degraded_solution() {
        let n = 60;
        let a = diag_dominant_dense(n, GenSeed(63));
        let b = rhs(n, GenSeed(64));
        // Simulate the f32 artifact path: solve in f32 precision.
        let exact = SeqLu::new().solve(&a, &b).unwrap();
        let x0: Vec<f64> = exact.iter().map(|&v| v as f32 as f64).collect();
        let degraded = rel_residual_dense(&a, &x0, &b);
        assert!(degraded > 1e-9, "f32 rounding should be visible: {degraded}");
        let (x, rep) =
            refine_external_solution(&SeqLu::new(), &a, &b, &x0, 5, 1e-13).unwrap();
        assert!(rep.converged, "{rep:?}");
        assert!(rel_residual_dense(&a, &x, &b) < 1e-13);
    }

    #[test]
    fn report_residual_is_the_returned_solutions_residual() {
        // The contract callers lean on: `rel_residual` in the report is
        // exactly the relative residual of the `x` handed back (not the
        // pre-correction one), and `converged` is `rel_residual <= tol`.
        let a = diag_dominant_dense(32, GenSeed(71));
        let b = rhs(32, GenSeed(72));
        for tol in [1e-12, 0.0] {
            let (x, rep) =
                Refined::new(SeqLu::new()).tol(tol).solve_reported(&a, &b).unwrap();
            assert_eq!(rep.rel_residual, rel_residual_dense(&a, &x, &b), "tol={tol}");
            assert_eq!(rep.converged, rep.rel_residual <= tol, "tol={tol}");
        }
    }

    #[test]
    fn refinement_tightens_f32_degraded_factors() {
        // Factors rounded through f32 start ~1e-7; refinement against
        // the f64 matrix must pull the residual back under 1e-12 and
        // report strict improvement over iteration zero.
        let n = 48;
        let a = diag_dominant_dense(n, GenSeed(73));
        let b = rhs(n, GenSeed(74));
        let exact = SeqLu::new().factor(&a).unwrap();
        let mut lu = exact.packed().clone();
        for i in 0..n {
            for j in 0..n {
                lu.set(i, j, lu.get(i, j) as f32 as f64);
            }
        }
        let degraded = DenseLuFactors::new(lu, exact.perm().clone());
        let x0 = degraded.solve(&b).unwrap();
        let start = rel_residual_dense(&a, &x0, &b);
        assert!(start > 1e-11, "f32 factors should be visibly off: {start}");
        let (x, rep) = refine_with_factors(&degraded, &a, &b, 10, 1e-12).unwrap();
        assert!(rep.converged, "{rep:?}");
        assert!(rep.iterations >= 1, "{rep:?}");
        assert!(rep.rel_residual < start, "{rep:?} vs {start}");
        assert!(rel_residual_dense(&a, &x, &b) <= 1e-12);
    }

    #[test]
    fn respects_iteration_cap() {
        let a = diag_dominant_dense(20, GenSeed(65));
        let b = rhs(20, GenSeed(66));
        let (_, rep) = Refined::new(SeqLu::new())
            .max_iters(0)
            .tol(0.0)
            .solve_reported(&a, &b)
            .unwrap();
        assert_eq!(rep.iterations, 0);
        assert!(!rep.converged); // tol 0.0 unreachable
    }

    #[test]
    fn lusolver_impl_delegates() {
        let a = diag_dominant_dense(15, GenSeed(67));
        let b = rhs(15, GenSeed(68));
        let r = Refined::new(SeqLu::new());
        let x = LuSolver::solve(&r, &a, &b).unwrap();
        assert!(a.residual(&x, &b) < 1e-10);
        assert_eq!(r.name(), "refined");
    }
}
