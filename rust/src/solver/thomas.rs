//! Thomas algorithm: the O(n) tridiagonal fast path.
//!
//! CFD codes (the paper's motivating domain) spend much of their time in
//! 1-D implicit sweeps — tridiagonal systems where general LU is wasteful.
//! The router can short-circuit banded systems with `kl = ku = 1` here.
//! No pivoting: diagonal dominance (Peclet < 2 in the convection-
//! diffusion generator) is the usual CFD guarantee.

use crate::matrix::BandedMatrix;
use crate::util::error::{EbvError, Result};

/// Factored tridiagonal system (the forward-sweep coefficients), ready
/// for repeated O(n) solves — the same factor-once/solve-many shape as
/// the LU paths.
#[derive(Debug, Clone)]
pub struct ThomasFactors {
    /// Modified upper diagonal c'.
    cp: Vec<f64>,
    /// Original sub/main diagonals needed by the solve sweep.
    sub: Vec<f64>,
    diag_mod: Vec<f64>,
}

impl ThomasFactors {
    pub fn n(&self) -> usize {
        self.diag_mod.len()
    }

    /// Solve against a right-hand side.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.n();
        if b.len() != n {
            return Err(EbvError::Shape("rhs length mismatch".into()));
        }
        let mut d = vec![0.0; n];
        // Forward sweep on the RHS with the cached coefficients.
        d[0] = b[0] / self.diag_mod[0];
        for i in 1..n {
            d[i] = (b[i] - self.sub[i - 1] * d[i - 1]) / self.diag_mod[i];
        }
        // Back substitution.
        let mut x = d;
        for i in (0..n - 1).rev() {
            let next = x[i + 1];
            x[i] -= self.cp[i] * next;
        }
        Ok(x)
    }
}

/// Factor a tridiagonal matrix (as a `BandedMatrix` with `kl = ku = 1`).
pub fn thomas_factor(m: &BandedMatrix) -> Result<ThomasFactors> {
    if m.kl() != 1 || m.ku() != 1 {
        return Err(EbvError::Shape(format!(
            "Thomas needs a tridiagonal matrix, got kl={} ku={}",
            m.kl(),
            m.ku()
        )));
    }
    let n = m.n();
    if n == 0 {
        return Err(EbvError::Shape("empty system".into()));
    }
    let mut cp = vec![0.0; n.saturating_sub(1)];
    let mut diag_mod = vec![0.0; n];
    let mut sub = vec![0.0; n.saturating_sub(1)];

    let tol = 1e-12;
    let d0 = m.get(0, 0);
    if d0.abs() < tol {
        return Err(EbvError::SingularPivot { step: 0, value: d0, tol });
    }
    diag_mod[0] = d0;
    if n > 1 {
        cp[0] = m.get(0, 1) / d0;
    }
    for i in 1..n {
        let a_i = m.get(i, i - 1);
        sub[i - 1] = a_i;
        let denom = m.get(i, i) - a_i * cp[i - 1];
        if denom.abs() < tol {
            return Err(EbvError::SingularPivot { step: i, value: denom, tol });
        }
        diag_mod[i] = denom;
        if i + 1 < n {
            cp[i] = m.get(i, i + 1) / denom;
        }
    }
    Ok(ThomasFactors { cp, sub, diag_mod })
}

/// Factor + solve in one call.
pub fn thomas_solve(m: &BandedMatrix, b: &[f64]) -> Result<Vec<f64>> {
    thomas_factor(m)?.solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::convection_diffusion_1d;
    use crate::matrix::norms::diff_inf;
    use crate::solver::{LuSolver, SeqLu};

    #[test]
    fn matches_dense_lu() {
        let n = 64;
        let m = convection_diffusion_1d(n, 0.8);
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
        let x = thomas_solve(&m, &b).unwrap();
        let xd = SeqLu::new().solve(&m.to_dense(), &b).unwrap();
        assert!(diff_inf(&x, &xd) < 1e-10);
        assert!(m.to_dense().residual(&x, &b) < 1e-10);
    }

    #[test]
    fn hand_case_3x3() {
        // [2 1 0; 1 2 1; 0 1 2] x = [3, 4, 3] -> x = [1, 1, 1]
        let m = BandedMatrix::tridiagonal(&[1.0, 1.0], &[2.0, 2.0, 2.0], &[1.0, 1.0]).unwrap();
        let x = thomas_solve(&m, &[3.0, 4.0, 3.0]).unwrap();
        assert!(diff_inf(&x, &[1.0, 1.0, 1.0]) < 1e-14);
    }

    #[test]
    fn factor_once_solve_many() {
        let n = 32;
        let m = convection_diffusion_1d(n, 0.5);
        let f = thomas_factor(&m).unwrap();
        for seed in 0..5u64 {
            let b: Vec<f64> = (0..n).map(|i| ((i as u64 + seed) as f64 * 0.3).cos()).collect();
            let x = f.solve(&b).unwrap();
            assert!(m.to_dense().residual(&x, &b) < 1e-10, "seed {seed}");
        }
    }

    #[test]
    fn rejects_wrong_bandwidth() {
        let m = BandedMatrix::zeros(8, 2, 1).unwrap();
        assert!(thomas_factor(&m).is_err());
    }

    #[test]
    fn detects_singular_pivot() {
        let m = BandedMatrix::tridiagonal(&[1.0], &[0.0, 1.0], &[1.0]).unwrap();
        assert!(matches!(thomas_factor(&m), Err(EbvError::SingularPivot { step: 0, .. })));
    }

    #[test]
    fn two_element_system() {
        // (n=1 is unrepresentable as a kl=ku=1 BandedMatrix by design.)
        let m = BandedMatrix::tridiagonal(&[1.0], &[4.0, 4.0], &[1.0]).unwrap();
        let x = thomas_solve(&m, &[5.0, 5.0]).unwrap();
        assert!(diff_inf(&x, &[1.0, 1.0]) < 1e-14);
    }
}
