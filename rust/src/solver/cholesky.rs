//! Cholesky factorization for SPD systems (A = L Lᵀ).
//!
//! The Poisson pressure systems the CFD examples produce are symmetric
//! positive definite; Cholesky halves the flops and storage relative to
//! LU. Included as the "exploit structure" comparator the evaluation
//! section contrasts against the general EBV path, and as a correctness
//! cross-check (LLᵀ must agree with LU on SPD inputs).

use crate::matrix::DenseMatrix;
use crate::util::error::{EbvError, Result};

/// Lower-triangular Cholesky factor.
#[derive(Debug, Clone)]
pub struct CholeskyFactors {
    l: DenseMatrix,
}

impl CholeskyFactors {
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    pub fn l(&self) -> &DenseMatrix {
        &self.l
    }

    /// Solve `A x = b` via `L y = b`, `Lᵀ x = y`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.n();
        if b.len() != n {
            return Err(EbvError::Shape("rhs length mismatch".into()));
        }
        // Forward with explicit diagonal.
        let mut y = b.to_vec();
        for i in 0..n {
            let row = self.l.row(i);
            let mut acc = y[i];
            for (j, &lij) in row[..i].iter().enumerate() {
                acc -= lij * y[j];
            }
            y[i] = acc / row[i];
        }
        // Backward with Lᵀ (column access on L).
        let mut x = y;
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in (i + 1)..n {
                acc -= self.l.get(j, i) * x[j];
            }
            x[i] = acc / self.l.get(i, i);
        }
        Ok(x)
    }

    /// Reconstruct `L Lᵀ` (test helper).
    pub fn reconstruct(&self) -> DenseMatrix {
        self.l.matmul(&self.l.transpose()).expect("square")
    }
}

/// Factor an SPD matrix. Fails with `Numeric` if a non-positive pivot
/// shows the input is not positive definite (or not symmetric enough).
pub fn cholesky_factor(a: &DenseMatrix) -> Result<CholeskyFactors> {
    if !a.is_square() {
        return Err(EbvError::Shape("Cholesky needs a square matrix".into()));
    }
    let n = a.rows();
    // Symmetry gate (cheap sample for large n, exact for small).
    let check = |i: usize, j: usize| (a.get(i, j) - a.get(j, i)).abs() > 1e-9;
    let sym_violation = if n <= 64 {
        (0..n).any(|i| (0..i).any(|j| check(i, j)))
    } else {
        (0..64).any(|k| check(k * (n - 1) / 63, (k * 37) % n))
    };
    if sym_violation {
        return Err(EbvError::Numeric("matrix is not symmetric".into()));
    }

    let mut l = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(EbvError::Numeric(format!(
                        "non-positive pivot {sum:.3e} at step {i}: matrix is not SPD"
                    )));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(CholeskyFactors { l })
}

/// Factor + solve.
pub fn cholesky_solve(a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    cholesky_factor(a)?.solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{manufactured_solution, poisson_2d, GenSeed};
    use crate::matrix::norms::diff_inf;
    use crate::solver::{LuSolver, SeqLu};

    fn spd(n: usize, seed: u64) -> DenseMatrix {
        // B Bᵀ + n I is SPD.
        let b = crate::matrix::generate::diag_dominant_dense(n, GenSeed(seed));
        let mut a = b.matmul(&b.transpose()).unwrap();
        for i in 0..n {
            a.set(i, i, a.get(i, i) + n as f64);
        }
        a
    }

    #[test]
    fn reconstructs_spd_matrix() {
        let a = spd(24, 1);
        let f = cholesky_factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-6 * a.get(0, 0).abs().max(1.0));
        // L is lower triangular with positive diagonal.
        for i in 0..24 {
            assert!(f.l().get(i, i) > 0.0);
            for j in (i + 1)..24 {
                assert_eq!(f.l().get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn solve_matches_lu() {
        let a = spd(40, 2);
        let b: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).sin()).collect();
        let xc = cholesky_solve(&a, &b).unwrap();
        let xl = SeqLu::new().solve(&a, &b).unwrap();
        assert!(diff_inf(&xc, &xl) < 1e-7, "{}", diff_inf(&xc, &xl));
    }

    #[test]
    fn poisson_system_is_spd() {
        let a = poisson_2d(8).to_dense();
        let f = cholesky_factor(&a).unwrap();
        let (x_true, b) = manufactured_solution(&poisson_2d(8), GenSeed(3));
        let x = f.solve(&b).unwrap();
        assert!(diff_inf(&x, &x_true) < 1e-9);
    }

    #[test]
    fn rejects_indefinite_and_asymmetric() {
        let indefinite =
            DenseMatrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]).unwrap(); // eigenvalues 3, -1
        assert!(cholesky_factor(&indefinite).is_err());
        let asym = DenseMatrix::from_rows(&[&[2.0, 1.0], &[0.0, 2.0]]).unwrap();
        assert!(matches!(cholesky_factor(&asym), Err(EbvError::Numeric(_))));
    }

    #[test]
    fn rejects_non_square() {
        assert!(cholesky_factor(&DenseMatrix::zeros(2, 3)).is_err());
    }
}
