//! Blocked right-looking LU — the "tuned library" comparator.
//!
//! Plays the role cuBLAS plays in the paper's closing comparison (the
//! paper notes library solvers top out around 15× speedup). Cache
//! blocking regroups the rank-1 updates into panel factorizations plus a
//! GEMM trailing update; on real TPU hardware this is also the form that
//! maps onto the MXU (see DESIGN.md §Hardware-Adaptation), which is why
//! the L1 Pallas kernel set includes a blocked variant.

use crate::matrix::DenseMatrix;
use crate::solver::kernel::{self, Kernel};
use crate::solver::pivot::Permutation;
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::{EbvError, Result};

/// Blocked (panel) LU without pivoting.
#[derive(Debug, Clone)]
pub struct BlockedLu {
    block: usize,
    pivot_tol: f64,
    kernel: Kernel,
}

impl BlockedLu {
    pub fn new() -> Self {
        // nb=32 measured best-or-tied across n=512…2048 on this host
        // (EXPERIMENTS.md §Perf, L3-D1 sweep).
        BlockedLu { block: 32, pivot_tol: 1e-12, kernel: Kernel::Auto }
    }

    pub fn with_block(block: usize) -> Self {
        assert!(block > 0, "block size must be positive");
        BlockedLu { block, pivot_tol: 1e-12, kernel: Kernel::Auto }
    }

    /// Select the trailing-update microkernel (default
    /// [`Kernel::Auto`]); the same module `EbvLu`'s blocked paths
    /// dispatch to, with the whole trailing range as the single-lane
    /// row set.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn block(&self) -> usize {
        self.block
    }
}

impl Default for BlockedLu {
    fn default() -> Self {
        BlockedLu::new()
    }
}

impl LuSolver for BlockedLu {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        if !a.is_square() {
            return Err(EbvError::Shape("LU needs a square matrix".into()));
        }
        let n = a.rows();
        let nb = self.block;
        let kern = self.kernel.resolve();
        let mut lu = a.clone();

        let mut k = 0usize;
        while k < n {
            let kb = nb.min(n - k);

            // 1. Factor the diagonal panel A[k.., k..k+kb] (unblocked,
            //    updates the panel's sub-diagonal rows too).
            for r in k..k + kb {
                let piv = lu.get(r, r);
                if piv.abs() < self.pivot_tol {
                    return Err(EbvError::SingularPivot {
                        step: r,
                        value: piv,
                        tol: self.pivot_tol,
                    });
                }
                let inv = 1.0 / piv;
                for i in (r + 1)..n {
                    let f = lu.get(i, r) * inv;
                    lu.set(i, r, f);
                    if f == 0.0 {
                        continue;
                    }
                    // Within the panel factorization only columns up to
                    // the panel edge are updated; the trailing block is
                    // handled by the GEMM below.
                    let hi = (k + kb).min(n);
                    for j in (r + 1)..hi {
                        let v = lu.get(i, j) - f * lu.get(r, j);
                        lu.set(i, j, v);
                    }
                }
            }

            let rest = k + kb;
            if rest >= n {
                break;
            }

            // 2. U12 := L11⁻¹ A12 (unit lower triangular solve on block
            //    rows k..k+kb, columns rest..n).
            for r in k..k + kb {
                for p in k..r {
                    let l_rp = lu.get(r, p);
                    if l_rp == 0.0 {
                        continue;
                    }
                    let cols = n;
                    let data = lu.data_mut();
                    let (top, bottom) = data.split_at_mut(r * cols);
                    let p_row = &top[p * cols + rest..p * cols + cols];
                    let r_row = &mut bottom[rest..cols];
                    for (t, &s) in r_row.iter_mut().zip(p_row.iter()) {
                        *t -= l_rp * s;
                    }
                }
            }

            // 3. A22 -= L21 · U12 through the shared trailing-update
            //    microkernel (`solver::kernel`) — the same code `EbvLu`
            //    runs per lane, here with the whole trailing range as
            //    the row set.
            let rows: Vec<usize> = (rest..n).collect();
            // SAFETY: `lu` is exclusively borrowed for the call; the
            // written rows (`rest..n`) are disjoint from the panel rows
            // the kernel reads (`k..rest`), which steps 1–2 finalized.
            unsafe {
                let view = kernel::MatView::from_raw(lu.data_mut().as_mut_ptr(), n);
                kernel::trailing_update(kern, view, &rows, k, rest, n);
            }

            k += kb;
        }
        Ok(DenseLuFactors::new(lu, Permutation::identity(n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::rel_residual_dense;
    use crate::solver::SeqLu;

    #[test]
    fn matches_unblocked_factors() {
        for n in [5usize, 16, 63, 64, 65, 130] {
            let a = diag_dominant_dense(n, GenSeed(31 + n as u64));
            let blocked = BlockedLu::with_block(16).factor(&a).unwrap();
            let seq = SeqLu::new().factor(&a).unwrap();
            assert!(
                blocked.packed().max_abs_diff(seq.packed()) < 1e-9,
                "n={n}"
            );
        }
    }

    #[test]
    fn block_larger_than_matrix_degenerates_gracefully() {
        let a = diag_dominant_dense(10, GenSeed(33));
        let f = BlockedLu::with_block(256).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn block_of_one_is_plain_elimination() {
        let a = diag_dominant_dense(12, GenSeed(34));
        let f = BlockedLu::with_block(1).factor(&a).unwrap();
        let seq = SeqLu::new().factor(&a).unwrap();
        assert!(f.packed().max_abs_diff(seq.packed()) < 1e-10);
    }

    #[test]
    fn solve_residual_is_small() {
        let n = 150;
        let a = diag_dominant_dense(n, GenSeed(35));
        let b = rhs(n, GenSeed(36));
        let x = BlockedLu::new().solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn singular_matrix_detected() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]).unwrap();
        assert!(matches!(
            BlockedLu::new().factor(&a),
            Err(EbvError::SingularPivot { .. })
        ));
    }

    #[test]
    #[should_panic(expected = "block size")]
    fn zero_block_panics() {
        BlockedLu::with_block(0);
    }

    #[test]
    fn tiled_kernel_is_bitwise_unroll4() {
        // n chosen so the trailing block spans several NR tiles and
        // the panel depth several KC tiles.
        let a = diag_dominant_dense(260, GenSeed(37));
        let u4 = BlockedLu::with_block(70).with_kernel(Kernel::Unroll4).factor(&a).unwrap();
        let tiled = BlockedLu::with_block(70).with_kernel(Kernel::Tiled).factor(&a).unwrap();
        assert_eq!(u4.packed().data(), tiled.packed().data());
    }

    #[test]
    fn unroll8_kernel_stays_componentwise() {
        let a = diag_dominant_dense(130, GenSeed(38));
        let seq = SeqLu::new().factor(&a).unwrap();
        let u8k = BlockedLu::with_block(16).with_kernel(Kernel::Unroll8).factor(&a).unwrap();
        assert!(u8k.packed().max_abs_diff(seq.packed()) < 1e-9);
        // Deterministic: a second run reproduces the bits.
        let again = BlockedLu::with_block(16).with_kernel(Kernel::Unroll8).factor(&a).unwrap();
        assert_eq!(u8k.packed().data(), again.packed().data());
    }
}
