//! Gauss–Jordan solver — the comparator the paper's LU section contrasts
//! against ("this method doesn't need repeating iterations like
//! Gauss-Jordan"). Full elimination to reduced row-echelon form with
//! partial pivoting; ~50% more flops than LU, no reusable factors.

use crate::matrix::DenseMatrix;
use crate::solver::pivot::argmax_pivot;
use crate::util::error::{EbvError, Result};

/// Gauss–Jordan elimination solver.
#[derive(Debug, Clone, Default)]
pub struct GaussJordan {
    pivot_tol: f64,
}

impl GaussJordan {
    pub fn new() -> Self {
        GaussJordan { pivot_tol: 1e-12 }
    }

    /// Solve `A x = b` by reducing `[A | b]` to `[I | x]`.
    pub fn solve(&self, a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
        if !a.is_square() {
            return Err(EbvError::Shape("Gauss-Jordan needs a square matrix".into()));
        }
        let n = a.rows();
        if b.len() != n {
            return Err(EbvError::Shape("rhs length mismatch".into()));
        }
        let mut m = a.clone();
        let mut x = b.to_vec();

        for r in 0..n {
            let p = argmax_pivot(&m, r, r);
            if p != r {
                let cols = n;
                let data = m.data_mut();
                let (lo, hi) = (r.min(p), r.max(p));
                let (a_half, b_half) = data.split_at_mut(hi * cols);
                a_half[lo * cols..(lo + 1) * cols].swap_with_slice(&mut b_half[..cols]);
                x.swap(r, p);
            }
            let piv = m.get(r, r);
            if piv.abs() < self.pivot_tol {
                return Err(EbvError::SingularPivot { step: r, value: piv, tol: self.pivot_tol });
            }
            // Normalize pivot row.
            let inv = 1.0 / piv;
            for j in 0..n {
                m.set(r, j, m.get(r, j) * inv);
            }
            x[r] *= inv;
            // Eliminate the column everywhere else (above and below).
            for i in 0..n {
                if i == r {
                    continue;
                }
                let f = m.get(i, r);
                if f == 0.0 {
                    continue;
                }
                for j in 0..n {
                    let v = m.get(i, j) - f * m.get(r, j);
                    m.set(i, j, v);
                }
                x[i] -= f * x[r];
            }
        }
        Ok(x)
    }

    /// Invert `A` (the classic Gauss–Jordan use; oracle for Eq. 4-c,
    /// which expresses `A⁻¹` as the bi-vector factor product).
    pub fn invert(&self, a: &DenseMatrix) -> Result<DenseMatrix> {
        if !a.is_square() {
            return Err(EbvError::Shape("invert needs a square matrix".into()));
        }
        let n = a.rows();
        let mut inv = DenseMatrix::zeros(n, n);
        // Solve n unit systems. O(n⁴) with this naive loop — oracle only.
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.solve(a, &e)?;
            for i in 0..n {
                inv.set(i, j, col[i]);
            }
        }
        Ok(inv)
    }
}

impl crate::solver::LuSolver for GaussJordan {
    fn name(&self) -> &'static str {
        "gauss-jordan"
    }

    /// Gauss–Jordan produces no reusable factors; `factor` is
    /// intentionally unsupported. Use [`LuSolver::solve`].
    fn factor(&self, _a: &DenseMatrix) -> Result<crate::solver::DenseLuFactors> {
        Err(EbvError::Numeric(
            "Gauss-Jordan has no factored form; call solve() instead".into(),
        ))
    }

    fn solve(&self, a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
        GaussJordan::solve(self, a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::{diff_inf, rel_residual_dense};
    use crate::solver::SeqLu;
    use crate::solver::LuSolver as _;

    #[test]
    fn matches_lu_solution() {
        let n = 50;
        let a = diag_dominant_dense(n, GenSeed(51));
        let b = rhs(n, GenSeed(52));
        let gj = GaussJordan::new().solve(&a, &b).unwrap();
        let lu = SeqLu::new().solve(&a, &b).unwrap();
        assert!(diff_inf(&gj, &lu) < 1e-10);
        assert!(rel_residual_dense(&a, &gj, &b) < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = DenseMatrix::from_rows(&[&[0.0, 2.0], &[3.0, 0.0]]).unwrap();
        let x = GaussJordan::new().solve(&a, &[4.0, 6.0]).unwrap();
        assert!(diff_inf(&x, &[2.0, 2.0]) < 1e-12);
    }

    #[test]
    fn invert_gives_identity_product() {
        let a = diag_dominant_dense(10, GenSeed(53));
        let inv = GaussJordan::new().invert(&a).unwrap();
        let prod = a.matmul(&inv).unwrap();
        assert!(prod.max_abs_diff(&DenseMatrix::identity(10)) < 1e-9);
    }

    #[test]
    fn singular_detected() {
        let a = DenseMatrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]).unwrap();
        assert!(matches!(
            GaussJordan::new().solve(&a, &[1.0, 1.0]),
            Err(EbvError::SingularPivot { .. })
        ));
    }

    #[test]
    fn shape_errors() {
        let a = DenseMatrix::zeros(2, 3);
        assert!(GaussJordan::new().solve(&a, &[1.0, 2.0]).is_err());
        let sq = DenseMatrix::identity(2);
        assert!(GaussJordan::new().solve(&sq, &[1.0]).is_err());
    }
}
