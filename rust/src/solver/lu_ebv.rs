//! The paper's parallel solver: **Equal bi-Vectorized LU**.
//!
//! Right-looking elimination where the updated rows are statically owned
//! by worker lanes according to an equalized (fold-paired) distribution
//! — the GPU thread mapping of the paper realized on CPU lanes (see
//! `rust/DESIGN.md` §Substitutions: GTX280 threads → resident
//! [`LaneEngine`] lanes; the tables' GPU-scale numbers come from
//! `gpusim` fed with this exact schedule).
//!
//! Execution runs on the persistent lane engine (`rust/DESIGN.md`
//! §Execution engine). Two elimination shapes share the engine:
//!
//! * **Column-at-a-time** (`panel(1)`): one barrier-separated step per
//!   elimination column, each a lane-distributed rank-1 update. After
//!   the barrier into step `r`, every lane may safely read pivot row
//!   `r` (its final update happened at step `r-1`, sequenced before
//!   the barrier). Bit-identical to [`SeqLu`](crate::solver::SeqLu).
//! * **Blocked panels** (`panel(nb)`, the default `nb = 64`): columns
//!   are grouped into `nb`-wide panels (see
//!   [`panels`](crate::ebv::schedule::panels)). A panel-column step
//!   updates panel rows full-width (building the `U12` block in place)
//!   but deeper rows only across the panel's own columns; one trailing
//!   step per panel then applies the deferred work as lane-distributed
//!   rank-`nb` updates through the shared trailing-update microkernel
//!   ([`kernel::trailing_update`] — selectable fuse width and cache
//!   tiling, each lane's owned rows forming the outer M partition), so
//!   the trailing matrix is swept once per panel instead of once per
//!   column. The fused multi-column accumulation reorders rounding, so
//!   blocked factors agree with `SeqLu` componentwise rather than
//!   bitwise — but for a fixed kernel choice are themselves bit-stable
//!   across lane counts, distributions, engine sizes and kernel tile
//!   sizes (each row's arithmetic depends only on the panel
//!   decomposition and the kernel's fuse width).
//!
//! In both shapes lanes write only rows they own, so writes are
//! disjoint by construction of [`LaneSchedule`]. The schedule's lane
//! count is a *virtual* width: the engine deals virtual lanes across
//! its resident lanes, so the factors never depend on the pool size.

use std::sync::{Arc, Mutex};

use crate::ebv::schedule::{panels, LaneSchedule, RowDist};
use crate::exec::{
    run_dataflow, DepGraph, DeviceSet, ExchangeBuffer, LaneEngine, Schedule, StepCtl,
};
use crate::matrix::DenseMatrix;
use crate::solver::kernel::{self, Kernel};
use crate::solver::pivot::Permutation;
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::{EbvError, Result};

/// Default panel width for the blocked elimination.
pub const DEFAULT_PANEL_WIDTH: usize = 64;

/// Parallel EBV LU factorization.
#[derive(Debug, Clone)]
pub struct EbvLu {
    lanes: usize,
    dist: RowDist,
    pivot_tol: f64,
    /// Below this size the parallel machinery costs more than it saves;
    /// fall through to the sequential kernel.
    seq_threshold: usize,
    /// Panel width `nb` of the blocked elimination; `1` selects the
    /// column-at-a-time path (bit-identical to `SeqLu`).
    panel: usize,
    /// Trailing-update microkernel of the blocked elimination (see
    /// [`kernel`]); resolved once per factorization. Irrelevant on the
    /// column-at-a-time path (`panel(1)`), which has no rank-`nb`
    /// update.
    kernel: Kernel,
    /// Engine override; `None` submits to the process-global engine.
    engine: Option<Arc<LaneEngine>>,
    /// Device-sharded execution: when set with more than one device,
    /// the elimination runs as a two-level job on the set (rows dealt
    /// to devices by greedy LPT, then to vlanes within a device by
    /// `dist`), with the pivot row broadcast through the staged
    /// exchange each step. Bitwise identical to the flat path for
    /// every device count.
    devices: Option<Arc<DeviceSet>>,
    /// Execution schedule of the blocked elimination:
    /// [`Schedule::Barrier`] steps every lane through the
    /// `blocked_steps` sequence; [`Schedule::Dataflow`] runs the same
    /// arithmetic as a dependency-counted task DAG with panel
    /// lookahead (one barrier entry per factorization). Factors are
    /// **bitwise identical** across the two schedules for every
    /// `(nb, kernel, lanes, dist, devices)` — the lookahead only
    /// re-partitions work whose per-element operand order is fixed.
    /// Paths without a blocked trailing update (`panel(1)`, the
    /// sequential fall-through, single-panel sizes) and device-sharded
    /// runs keep the barrier shape regardless of the knob.
    schedule: Schedule,
}

impl EbvLu {
    /// EBV solver with the paper's fold distribution on `lanes` lanes.
    pub fn with_lanes(lanes: usize) -> Self {
        EbvLu {
            lanes: lanes.max(1),
            dist: RowDist::EbvFold,
            pivot_tol: 1e-12,
            seq_threshold: 128,
            panel: DEFAULT_PANEL_WIDTH,
            kernel: Kernel::Auto,
            engine: None,
            devices: None,
            schedule: Schedule::Barrier,
        }
    }

    /// Use all available parallelism.
    pub fn auto() -> Self {
        EbvLu::with_lanes(crate::exec::default_lanes())
    }

    /// Override the row-distribution strategy (ablation hook).
    pub fn with_dist(mut self, dist: RowDist) -> Self {
        self.dist = dist;
        self
    }

    /// Submit to a specific engine instead of the process-global one
    /// (the coordinator shares one engine across its workers this way).
    pub fn with_engine(mut self, engine: Arc<LaneEngine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Execute device-sharded on a [`DeviceSet`] (the coordinator
    /// shares one set across its workers when `service.devices > 1`).
    /// The configured lane count is split across the set's devices
    /// (`ceil(lanes / devices)` vlanes per device); a single-device
    /// set keeps the flat path. Factors are bitwise identical either
    /// way.
    pub fn with_devices(mut self, devices: Arc<DeviceSet>) -> Self {
        self.devices = Some(devices);
        self
    }

    /// Override the sequential fall-through threshold (bench hook).
    pub fn seq_threshold(mut self, t: usize) -> Self {
        self.seq_threshold = t;
        self
    }

    /// Set the panel width `nb` of the blocked elimination. `1` keeps
    /// the column-at-a-time path (bit-identical to `SeqLu`); wider
    /// panels trade that exactness for rank-`nb` trailing updates.
    /// Clamped to at least 1.
    pub fn panel(mut self, nb: usize) -> Self {
        self.panel = nb.max(1);
        self
    }

    /// Select the trailing-update microkernel of the blocked
    /// elimination (default [`Kernel::Auto`] — `EBV_KERNEL` or tiled).
    /// `tiled` and `unroll4` factors are bitwise identical; `unroll8`
    /// agrees componentwise. Every choice is bit-stable across lane
    /// counts, distributions, engine sizes and device counts.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Select the execution schedule of the blocked elimination
    /// (default [`Schedule::Barrier`]). `dataflow` overlaps panel
    /// factorizations with the previous panel's far trailing updates —
    /// same bits, fewer barrier entries (see the field docs for the
    /// fallback matrix).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn dist(&self) -> RowDist {
        self.dist
    }

    /// Configured panel width `nb`.
    pub fn panel_width(&self) -> usize {
        self.panel
    }

    /// Configured microkernel choice (possibly [`Kernel::Auto`]).
    pub fn kernel_choice(&self) -> Kernel {
        self.kernel
    }

    /// Configured execution schedule.
    pub fn schedule_choice(&self) -> Schedule {
        self.schedule
    }
}

impl LuSolver for EbvLu {
    fn name(&self) -> &'static str {
        "ebv"
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        if !a.is_square() {
            return Err(EbvError::Shape("LU needs a square matrix".into()));
        }
        let n = a.rows();
        if self.lanes == 1 || n <= self.seq_threshold {
            // The parallel path is bitwise-identical in arithmetic order
            // per row, so falling through is exact, not approximate.
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
            return crate::solver::SeqLu::new().pivot_tol(self.pivot_tol).factor(a);
        }
        let mut lu = a.clone();
        if let Some(set) = self.devices.as_ref().filter(|s| s.devices() > 1) {
            let lpd = self.lanes.div_ceil(set.devices()).max(1);
            // The dense "symbolic" phase is schedule construction: the
            // equalized vlane decomposition the paper's method plans.
            let schedule = {
                let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Symbolic);
                LaneSchedule::build_sharded(n, set.devices(), lpd, self.dist)
            };
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
            if self.panel <= 1 {
                parallel_eliminate_sharded(&mut lu, &schedule, self.pivot_tol, set.as_ref())?;
            } else {
                parallel_eliminate_blocked_sharded(
                    &mut lu,
                    &schedule,
                    self.panel,
                    self.kernel.resolve(),
                    self.pivot_tol,
                    set.as_ref(),
                )?;
            }
            return Ok(DenseLuFactors::new(lu, Permutation::identity(n)));
        }
        let schedule = {
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Symbolic);
            LaneSchedule::build(n, self.lanes, self.dist)
        };
        let engine = crate::exec::engine_or_global(self.engine.as_ref());
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
        if self.panel <= 1 {
            parallel_eliminate(&mut lu, &schedule, self.pivot_tol, engine)?;
        } else if self.schedule == Schedule::Dataflow && panels(n, self.panel).len() >= 2 {
            // Dataflow needs at least two panels to have a trailing
            // update to overlap; a single covering panel falls through
            // to the (bitwise identical) barrier path.
            parallel_eliminate_blocked_dataflow(
                &mut lu,
                &schedule,
                self.panel,
                self.kernel.resolve(),
                self.pivot_tol,
                engine,
            )?;
        } else {
            parallel_eliminate_blocked(
                &mut lu,
                &schedule,
                self.panel,
                self.kernel.resolve(),
                self.pivot_tol,
                engine,
            )?;
        }
        Ok(DenseLuFactors::new(lu, Permutation::identity(n)))
    }
}

/// Shared mutable matrix for the engine lanes. Writes are restricted to
/// owned rows (disjoint across lanes); reads of the pivot row are
/// sequenced by the per-step barrier.
struct SharedMatrix {
    ptr: *mut f64,
    cols: usize,
}
unsafe impl Send for SharedMatrix {}
unsafe impl Sync for SharedMatrix {}

impl SharedMatrix {
    /// Immutable view of row `r`. Caller must guarantee no lane is
    /// concurrently writing row `r` (holds for the pivot row after the
    /// step barrier).
    #[inline]
    unsafe fn row(&self, r: usize) -> &[f64] {
        std::slice::from_raw_parts(self.ptr.add(r * self.cols), self.cols)
    }

    /// Mutable view of row `i`. Caller must own row `i`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

fn parallel_eliminate(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    // First singular pivot seen by any lane (steps are synchronized, so
    // every lane records the same pivot at the same step; the engine
    // ends the job on the step where it is detected).
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    engine.run_steps(schedule.lanes(), n - 1, |lane, r| {
        // SAFETY: after the barrier into step r, row r's final update
        // (performed at step r-1 by its owner) has completed; no lane
        // writes row r during step r because active rows are strictly
        // below the pivot.
        let pivot_row = unsafe { shared.row(r) };
        let piv = pivot_row[r];
        if piv.abs() < pivot_tol {
            let mut bad = first_bad.lock().expect("pivot slot");
            if bad.is_none() {
                *bad = Some((r, piv));
            }
            return StepCtl::Break;
        }
        let inv = 1.0 / piv;
        for &i in schedule.active_rows_of(lane, r) {
            // SAFETY: lane owns row i exclusively.
            let row_i = unsafe { shared.row_mut(i) };
            let f = row_i[r] * inv;
            row_i[r] = f;
            if f == 0.0 {
                continue;
            }
            let (head, tail) = row_i.split_at_mut(r + 1);
            let _ = head;
            for (t, &p) in tail.iter_mut().zip(pivot_row[r + 1..].iter()) {
                *t -= f * p;
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    // Check the last pivot too (never used as a divisor during
    // elimination but required for the solve).
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// Device-sharded column-at-a-time elimination: the same arithmetic as
/// [`parallel_eliminate`] executed as a two-level [`DeviceSet`] job.
/// Each step the exchange phase (device 0's host) validates the pivot
/// and broadcasts the trailing pivot row through the staged
/// [`ExchangeBuffer`] (a bit-exact copy — the realized counterpart of
/// the `gpusim::cluster` broadcast term); every device then updates its
/// owned rows reading the staged row. Factors are bitwise identical to
/// the flat path for every device count, lane count and distribution.
fn parallel_eliminate_sharded(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    pivot_tol: f64,
    set: &DeviceSet,
) -> Result<()> {
    let n = lu.rows();
    let lpd = schedule.lanes_per_device();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let mut staged = vec![0.0f64; n];
    let stage = ExchangeBuffer::new(&mut staged);
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    set.run_sharded(
        lpd,
        n - 1,
        |r| {
            // SAFETY: row r's final update (performed at step r-1 by its
            // owner) was published by the closing cross-device barrier;
            // no device computes while the exchange runs.
            let pivot_row = unsafe { shared.row(r) };
            let piv = pivot_row[r];
            if piv.abs() < pivot_tol {
                let mut bad = first_bad.lock().expect("pivot slot");
                if bad.is_none() {
                    *bad = Some((r, piv));
                }
                return StepCtl::Break;
            }
            // SAFETY: exchange phase — sole accessor of the stage.
            unsafe { stage.stage(r, &pivot_row[r..]) };
            set.record_exchange(n - r);
            StepCtl::Continue
        },
        |dev, vlane, r| {
            // SAFETY: compute phase — the stage is read-only everywhere.
            let pivot_row = unsafe { stage.staged() };
            let inv = 1.0 / pivot_row[r];
            for &i in schedule.active_rows_of(dev * lpd + vlane, r) {
                // SAFETY: this (device, vlane) owns row i exclusively.
                let row_i = unsafe { shared.row_mut(i) };
                let f = row_i[r] * inv;
                row_i[r] = f;
                if f == 0.0 {
                    continue;
                }
                let (head, tail) = row_i.split_at_mut(r + 1);
                let _ = head;
                for (t, &p) in tail.iter_mut().zip(pivot_row[r + 1..].iter()) {
                    *t -= f * p;
                }
            }
            StepCtl::Continue
        },
    );

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// One barrier-separated step of the blocked elimination.
#[derive(Debug, Clone, Copy)]
enum BlockStep {
    /// Eliminate panel column `r`: rows inside the panel (`i <
    /// panel_end`) carry their whole trailing row forward (building the
    /// `U12` block incrementally), rows below the panel compute their
    /// multiplier and update only columns `r+1..panel_end` — their wide
    /// update is deferred to the panel's `Update` step.
    Col { r: usize, panel_end: usize },
    /// Rank-`(panel_end - panel_start)` trailing update: every owned
    /// row at or below `panel_end` absorbs the whole panel in one
    /// GEMM-style pass.
    Update { panel_start: usize, panel_end: usize },
}

/// Flatten the panel decomposition into the engine's step sequence.
fn blocked_steps(n: usize, nb: usize) -> Vec<BlockStep> {
    let mut steps = Vec::new();
    for (k, end) in panels(n, nb) {
        for r in k..end.min(n.saturating_sub(1)) {
            steps.push(BlockStep::Col { r, panel_end: end });
        }
        if end < n {
            steps.push(BlockStep::Update { panel_start: k, panel_end: end });
        }
    }
    steps
}

fn parallel_eliminate_blocked(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    nb: usize,
    kern: Kernel,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let steps = blocked_steps(n, nb);
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    engine.run_steps(schedule.lanes(), steps.len(), |lane, s| {
        match steps[s] {
            BlockStep::Col { r, panel_end } => {
                // SAFETY: row r's final write (its owner at the previous
                // Col step, or the preceding panel's Update step) is
                // sequenced before the barrier into this step; no lane
                // writes row r now (active rows are strictly below it).
                let pivot_row = unsafe { shared.row(r) };
                let piv = pivot_row[r];
                if piv.abs() < pivot_tol {
                    let mut bad = first_bad.lock().expect("pivot slot");
                    if bad.is_none() {
                        *bad = Some((r, piv));
                    }
                    return StepCtl::Break;
                }
                let inv = 1.0 / piv;
                for &i in schedule.active_rows_of(lane, r) {
                    // SAFETY: lane owns row i exclusively.
                    let row_i = unsafe { shared.row_mut(i) };
                    let f = row_i[r] * inv;
                    row_i[r] = f;
                    if f == 0.0 {
                        continue;
                    }
                    let hi = if i < panel_end { n } else { panel_end };
                    for (t, &p) in
                        row_i[r + 1..hi].iter_mut().zip(pivot_row[r + 1..hi].iter())
                    {
                        *t -= f * p;
                    }
                }
            }
            BlockStep::Update { panel_start, panel_end } => {
                // SAFETY: the lane's `rows_from` range is owned
                // exclusively (disjoint across lanes by LaneSchedule
                // construction); the panel rows the kernel reads (U12)
                // satisfy panel_start + p < panel_end <= i, so they
                // alias no write, and their final updates happened at
                // Col steps sequenced before this barrier.
                unsafe {
                    kernel::trailing_update(
                        kern,
                        kernel::MatView::from_raw(shared.ptr, shared.cols),
                        schedule.rows_from(lane, panel_end),
                        panel_start,
                        panel_end,
                        n,
                    )
                };
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// One task of the dataflow blocked elimination (see
/// [`parallel_eliminate_blocked_dataflow`]).
#[derive(Debug, Clone, Copy)]
enum DfTask {
    /// Factor one whole panel: every Col step of `[start, end)`, all
    /// active rows, executed sequentially by whichever lane claims the
    /// task. Per-row arithmetic is identical to the barrier Col steps
    /// (rows are independent within a step), so the single-runner
    /// shape changes no bits.
    Panel { start: usize, end: usize },
    /// One lane's slice of a panel's trailing update, narrowed to a
    /// column range: rows of `lane` in `[row_lo, row_hi)`, columns
    /// `[cols_lo, cols_hi)` — a [`kernel::trailing_update_cols`] call.
    Piece {
        lane: usize,
        row_lo: usize,
        row_hi: usize,
        panel_start: usize,
        panel_end: usize,
        cols_lo: usize,
        cols_hi: usize,
    },
}

/// Dataflow blocked elimination with **panel lookahead**: the same
/// arithmetic as [`parallel_eliminate_blocked`], re-partitioned into a
/// dependency-counted task DAG so panel `k+1`'s column factorization
/// starts as soon as panel `k`'s trailing update has covered panel
/// `k+1`'s columns — overlapping the narrow, badly-parallel panel work
/// with the wide trailing sweep instead of barrier-stepping everyone
/// through both. One engine step (one barrier entry) per
/// factorization, versus `(n-1) + panels` for the barrier schedule.
///
/// Task decomposition, per panel `p` with columns `[ps, pe)` and next
/// panel end `pe2` (pieces exist for every panel but the last):
///
/// * `Panel(p)` — all Col steps of the panel, every active row;
/// * `Near(p, l)` — lane `l`'s rows `>= pe`, columns `[pe, pe2)`: the
///   slab panel `p+1` needs next;
/// * `FarHead(p, l)` — lane `l`'s rows in `[pe, pe2)` (panel `p+1`'s
///   own rows), columns `[pe2, n)`;
/// * `FarTail(p, l)` — lane `l`'s rows `>= pe2`, columns `[pe2, n)`:
///   the piece that overlaps `Panel(p+1)`.
///
/// Edges: `Panel(p) ← Near(p-1, ∀l) + FarHead(p-1, ∀l)`, and every
/// piece of panel `p` ← `Panel(p)` + `FarTail(p-1, l)` (same lane).
/// `FarTail(p-1, ·)` is deliberately **not** a parent of `Panel(p)` —
/// it writes rows `>= pe` at columns `>= pe`, while `Panel(p)` touches
/// its panel rows (`< pe`) at any column and deeper rows only at
/// columns `< pe`: disjoint, so the two run concurrently. That overlap
/// is the whole win; everything the panel reads (its rows' multiplier
/// columns, the pivot rows full-width) is covered by the `Near` and
/// `FarHead` parents, transitively through the per-lane `FarTail`
/// chain.
///
/// **Bit-identity.** Row partition (existing ledger) and column
/// partition ([`kernel::trailing_update_cols`]) of a trailing update
/// are both per-element inert, and the dep edges reproduce exactly the
/// reads-after-writes the barrier sequence enforced — so factors are
/// bitwise identical to the barrier schedule for every
/// `(nb, kernel, lanes, dist)`, and bit-stable across engine sizes
/// (tasks are defined by the *schedule's* lane ownership, not by which
/// OS lane executes them). Pinned in `tests/prop_schedule.rs` and the
/// `dataflow_*` tests below.
fn parallel_eliminate_blocked_dataflow(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    nb: usize,
    kern: Kernel,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let panel_list = panels(n, nb);
    let m = panel_list.len();
    debug_assert!(m >= 2, "caller guarantees at least two panels");
    let vl = schedule.lanes();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    // Task ids: panels first (Panel(p) = p), pieces appended in
    // (panel, kind, lane) order.
    let mut tasks: Vec<DfTask> = panel_list
        .iter()
        .map(|&(start, end)| DfTask::Panel { start, end })
        .collect();
    let mut near = vec![usize::MAX; (m - 1) * vl];
    let mut far_head = vec![usize::MAX; (m - 1) * vl];
    let mut far_tail = vec![usize::MAX; (m - 1) * vl];
    for p in 0..m - 1 {
        let (ps, pe) = panel_list[p];
        let pe2 = panel_list[p + 1].1;
        for l in 0..vl {
            near[p * vl + l] = tasks.len();
            tasks.push(DfTask::Piece {
                lane: l,
                row_lo: pe,
                row_hi: n,
                panel_start: ps,
                panel_end: pe,
                cols_lo: pe,
                cols_hi: pe2,
            });
            if pe2 < n {
                far_head[p * vl + l] = tasks.len();
                tasks.push(DfTask::Piece {
                    lane: l,
                    row_lo: pe,
                    row_hi: pe2,
                    panel_start: ps,
                    panel_end: pe,
                    cols_lo: pe2,
                    cols_hi: n,
                });
                far_tail[p * vl + l] = tasks.len();
                tasks.push(DfTask::Piece {
                    lane: l,
                    row_lo: pe2,
                    row_hi: n,
                    panel_start: ps,
                    panel_end: pe,
                    cols_lo: pe2,
                    cols_hi: n,
                });
            }
        }
    }

    let mut graph = DepGraph::new(tasks.len());
    for p in 1..m {
        for l in 0..vl {
            graph.add_edge(near[(p - 1) * vl + l], p);
            if far_head[(p - 1) * vl + l] != usize::MAX {
                graph.add_edge(far_head[(p - 1) * vl + l], p);
            }
        }
    }
    for p in 0..m - 1 {
        for l in 0..vl {
            for ids in [&near, &far_head, &far_tail] {
                let id = ids[p * vl + l];
                if id == usize::MAX {
                    continue;
                }
                graph.add_edge(p, id);
                if p > 0 && far_tail[(p - 1) * vl + l] != usize::MAX {
                    graph.add_edge(far_tail[(p - 1) * vl + l], id);
                }
            }
        }
    }

    run_dataflow(engine, &graph, |_worker, t| {
        match tasks[t] {
            DfTask::Panel { start, end } => {
                for r in start..end.min(n.saturating_sub(1)) {
                    // SAFETY: every write to row r is sequenced before
                    // this task by the dep edges (its own earlier Col
                    // steps run in this task; older-panel updates are
                    // parents); concurrent pieces write rows >= end at
                    // columns >= end only.
                    let pivot_row = unsafe { shared.row(r) };
                    let piv = pivot_row[r];
                    if piv.abs() < pivot_tol {
                        let mut bad = first_bad.lock().expect("pivot slot");
                        if bad.is_none() {
                            *bad = Some((r, piv));
                        }
                        return StepCtl::Break;
                    }
                    let inv = 1.0 / piv;
                    for i in r + 1..n {
                        // SAFETY: rows below the pivot are written only
                        // by this task at columns < end (deep rows) or
                        // are panel rows no piece touches.
                        let row_i = unsafe { shared.row_mut(i) };
                        let f = row_i[r] * inv;
                        row_i[r] = f;
                        if f == 0.0 {
                            continue;
                        }
                        let hi = if i < end { n } else { end };
                        for (t, &p) in
                            row_i[r + 1..hi].iter_mut().zip(pivot_row[r + 1..hi].iter())
                        {
                            *t -= f * p;
                        }
                    }
                }
            }
            DfTask::Piece { lane, row_lo, row_hi, panel_start, panel_end, cols_lo, cols_hi } => {
                let from = schedule.rows_from(lane, row_lo);
                let rows = &from[..from.partition_point(|&i| i < row_hi)];
                // SAFETY: the rows are one schedule lane's, further
                // disjoint across pieces by the row/column ranges; the
                // panel rows read (U12 at these columns) were finalized
                // by the parent tasks, published through the dep
                // counters' AcqRel chain.
                unsafe {
                    kernel::trailing_update_cols(
                        kern,
                        kernel::MatView::from_raw(shared.ptr, shared.cols),
                        rows,
                        panel_start,
                        panel_end,
                        cols_lo,
                        cols_hi,
                    )
                };
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// Device-sharded blocked-panel elimination: the step sequence of
/// [`parallel_eliminate_blocked`] on a [`DeviceSet`]. Col steps
/// broadcast the trailing pivot row through the staged exchange (and
/// validate the pivot centrally); Update steps read the finalized
/// panel rows in place — published by the closing barrier of their Col
/// steps — and only account the `U12` broadcast the cost model prices.
/// Per-row arithmetic depends solely on the panel decomposition, so
/// for fixed `nb` the factors are bitwise identical to the flat
/// blocked path for every device count.
fn parallel_eliminate_blocked_sharded(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    nb: usize,
    kern: Kernel,
    pivot_tol: f64,
    set: &DeviceSet,
) -> Result<()> {
    let n = lu.rows();
    let lpd = schedule.lanes_per_device();
    let steps = blocked_steps(n, nb);
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let mut staged = vec![0.0f64; n];
    let stage = ExchangeBuffer::new(&mut staged);
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    set.run_sharded(
        lpd,
        steps.len(),
        |s| match steps[s] {
            BlockStep::Col { r, panel_end: _ } => {
                // SAFETY: row r's final write (its owner at the previous
                // Col step, or the preceding panel's Update step) was
                // published by the closing cross-device barrier.
                let pivot_row = unsafe { shared.row(r) };
                let piv = pivot_row[r];
                if piv.abs() < pivot_tol {
                    let mut bad = first_bad.lock().expect("pivot slot");
                    if bad.is_none() {
                        *bad = Some((r, piv));
                    }
                    return StepCtl::Break;
                }
                // SAFETY: exchange phase — sole accessor of the stage.
                unsafe { stage.stage(r, &pivot_row[r..]) };
                set.record_exchange(n - r);
                StepCtl::Continue
            }
            BlockStep::Update { panel_start, panel_end } => {
                // The panel's U12 block travels to every device; it is
                // read in place (finalized before the barrier), so the
                // broadcast is accounted, not copied.
                set.record_exchange((panel_end - panel_start) * (n - panel_end));
                StepCtl::Continue
            }
        },
        |dev, vlane, s| {
            let lane = dev * lpd + vlane;
            match steps[s] {
                BlockStep::Col { r, panel_end } => {
                    // SAFETY: compute phase — the stage is read-only.
                    let pivot_row = unsafe { stage.staged() };
                    let inv = 1.0 / pivot_row[r];
                    for &i in schedule.active_rows_of(lane, r) {
                        // SAFETY: this (device, vlane) owns row i.
                        let row_i = unsafe { shared.row_mut(i) };
                        let f = row_i[r] * inv;
                        row_i[r] = f;
                        if f == 0.0 {
                            continue;
                        }
                        let hi = if i < panel_end { n } else { panel_end };
                        for (t, &p) in
                            row_i[r + 1..hi].iter_mut().zip(pivot_row[r + 1..hi].iter())
                        {
                            *t -= f * p;
                        }
                    }
                }
                BlockStep::Update { panel_start, panel_end } => {
                    // SAFETY: same argument as the flat Update step; the
                    // panel rows' final Col-step writes were published
                    // by the closing cross-device barrier.
                    unsafe {
                        kernel::trailing_update(
                            kern,
                            kernel::MatView::from_raw(shared.ptr, shared.cols),
                            schedule.rows_from(lane, panel_end),
                            panel_start,
                            panel_end,
                            n,
                        )
                    };
                }
            }
            StepCtl::Continue
        },
    );

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::rel_residual_dense;
    use crate::solver::SeqLu;

    /// Force the parallel *column-at-a-time* path regardless of size
    /// (`panel(1)` — the bit-identical shape).
    fn par(lanes: usize, dist: RowDist) -> EbvLu {
        EbvLu::with_lanes(lanes).with_dist(dist).seq_threshold(0).panel(1)
    }

    /// Force the blocked-panel path regardless of size.
    fn blocked(lanes: usize, nb: usize) -> EbvLu {
        EbvLu::with_lanes(lanes).seq_threshold(0).panel(nb)
    }

    #[test]
    fn matches_sequential_exactly_for_all_dists() {
        // The parallel elimination performs the same per-row arithmetic in
        // the same order, so the factors are bit-identical to SeqLu.
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(21));
        let reference = SeqLu::new().factor(&a).unwrap();
        for dist in RowDist::ALL {
            for lanes in [2usize, 3, 4] {
                let f = par(lanes, dist).factor(&a).unwrap();
                assert_eq!(
                    f.packed().max_abs_diff(reference.packed()),
                    0.0,
                    "{dist:?} lanes={lanes}"
                );
            }
        }
    }

    #[test]
    fn explicit_engine_matches_global_engine_bitwise() {
        // Schedule width and pool size are independent: a 4-lane
        // schedule on a 2-lane engine virtualizes without changing a
        // single bit of the factors.
        let a = diag_dominant_dense(80, GenSeed(28));
        let reference = SeqLu::new().factor(&a).unwrap();
        for engine_lanes in [1usize, 2, 3] {
            let engine = Arc::new(LaneEngine::new(engine_lanes));
            let f = par(4, RowDist::EbvFold).with_engine(engine).factor(&a).unwrap();
            assert_eq!(
                f.packed().max_abs_diff(reference.packed()),
                0.0,
                "engine_lanes={engine_lanes}"
            );
        }
    }

    #[test]
    fn solves_with_small_residual() {
        let n = 200;
        let a = diag_dominant_dense(n, GenSeed(22));
        let b = rhs(n, GenSeed(23));
        let x = par(4, RowDist::EbvFold).solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
        // The default (blocked, nb=64) path solves just as tightly.
        let x = EbvLu::with_lanes(4).seq_threshold(0).solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn default_panel_width_is_64() {
        assert_eq!(EbvLu::with_lanes(4).panel_width(), DEFAULT_PANEL_WIDTH);
        assert_eq!(DEFAULT_PANEL_WIDTH, 64);
        // The knob clamps to at least one.
        assert_eq!(EbvLu::with_lanes(4).panel(0).panel_width(), 1);
    }

    #[test]
    fn blocked_panels_match_sequential_within_tolerance() {
        // Panel widths straddling the matrix size; the fused rank-nb
        // update reorders rounding, so agreement is componentwise, not
        // bitwise (see the module docs and DESIGN.md's ledger).
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(31));
        let reference = SeqLu::new().factor(&a).unwrap();
        for nb in [2usize, 5, 8, 64, 96, 200] {
            for lanes in [2usize, 4] {
                let f = blocked(lanes, nb).factor(&a).unwrap();
                let diff = f.packed().max_abs_diff(reference.packed());
                assert!(diff < 1e-9, "nb={nb} lanes={lanes} diff={diff:e}");
            }
        }
    }

    #[test]
    fn panel_covering_the_matrix_is_bitwise_exact() {
        // One panel spanning every column makes each Col step full-width
        // for every row — the exact arithmetic of the column path.
        let a = diag_dominant_dense(40, GenSeed(32));
        let reference = SeqLu::new().factor(&a).unwrap();
        let f = blocked(3, 40).factor(&a).unwrap();
        assert_eq!(f.packed().max_abs_diff(reference.packed()), 0.0);
    }

    #[test]
    fn blocked_bits_are_stable_across_lanes_dists_and_engines() {
        // For a fixed nb each row's arithmetic depends only on the panel
        // decomposition, so the blocked factors are bit-identical no
        // matter how rows are dealt to lanes or how many resident lanes
        // execute them.
        let n = 80;
        let nb = 8;
        let a = diag_dominant_dense(n, GenSeed(33));
        let reference = blocked(2, nb).factor(&a).unwrap();
        for dist in RowDist::ALL {
            for lanes in [2usize, 3, 5] {
                for engine_lanes in [1usize, 2, 3] {
                    let engine = Arc::new(LaneEngine::new(engine_lanes));
                    let f = blocked(lanes, nb)
                        .with_dist(dist)
                        .with_engine(engine)
                        .factor(&a)
                        .unwrap();
                    assert_eq!(
                        f.packed().max_abs_diff(reference.packed()),
                        0.0,
                        "{dist:?} lanes={lanes} engine_lanes={engine_lanes}"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_detects_singular_pivot_mid_panel() {
        let mut a = diag_dominant_dense(64, GenSeed(34));
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        // Row 30 sits mid-panel for nb=8 and inside the first panel for
        // nb=64; both shapes must stop on the bad column.
        for nb in [8usize, 64] {
            let err = blocked(4, nb).factor(&a);
            assert!(
                matches!(err, Err(EbvError::SingularPivot { .. })),
                "nb={nb}: {err:?}"
            );
        }
    }

    #[test]
    fn dataflow_is_bitwise_barrier_for_every_lane_dist_kernel_and_engine() {
        // The lookahead DAG only re-partitions work whose per-element
        // operand order is fixed by (nb, kernel) — so the dataflow
        // schedule must reproduce the barrier factors bit for bit,
        // for every lane count, distribution, microkernel and engine
        // size.
        let n = 80;
        for nb in [8usize, 32] {
            let a = diag_dominant_dense(n, GenSeed(41));
            for kern in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
                let reference = blocked(2, nb).kernel(kern).factor(&a).unwrap();
                for dist in RowDist::ALL {
                    for lanes in [2usize, 3, 5] {
                        for engine_lanes in [1usize, 2, 4] {
                            let engine = Arc::new(LaneEngine::new(engine_lanes));
                            let f = blocked(lanes, nb)
                                .with_dist(dist)
                                .kernel(kern)
                                .schedule(Schedule::Dataflow)
                                .with_engine(engine)
                                .factor(&a)
                                .unwrap();
                            assert_eq!(
                                f.packed().max_abs_diff(reference.packed()),
                                0.0,
                                "nb={nb} {kern:?} {dist:?} lanes={lanes} \
                                 engine_lanes={engine_lanes}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dataflow_detects_singular_pivot_on_the_same_step() {
        let mut a = diag_dominant_dense(64, GenSeed(34));
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        for nb in [8usize, 16] {
            let barrier = blocked(4, nb).factor(&a);
            let dataflow = blocked(4, nb).schedule(Schedule::Dataflow).factor(&a);
            let step_of = |r: &Result<DenseLuFactors>| match r {
                Err(EbvError::SingularPivot { step, .. }) => *step,
                other => panic!("nb={nb}: expected SingularPivot, got {other:?}"),
            };
            // Panel tasks run in panel order and check pivots in the
            // barrier's column order, so the reported step agrees.
            assert_eq!(step_of(&barrier), step_of(&dataflow), "nb={nb}");
            assert_eq!(step_of(&barrier), 30, "nb={nb}");
        }
    }

    #[test]
    fn dataflow_single_panel_falls_back_to_barrier_bits() {
        // nb >= n leaves nothing to overlap; the knob must quietly keep
        // the (bitwise SeqLu-exact) covering-panel path.
        let a = diag_dominant_dense(40, GenSeed(32));
        let reference = SeqLu::new().factor(&a).unwrap();
        let f = blocked(3, 40).schedule(Schedule::Dataflow).factor(&a).unwrap();
        assert_eq!(f.packed().max_abs_diff(reference.packed()), 0.0);
    }

    #[test]
    fn dataflow_costs_one_engine_step_per_factor() {
        let n = 80;
        let nb = 8;
        let a = diag_dominant_dense(n, GenSeed(35));
        let engine = Arc::new(LaneEngine::new(3));

        let before = engine.stats();
        let dep_before = engine.dep_stats();
        blocked(4, nb)
            .schedule(Schedule::Dataflow)
            .with_engine(Arc::clone(&engine))
            .factor(&a)
            .unwrap();
        let after = engine.stats();
        let dep_after = engine.dep_stats();
        // The whole DAG drains inside a single barrier-separated step,
        // while the barrier schedule would pay one per blocked step.
        assert_eq!(after.steps - before.steps, 1);
        assert_eq!(dep_after.runs - dep_before.runs, 1);
        assert!(dep_after.tasks > dep_before.tasks);

        let before = engine.stats();
        blocked(4, nb).with_engine(Arc::clone(&engine)).factor(&a).unwrap();
        let after = engine.stats();
        assert_eq!(
            (after.steps - before.steps) as usize,
            blocked_steps(n, nb).len(),
            "barrier schedule pays one barrier entry per blocked step"
        );
    }

    #[test]
    fn blocked_steps_cover_each_column_once() {
        for (n, nb) in [(8usize, 3usize), (20, 8), (5, 64), (16, 1)] {
            let steps = blocked_steps(n, nb);
            let mut cols = vec![0usize; n];
            let mut updates = 0usize;
            for s in &steps {
                match *s {
                    BlockStep::Col { r, panel_end } => {
                        cols[r] += 1;
                        assert!(r < panel_end && panel_end - r <= nb, "n={n} nb={nb}");
                    }
                    BlockStep::Update { panel_start, panel_end } => {
                        updates += 1;
                        assert!(panel_end > panel_start && panel_end < n);
                        assert!(panel_end - panel_start <= nb);
                    }
                }
            }
            // Every column but the last eliminated exactly once; one
            // trailing update per panel that leaves columns behind it.
            assert_eq!(&cols[..n - 1], &vec![1usize; n - 1][..], "n={n} nb={nb}");
            assert_eq!(cols[n - 1], 0, "n={n} nb={nb}");
            // One trailing update per panel except the last.
            assert_eq!(updates, n.div_ceil(nb) - 1, "n={n} nb={nb}: updates");
        }
    }

    #[test]
    fn sequential_fallthrough_for_small_systems() {
        let a = diag_dominant_dense(16, GenSeed(24));
        // threshold 128 (default) > 16 -> sequential path, still correct.
        let f = EbvLu::with_lanes(8).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn single_lane_degenerates_to_sequential() {
        let a = diag_dominant_dense(64, GenSeed(25));
        let f1 = EbvLu::with_lanes(1).seq_threshold(0).factor(&a).unwrap();
        let f2 = SeqLu::new().factor(&a).unwrap();
        assert_eq!(f1.packed().max_abs_diff(f2.packed()), 0.0);
    }

    #[test]
    fn detects_singular_pivot_in_parallel_path() {
        let mut a = diag_dominant_dense(64, GenSeed(26));
        // Zero out a middle pivot's whole row/column region to force a
        // singular pivot mid-elimination.
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        let err = par(4, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { .. })), "{err:?}");
    }

    #[test]
    fn detects_singular_last_pivot() {
        // 2x2 with dependent rows hits the last-pivot check.
        let a = DenseMatrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]).unwrap();
        let err = par(2, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
    }

    #[test]
    fn more_lanes_than_rows_still_correct() {
        let a = diag_dominant_dense(8, GenSeed(27));
        let f = par(16, RowDist::EbvFold).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn rejects_non_square() {
        assert!(par(2, RowDist::EbvFold).factor(&DenseMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn device_sharded_column_path_is_bitwise_flat() {
        let a = diag_dominant_dense(72, GenSeed(41));
        let reference = SeqLu::new().factor(&a).unwrap();
        for devices in [1usize, 2, 4] {
            let set = Arc::new(DeviceSet::new(devices, 2));
            let f = par(4, RowDist::EbvFold).with_devices(set).factor(&a).unwrap();
            assert_eq!(
                f.packed().max_abs_diff(reference.packed()),
                0.0,
                "devices={devices}"
            );
        }
    }

    #[test]
    fn device_sharded_blocked_path_is_bitwise_flat() {
        let n = 80;
        let nb = 8;
        let a = diag_dominant_dense(n, GenSeed(42));
        let reference = blocked(3, nb).factor(&a).unwrap();
        for devices in [2usize, 3] {
            for dist in [RowDist::EbvFold, RowDist::Cyclic] {
                let set = Arc::new(DeviceSet::new(devices, 2));
                let f = blocked(6, nb).with_dist(dist).with_devices(set).factor(&a).unwrap();
                assert_eq!(
                    f.packed().max_abs_diff(reference.packed()),
                    0.0,
                    "devices={devices} {dist:?}"
                );
            }
        }
    }

    #[test]
    fn device_sharded_counts_the_pivot_broadcast() {
        // The measured exchange of the column path must equal what the
        // cost-model plan prices: the trailing pivot row, once per step.
        let n = 64;
        let a = diag_dominant_dense(n, GenSeed(43));
        let set = Arc::new(DeviceSet::new(2, 2));
        par(4, RowDist::EbvFold).with_devices(Arc::clone(&set)).factor(&a).unwrap();
        let snap = set.snapshot();
        let expect: u64 = (0..n - 1).map(|r| (n - r) as u64).sum();
        assert_eq!(snap.exchange_elems, expect);
        assert_eq!(snap.sharded_jobs, 1);
        assert_eq!(snap.exchange_steps, (n - 1) as u64);
    }

    #[test]
    fn device_sharded_detects_singular_pivot() {
        let mut a = diag_dominant_dense(64, GenSeed(44));
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        for nb in [1usize, 8] {
            let set = Arc::new(DeviceSet::new(2, 2));
            let err =
                EbvLu::with_lanes(4).seq_threshold(0).panel(nb).with_devices(set).factor(&a);
            assert!(
                matches!(err, Err(EbvError::SingularPivot { step: 30, .. })),
                "nb={nb}: {err:?}"
            );
        }
    }

    #[test]
    fn single_device_set_keeps_the_flat_engine_path() {
        // A one-device set never enters the sharded runtime: no sharded
        // jobs are recorded and the factors stay bitwise SeqLu.
        let a = diag_dominant_dense(48, GenSeed(45));
        let set = Arc::new(DeviceSet::new(1, 2));
        let f = par(4, RowDist::EbvFold).with_devices(Arc::clone(&set)).factor(&a).unwrap();
        let reference = SeqLu::new().factor(&a).unwrap();
        assert_eq!(f.packed().max_abs_diff(reference.packed()), 0.0);
        assert_eq!(set.snapshot().sharded_jobs, 0);
    }
}
