//! The paper's parallel solver: **Equal bi-Vectorized LU**.
//!
//! Right-looking elimination where the updated rows are statically owned
//! by worker lanes according to an equalized (fold-paired) distribution
//! — the GPU thread mapping of the paper realized on CPU lanes (see
//! `rust/DESIGN.md` §Substitutions: GTX280 threads → resident
//! [`LaneEngine`] lanes; the tables' GPU-scale numbers come from
//! `gpusim` fed with this exact schedule).
//!
//! Execution runs on the persistent lane engine (`rust/DESIGN.md`
//! §Execution engine): the factorization is one step-loop job with one
//! barrier-separated step per elimination column. After the barrier
//! into step `r`, every lane may safely read pivot row `r` (its final
//! update happened at step `r-1`, sequenced before the barrier). Lanes
//! write only rows they own, so writes are disjoint by construction of
//! [`LaneSchedule`]. The schedule's lane count is a *virtual* width:
//! the engine deals virtual lanes across its resident lanes, so the
//! factors are bit-identical for any pool size.

use std::sync::{Arc, Mutex};

use crate::ebv::schedule::{LaneSchedule, RowDist};
use crate::exec::{LaneEngine, StepCtl};
use crate::matrix::DenseMatrix;
use crate::solver::pivot::Permutation;
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::{EbvError, Result};

/// Parallel EBV LU factorization.
#[derive(Debug, Clone)]
pub struct EbvLu {
    lanes: usize,
    dist: RowDist,
    pivot_tol: f64,
    /// Below this size the parallel machinery costs more than it saves;
    /// fall through to the sequential kernel.
    seq_threshold: usize,
    /// Engine override; `None` submits to the process-global engine.
    engine: Option<Arc<LaneEngine>>,
}

impl EbvLu {
    /// EBV solver with the paper's fold distribution on `lanes` lanes.
    pub fn with_lanes(lanes: usize) -> Self {
        EbvLu {
            lanes: lanes.max(1),
            dist: RowDist::EbvFold,
            pivot_tol: 1e-12,
            seq_threshold: 128,
            engine: None,
        }
    }

    /// Use all available parallelism.
    pub fn auto() -> Self {
        EbvLu::with_lanes(crate::exec::default_lanes())
    }

    /// Override the row-distribution strategy (ablation hook).
    pub fn with_dist(mut self, dist: RowDist) -> Self {
        self.dist = dist;
        self
    }

    /// Submit to a specific engine instead of the process-global one
    /// (the coordinator shares one engine across its workers this way).
    pub fn with_engine(mut self, engine: Arc<LaneEngine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Override the sequential fall-through threshold (bench hook).
    pub fn seq_threshold(mut self, t: usize) -> Self {
        self.seq_threshold = t;
        self
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn dist(&self) -> RowDist {
        self.dist
    }
}

impl LuSolver for EbvLu {
    fn name(&self) -> &'static str {
        "ebv"
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        if !a.is_square() {
            return Err(EbvError::Shape("LU needs a square matrix".into()));
        }
        let n = a.rows();
        if self.lanes == 1 || n <= self.seq_threshold {
            // The parallel path is bitwise-identical in arithmetic order
            // per row, so falling through is exact, not approximate.
            return crate::solver::SeqLu::new().pivot_tol(self.pivot_tol).factor(a);
        }
        let mut lu = a.clone();
        let schedule = LaneSchedule::build(n, self.lanes, self.dist);
        let engine = crate::exec::engine_or_global(self.engine.as_ref());
        parallel_eliminate(&mut lu, &schedule, self.pivot_tol, engine)?;
        Ok(DenseLuFactors::new(lu, Permutation::identity(n)))
    }
}

/// Shared mutable matrix for the engine lanes. Writes are restricted to
/// owned rows (disjoint across lanes); reads of the pivot row are
/// sequenced by the per-step barrier.
struct SharedMatrix {
    ptr: *mut f64,
    cols: usize,
}
unsafe impl Send for SharedMatrix {}
unsafe impl Sync for SharedMatrix {}

impl SharedMatrix {
    /// Immutable view of row `r`. Caller must guarantee no lane is
    /// concurrently writing row `r` (holds for the pivot row after the
    /// step barrier).
    #[inline]
    unsafe fn row(&self, r: usize) -> &[f64] {
        std::slice::from_raw_parts(self.ptr.add(r * self.cols), self.cols)
    }

    /// Mutable view of row `i`. Caller must own row `i`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

fn parallel_eliminate(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    // First singular pivot seen by any lane (steps are synchronized, so
    // every lane records the same pivot at the same step; the engine
    // ends the job on the step where it is detected).
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    engine.run_steps(schedule.lanes(), n - 1, |lane, r| {
        // SAFETY: after the barrier into step r, row r's final update
        // (performed at step r-1 by its owner) has completed; no lane
        // writes row r during step r because active rows are strictly
        // below the pivot.
        let pivot_row = unsafe { shared.row(r) };
        let piv = pivot_row[r];
        if piv.abs() < pivot_tol {
            let mut bad = first_bad.lock().expect("pivot slot");
            if bad.is_none() {
                *bad = Some((r, piv));
            }
            return StepCtl::Break;
        }
        let inv = 1.0 / piv;
        for &i in schedule.active_rows_of(lane, r) {
            // SAFETY: lane owns row i exclusively.
            let row_i = unsafe { shared.row_mut(i) };
            let f = row_i[r] * inv;
            row_i[r] = f;
            if f == 0.0 {
                continue;
            }
            let (head, tail) = row_i.split_at_mut(r + 1);
            let _ = head;
            for (t, &p) in tail.iter_mut().zip(pivot_row[r + 1..].iter()) {
                *t -= f * p;
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    // Check the last pivot too (never used as a divisor during
    // elimination but required for the solve).
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::rel_residual_dense;
    use crate::solver::SeqLu;

    /// Force the parallel path regardless of size.
    fn par(lanes: usize, dist: RowDist) -> EbvLu {
        EbvLu::with_lanes(lanes).with_dist(dist).seq_threshold(0)
    }

    #[test]
    fn matches_sequential_exactly_for_all_dists() {
        // The parallel elimination performs the same per-row arithmetic in
        // the same order, so the factors are bit-identical to SeqLu.
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(21));
        let reference = SeqLu::new().factor(&a).unwrap();
        for dist in RowDist::ALL {
            for lanes in [2usize, 3, 4] {
                let f = par(lanes, dist).factor(&a).unwrap();
                assert_eq!(
                    f.packed().max_abs_diff(reference.packed()),
                    0.0,
                    "{dist:?} lanes={lanes}"
                );
            }
        }
    }

    #[test]
    fn explicit_engine_matches_global_engine_bitwise() {
        // Schedule width and pool size are independent: a 4-lane
        // schedule on a 2-lane engine virtualizes without changing a
        // single bit of the factors.
        let a = diag_dominant_dense(80, GenSeed(28));
        let reference = SeqLu::new().factor(&a).unwrap();
        for engine_lanes in [1usize, 2, 3] {
            let engine = Arc::new(LaneEngine::new(engine_lanes));
            let f = par(4, RowDist::EbvFold).with_engine(engine).factor(&a).unwrap();
            assert_eq!(
                f.packed().max_abs_diff(reference.packed()),
                0.0,
                "engine_lanes={engine_lanes}"
            );
        }
    }

    #[test]
    fn solves_with_small_residual() {
        let n = 200;
        let a = diag_dominant_dense(n, GenSeed(22));
        let b = rhs(n, GenSeed(23));
        let x = par(4, RowDist::EbvFold).solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn sequential_fallthrough_for_small_systems() {
        let a = diag_dominant_dense(16, GenSeed(24));
        // threshold 128 (default) > 16 -> sequential path, still correct.
        let f = EbvLu::with_lanes(8).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn single_lane_degenerates_to_sequential() {
        let a = diag_dominant_dense(64, GenSeed(25));
        let f1 = EbvLu::with_lanes(1).seq_threshold(0).factor(&a).unwrap();
        let f2 = SeqLu::new().factor(&a).unwrap();
        assert_eq!(f1.packed().max_abs_diff(f2.packed()), 0.0);
    }

    #[test]
    fn detects_singular_pivot_in_parallel_path() {
        let mut a = diag_dominant_dense(64, GenSeed(26));
        // Zero out a middle pivot's whole row/column region to force a
        // singular pivot mid-elimination.
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        let err = par(4, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { .. })), "{err:?}");
    }

    #[test]
    fn detects_singular_last_pivot() {
        // 2x2 with dependent rows hits the last-pivot check.
        let a = DenseMatrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]).unwrap();
        let err = par(2, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
    }

    #[test]
    fn more_lanes_than_rows_still_correct() {
        let a = diag_dominant_dense(8, GenSeed(27));
        let f = par(16, RowDist::EbvFold).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn rejects_non_square() {
        assert!(par(2, RowDist::EbvFold).factor(&DenseMatrix::zeros(2, 3)).is_err());
    }
}
