//! The paper's parallel solver: **Equal bi-Vectorized LU**.
//!
//! Right-looking elimination where the updated rows are statically owned
//! by worker lanes according to an equalized (fold-paired) distribution
//! — the GPU thread mapping of the paper realized on CPU lanes (see
//! `rust/DESIGN.md` §Substitutions: GTX280 threads → resident
//! [`LaneEngine`] lanes; the tables' GPU-scale numbers come from
//! `gpusim` fed with this exact schedule).
//!
//! Execution runs on the persistent lane engine (`rust/DESIGN.md`
//! §Execution engine). Two elimination shapes share the engine:
//!
//! * **Column-at-a-time** (`panel(1)`): one barrier-separated step per
//!   elimination column, each a lane-distributed rank-1 update. After
//!   the barrier into step `r`, every lane may safely read pivot row
//!   `r` (its final update happened at step `r-1`, sequenced before
//!   the barrier). Bit-identical to [`SeqLu`](crate::solver::SeqLu).
//! * **Blocked panels** (`panel(nb)`, the default `nb = 64`): columns
//!   are grouped into `nb`-wide panels (see
//!   [`panels`](crate::ebv::schedule::panels)). A panel-column step
//!   updates panel rows full-width (building the `U12` block in place)
//!   but deeper rows only across the panel's own columns; one trailing
//!   step per panel then applies the deferred work as lane-distributed
//!   rank-`nb` updates through the shared trailing-update microkernel
//!   ([`kernel::trailing_update`] — selectable fuse width and cache
//!   tiling, each lane's owned rows forming the outer M partition), so
//!   the trailing matrix is swept once per panel instead of once per
//!   column. The fused multi-column accumulation reorders rounding, so
//!   blocked factors agree with `SeqLu` componentwise rather than
//!   bitwise — but for a fixed kernel choice are themselves bit-stable
//!   across lane counts, distributions, engine sizes and kernel tile
//!   sizes (each row's arithmetic depends only on the panel
//!   decomposition and the kernel's fuse width).
//!
//! In both shapes lanes write only rows they own, so writes are
//! disjoint by construction of [`LaneSchedule`]. The schedule's lane
//! count is a *virtual* width: the engine deals virtual lanes across
//! its resident lanes, so the factors never depend on the pool size.

use std::sync::{Arc, Mutex};

use crate::ebv::schedule::{panels, LaneSchedule, RowDist};
use crate::exec::{DeviceSet, ExchangeBuffer, LaneEngine, StepCtl};
use crate::matrix::DenseMatrix;
use crate::solver::kernel::{self, Kernel};
use crate::solver::pivot::Permutation;
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::{EbvError, Result};

/// Default panel width for the blocked elimination.
pub const DEFAULT_PANEL_WIDTH: usize = 64;

/// Parallel EBV LU factorization.
#[derive(Debug, Clone)]
pub struct EbvLu {
    lanes: usize,
    dist: RowDist,
    pivot_tol: f64,
    /// Below this size the parallel machinery costs more than it saves;
    /// fall through to the sequential kernel.
    seq_threshold: usize,
    /// Panel width `nb` of the blocked elimination; `1` selects the
    /// column-at-a-time path (bit-identical to `SeqLu`).
    panel: usize,
    /// Trailing-update microkernel of the blocked elimination (see
    /// [`kernel`]); resolved once per factorization. Irrelevant on the
    /// column-at-a-time path (`panel(1)`), which has no rank-`nb`
    /// update.
    kernel: Kernel,
    /// Engine override; `None` submits to the process-global engine.
    engine: Option<Arc<LaneEngine>>,
    /// Device-sharded execution: when set with more than one device,
    /// the elimination runs as a two-level job on the set (rows dealt
    /// to devices by greedy LPT, then to vlanes within a device by
    /// `dist`), with the pivot row broadcast through the staged
    /// exchange each step. Bitwise identical to the flat path for
    /// every device count.
    devices: Option<Arc<DeviceSet>>,
}

impl EbvLu {
    /// EBV solver with the paper's fold distribution on `lanes` lanes.
    pub fn with_lanes(lanes: usize) -> Self {
        EbvLu {
            lanes: lanes.max(1),
            dist: RowDist::EbvFold,
            pivot_tol: 1e-12,
            seq_threshold: 128,
            panel: DEFAULT_PANEL_WIDTH,
            kernel: Kernel::Auto,
            engine: None,
            devices: None,
        }
    }

    /// Use all available parallelism.
    pub fn auto() -> Self {
        EbvLu::with_lanes(crate::exec::default_lanes())
    }

    /// Override the row-distribution strategy (ablation hook).
    pub fn with_dist(mut self, dist: RowDist) -> Self {
        self.dist = dist;
        self
    }

    /// Submit to a specific engine instead of the process-global one
    /// (the coordinator shares one engine across its workers this way).
    pub fn with_engine(mut self, engine: Arc<LaneEngine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Execute device-sharded on a [`DeviceSet`] (the coordinator
    /// shares one set across its workers when `service.devices > 1`).
    /// The configured lane count is split across the set's devices
    /// (`ceil(lanes / devices)` vlanes per device); a single-device
    /// set keeps the flat path. Factors are bitwise identical either
    /// way.
    pub fn with_devices(mut self, devices: Arc<DeviceSet>) -> Self {
        self.devices = Some(devices);
        self
    }

    /// Override the sequential fall-through threshold (bench hook).
    pub fn seq_threshold(mut self, t: usize) -> Self {
        self.seq_threshold = t;
        self
    }

    /// Set the panel width `nb` of the blocked elimination. `1` keeps
    /// the column-at-a-time path (bit-identical to `SeqLu`); wider
    /// panels trade that exactness for rank-`nb` trailing updates.
    /// Clamped to at least 1.
    pub fn panel(mut self, nb: usize) -> Self {
        self.panel = nb.max(1);
        self
    }

    /// Select the trailing-update microkernel of the blocked
    /// elimination (default [`Kernel::Auto`] — `EBV_KERNEL` or tiled).
    /// `tiled` and `unroll4` factors are bitwise identical; `unroll8`
    /// agrees componentwise. Every choice is bit-stable across lane
    /// counts, distributions, engine sizes and device counts.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn dist(&self) -> RowDist {
        self.dist
    }

    /// Configured panel width `nb`.
    pub fn panel_width(&self) -> usize {
        self.panel
    }

    /// Configured microkernel choice (possibly [`Kernel::Auto`]).
    pub fn kernel_choice(&self) -> Kernel {
        self.kernel
    }
}

impl LuSolver for EbvLu {
    fn name(&self) -> &'static str {
        "ebv"
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        if !a.is_square() {
            return Err(EbvError::Shape("LU needs a square matrix".into()));
        }
        let n = a.rows();
        if self.lanes == 1 || n <= self.seq_threshold {
            // The parallel path is bitwise-identical in arithmetic order
            // per row, so falling through is exact, not approximate.
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
            return crate::solver::SeqLu::new().pivot_tol(self.pivot_tol).factor(a);
        }
        let mut lu = a.clone();
        if let Some(set) = self.devices.as_ref().filter(|s| s.devices() > 1) {
            let lpd = self.lanes.div_ceil(set.devices()).max(1);
            // The dense "symbolic" phase is schedule construction: the
            // equalized vlane decomposition the paper's method plans.
            let schedule = {
                let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Symbolic);
                LaneSchedule::build_sharded(n, set.devices(), lpd, self.dist)
            };
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
            if self.panel <= 1 {
                parallel_eliminate_sharded(&mut lu, &schedule, self.pivot_tol, set.as_ref())?;
            } else {
                parallel_eliminate_blocked_sharded(
                    &mut lu,
                    &schedule,
                    self.panel,
                    self.kernel.resolve(),
                    self.pivot_tol,
                    set.as_ref(),
                )?;
            }
            return Ok(DenseLuFactors::new(lu, Permutation::identity(n)));
        }
        let schedule = {
            let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Symbolic);
            LaneSchedule::build(n, self.lanes, self.dist)
        };
        let engine = crate::exec::engine_or_global(self.engine.as_ref());
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
        if self.panel <= 1 {
            parallel_eliminate(&mut lu, &schedule, self.pivot_tol, engine)?;
        } else {
            parallel_eliminate_blocked(
                &mut lu,
                &schedule,
                self.panel,
                self.kernel.resolve(),
                self.pivot_tol,
                engine,
            )?;
        }
        Ok(DenseLuFactors::new(lu, Permutation::identity(n)))
    }
}

/// Shared mutable matrix for the engine lanes. Writes are restricted to
/// owned rows (disjoint across lanes); reads of the pivot row are
/// sequenced by the per-step barrier.
struct SharedMatrix {
    ptr: *mut f64,
    cols: usize,
}
unsafe impl Send for SharedMatrix {}
unsafe impl Sync for SharedMatrix {}

impl SharedMatrix {
    /// Immutable view of row `r`. Caller must guarantee no lane is
    /// concurrently writing row `r` (holds for the pivot row after the
    /// step barrier).
    #[inline]
    unsafe fn row(&self, r: usize) -> &[f64] {
        std::slice::from_raw_parts(self.ptr.add(r * self.cols), self.cols)
    }

    /// Mutable view of row `i`. Caller must own row `i`.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

fn parallel_eliminate(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    // First singular pivot seen by any lane (steps are synchronized, so
    // every lane records the same pivot at the same step; the engine
    // ends the job on the step where it is detected).
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    engine.run_steps(schedule.lanes(), n - 1, |lane, r| {
        // SAFETY: after the barrier into step r, row r's final update
        // (performed at step r-1 by its owner) has completed; no lane
        // writes row r during step r because active rows are strictly
        // below the pivot.
        let pivot_row = unsafe { shared.row(r) };
        let piv = pivot_row[r];
        if piv.abs() < pivot_tol {
            let mut bad = first_bad.lock().expect("pivot slot");
            if bad.is_none() {
                *bad = Some((r, piv));
            }
            return StepCtl::Break;
        }
        let inv = 1.0 / piv;
        for &i in schedule.active_rows_of(lane, r) {
            // SAFETY: lane owns row i exclusively.
            let row_i = unsafe { shared.row_mut(i) };
            let f = row_i[r] * inv;
            row_i[r] = f;
            if f == 0.0 {
                continue;
            }
            let (head, tail) = row_i.split_at_mut(r + 1);
            let _ = head;
            for (t, &p) in tail.iter_mut().zip(pivot_row[r + 1..].iter()) {
                *t -= f * p;
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    // Check the last pivot too (never used as a divisor during
    // elimination but required for the solve).
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// Device-sharded column-at-a-time elimination: the same arithmetic as
/// [`parallel_eliminate`] executed as a two-level [`DeviceSet`] job.
/// Each step the exchange phase (device 0's host) validates the pivot
/// and broadcasts the trailing pivot row through the staged
/// [`ExchangeBuffer`] (a bit-exact copy — the realized counterpart of
/// the `gpusim::cluster` broadcast term); every device then updates its
/// owned rows reading the staged row. Factors are bitwise identical to
/// the flat path for every device count, lane count and distribution.
fn parallel_eliminate_sharded(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    pivot_tol: f64,
    set: &DeviceSet,
) -> Result<()> {
    let n = lu.rows();
    let lpd = schedule.lanes_per_device();
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let mut staged = vec![0.0f64; n];
    let stage = ExchangeBuffer::new(&mut staged);
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    set.run_sharded(
        lpd,
        n - 1,
        |r| {
            // SAFETY: row r's final update (performed at step r-1 by its
            // owner) was published by the closing cross-device barrier;
            // no device computes while the exchange runs.
            let pivot_row = unsafe { shared.row(r) };
            let piv = pivot_row[r];
            if piv.abs() < pivot_tol {
                let mut bad = first_bad.lock().expect("pivot slot");
                if bad.is_none() {
                    *bad = Some((r, piv));
                }
                return StepCtl::Break;
            }
            // SAFETY: exchange phase — sole accessor of the stage.
            unsafe { stage.stage(r, &pivot_row[r..]) };
            set.record_exchange(n - r);
            StepCtl::Continue
        },
        |dev, vlane, r| {
            // SAFETY: compute phase — the stage is read-only everywhere.
            let pivot_row = unsafe { stage.staged() };
            let inv = 1.0 / pivot_row[r];
            for &i in schedule.active_rows_of(dev * lpd + vlane, r) {
                // SAFETY: this (device, vlane) owns row i exclusively.
                let row_i = unsafe { shared.row_mut(i) };
                let f = row_i[r] * inv;
                row_i[r] = f;
                if f == 0.0 {
                    continue;
                }
                let (head, tail) = row_i.split_at_mut(r + 1);
                let _ = head;
                for (t, &p) in tail.iter_mut().zip(pivot_row[r + 1..].iter()) {
                    *t -= f * p;
                }
            }
            StepCtl::Continue
        },
    );

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// One barrier-separated step of the blocked elimination.
#[derive(Debug, Clone, Copy)]
enum BlockStep {
    /// Eliminate panel column `r`: rows inside the panel (`i <
    /// panel_end`) carry their whole trailing row forward (building the
    /// `U12` block incrementally), rows below the panel compute their
    /// multiplier and update only columns `r+1..panel_end` — their wide
    /// update is deferred to the panel's `Update` step.
    Col { r: usize, panel_end: usize },
    /// Rank-`(panel_end - panel_start)` trailing update: every owned
    /// row at or below `panel_end` absorbs the whole panel in one
    /// GEMM-style pass.
    Update { panel_start: usize, panel_end: usize },
}

/// Flatten the panel decomposition into the engine's step sequence.
fn blocked_steps(n: usize, nb: usize) -> Vec<BlockStep> {
    let mut steps = Vec::new();
    for (k, end) in panels(n, nb) {
        for r in k..end.min(n.saturating_sub(1)) {
            steps.push(BlockStep::Col { r, panel_end: end });
        }
        if end < n {
            steps.push(BlockStep::Update { panel_start: k, panel_end: end });
        }
    }
    steps
}

fn parallel_eliminate_blocked(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    nb: usize,
    kern: Kernel,
    pivot_tol: f64,
    engine: &LaneEngine,
) -> Result<()> {
    let n = lu.rows();
    let steps = blocked_steps(n, nb);
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    engine.run_steps(schedule.lanes(), steps.len(), |lane, s| {
        match steps[s] {
            BlockStep::Col { r, panel_end } => {
                // SAFETY: row r's final write (its owner at the previous
                // Col step, or the preceding panel's Update step) is
                // sequenced before the barrier into this step; no lane
                // writes row r now (active rows are strictly below it).
                let pivot_row = unsafe { shared.row(r) };
                let piv = pivot_row[r];
                if piv.abs() < pivot_tol {
                    let mut bad = first_bad.lock().expect("pivot slot");
                    if bad.is_none() {
                        *bad = Some((r, piv));
                    }
                    return StepCtl::Break;
                }
                let inv = 1.0 / piv;
                for &i in schedule.active_rows_of(lane, r) {
                    // SAFETY: lane owns row i exclusively.
                    let row_i = unsafe { shared.row_mut(i) };
                    let f = row_i[r] * inv;
                    row_i[r] = f;
                    if f == 0.0 {
                        continue;
                    }
                    let hi = if i < panel_end { n } else { panel_end };
                    for (t, &p) in
                        row_i[r + 1..hi].iter_mut().zip(pivot_row[r + 1..hi].iter())
                    {
                        *t -= f * p;
                    }
                }
            }
            BlockStep::Update { panel_start, panel_end } => {
                // SAFETY: the lane's `rows_from` range is owned
                // exclusively (disjoint across lanes by LaneSchedule
                // construction); the panel rows the kernel reads (U12)
                // satisfy panel_start + p < panel_end <= i, so they
                // alias no write, and their final updates happened at
                // Col steps sequenced before this barrier.
                unsafe {
                    kernel::trailing_update(
                        kern,
                        kernel::MatView::from_raw(shared.ptr, shared.cols),
                        schedule.rows_from(lane, panel_end),
                        panel_start,
                        panel_end,
                        n,
                    )
                };
            }
        }
        StepCtl::Continue
    });

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

/// Device-sharded blocked-panel elimination: the step sequence of
/// [`parallel_eliminate_blocked`] on a [`DeviceSet`]. Col steps
/// broadcast the trailing pivot row through the staged exchange (and
/// validate the pivot centrally); Update steps read the finalized
/// panel rows in place — published by the closing barrier of their Col
/// steps — and only account the `U12` broadcast the cost model prices.
/// Per-row arithmetic depends solely on the panel decomposition, so
/// for fixed `nb` the factors are bitwise identical to the flat
/// blocked path for every device count.
fn parallel_eliminate_blocked_sharded(
    lu: &mut DenseMatrix,
    schedule: &LaneSchedule,
    nb: usize,
    kern: Kernel,
    pivot_tol: f64,
    set: &DeviceSet,
) -> Result<()> {
    let n = lu.rows();
    let lpd = schedule.lanes_per_device();
    let steps = blocked_steps(n, nb);
    let shared = SharedMatrix { ptr: lu.data_mut().as_mut_ptr(), cols: n };
    let mut staged = vec![0.0f64; n];
    let stage = ExchangeBuffer::new(&mut staged);
    let first_bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

    set.run_sharded(
        lpd,
        steps.len(),
        |s| match steps[s] {
            BlockStep::Col { r, panel_end: _ } => {
                // SAFETY: row r's final write (its owner at the previous
                // Col step, or the preceding panel's Update step) was
                // published by the closing cross-device barrier.
                let pivot_row = unsafe { shared.row(r) };
                let piv = pivot_row[r];
                if piv.abs() < pivot_tol {
                    let mut bad = first_bad.lock().expect("pivot slot");
                    if bad.is_none() {
                        *bad = Some((r, piv));
                    }
                    return StepCtl::Break;
                }
                // SAFETY: exchange phase — sole accessor of the stage.
                unsafe { stage.stage(r, &pivot_row[r..]) };
                set.record_exchange(n - r);
                StepCtl::Continue
            }
            BlockStep::Update { panel_start, panel_end } => {
                // The panel's U12 block travels to every device; it is
                // read in place (finalized before the barrier), so the
                // broadcast is accounted, not copied.
                set.record_exchange((panel_end - panel_start) * (n - panel_end));
                StepCtl::Continue
            }
        },
        |dev, vlane, s| {
            let lane = dev * lpd + vlane;
            match steps[s] {
                BlockStep::Col { r, panel_end } => {
                    // SAFETY: compute phase — the stage is read-only.
                    let pivot_row = unsafe { stage.staged() };
                    let inv = 1.0 / pivot_row[r];
                    for &i in schedule.active_rows_of(lane, r) {
                        // SAFETY: this (device, vlane) owns row i.
                        let row_i = unsafe { shared.row_mut(i) };
                        let f = row_i[r] * inv;
                        row_i[r] = f;
                        if f == 0.0 {
                            continue;
                        }
                        let hi = if i < panel_end { n } else { panel_end };
                        for (t, &p) in
                            row_i[r + 1..hi].iter_mut().zip(pivot_row[r + 1..hi].iter())
                        {
                            *t -= f * p;
                        }
                    }
                }
                BlockStep::Update { panel_start, panel_end } => {
                    // SAFETY: same argument as the flat Update step; the
                    // panel rows' final Col-step writes were published
                    // by the closing cross-device barrier.
                    unsafe {
                        kernel::trailing_update(
                            kern,
                            kernel::MatView::from_raw(shared.ptr, shared.cols),
                            schedule.rows_from(lane, panel_end),
                            panel_start,
                            panel_end,
                            n,
                        )
                    };
                }
            }
            StepCtl::Continue
        },
    );

    if let Some((step, value)) = first_bad.into_inner().expect("pivot slot") {
        return Err(EbvError::SingularPivot { step, value, tol: pivot_tol });
    }
    let last = lu.get(n - 1, n - 1);
    if last.abs() < pivot_tol {
        return Err(EbvError::SingularPivot { step: n - 1, value: last, tol: pivot_tol });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::rel_residual_dense;
    use crate::solver::SeqLu;

    /// Force the parallel *column-at-a-time* path regardless of size
    /// (`panel(1)` — the bit-identical shape).
    fn par(lanes: usize, dist: RowDist) -> EbvLu {
        EbvLu::with_lanes(lanes).with_dist(dist).seq_threshold(0).panel(1)
    }

    /// Force the blocked-panel path regardless of size.
    fn blocked(lanes: usize, nb: usize) -> EbvLu {
        EbvLu::with_lanes(lanes).seq_threshold(0).panel(nb)
    }

    #[test]
    fn matches_sequential_exactly_for_all_dists() {
        // The parallel elimination performs the same per-row arithmetic in
        // the same order, so the factors are bit-identical to SeqLu.
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(21));
        let reference = SeqLu::new().factor(&a).unwrap();
        for dist in RowDist::ALL {
            for lanes in [2usize, 3, 4] {
                let f = par(lanes, dist).factor(&a).unwrap();
                assert_eq!(
                    f.packed().max_abs_diff(reference.packed()),
                    0.0,
                    "{dist:?} lanes={lanes}"
                );
            }
        }
    }

    #[test]
    fn explicit_engine_matches_global_engine_bitwise() {
        // Schedule width and pool size are independent: a 4-lane
        // schedule on a 2-lane engine virtualizes without changing a
        // single bit of the factors.
        let a = diag_dominant_dense(80, GenSeed(28));
        let reference = SeqLu::new().factor(&a).unwrap();
        for engine_lanes in [1usize, 2, 3] {
            let engine = Arc::new(LaneEngine::new(engine_lanes));
            let f = par(4, RowDist::EbvFold).with_engine(engine).factor(&a).unwrap();
            assert_eq!(
                f.packed().max_abs_diff(reference.packed()),
                0.0,
                "engine_lanes={engine_lanes}"
            );
        }
    }

    #[test]
    fn solves_with_small_residual() {
        let n = 200;
        let a = diag_dominant_dense(n, GenSeed(22));
        let b = rhs(n, GenSeed(23));
        let x = par(4, RowDist::EbvFold).solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
        // The default (blocked, nb=64) path solves just as tightly.
        let x = EbvLu::with_lanes(4).seq_threshold(0).solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn default_panel_width_is_64() {
        assert_eq!(EbvLu::with_lanes(4).panel_width(), DEFAULT_PANEL_WIDTH);
        assert_eq!(DEFAULT_PANEL_WIDTH, 64);
        // The knob clamps to at least one.
        assert_eq!(EbvLu::with_lanes(4).panel(0).panel_width(), 1);
    }

    #[test]
    fn blocked_panels_match_sequential_within_tolerance() {
        // Panel widths straddling the matrix size; the fused rank-nb
        // update reorders rounding, so agreement is componentwise, not
        // bitwise (see the module docs and DESIGN.md's ledger).
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(31));
        let reference = SeqLu::new().factor(&a).unwrap();
        for nb in [2usize, 5, 8, 64, 96, 200] {
            for lanes in [2usize, 4] {
                let f = blocked(lanes, nb).factor(&a).unwrap();
                let diff = f.packed().max_abs_diff(reference.packed());
                assert!(diff < 1e-9, "nb={nb} lanes={lanes} diff={diff:e}");
            }
        }
    }

    #[test]
    fn panel_covering_the_matrix_is_bitwise_exact() {
        // One panel spanning every column makes each Col step full-width
        // for every row — the exact arithmetic of the column path.
        let a = diag_dominant_dense(40, GenSeed(32));
        let reference = SeqLu::new().factor(&a).unwrap();
        let f = blocked(3, 40).factor(&a).unwrap();
        assert_eq!(f.packed().max_abs_diff(reference.packed()), 0.0);
    }

    #[test]
    fn blocked_bits_are_stable_across_lanes_dists_and_engines() {
        // For a fixed nb each row's arithmetic depends only on the panel
        // decomposition, so the blocked factors are bit-identical no
        // matter how rows are dealt to lanes or how many resident lanes
        // execute them.
        let n = 80;
        let nb = 8;
        let a = diag_dominant_dense(n, GenSeed(33));
        let reference = blocked(2, nb).factor(&a).unwrap();
        for dist in RowDist::ALL {
            for lanes in [2usize, 3, 5] {
                for engine_lanes in [1usize, 2, 3] {
                    let engine = Arc::new(LaneEngine::new(engine_lanes));
                    let f = blocked(lanes, nb)
                        .with_dist(dist)
                        .with_engine(engine)
                        .factor(&a)
                        .unwrap();
                    assert_eq!(
                        f.packed().max_abs_diff(reference.packed()),
                        0.0,
                        "{dist:?} lanes={lanes} engine_lanes={engine_lanes}"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_detects_singular_pivot_mid_panel() {
        let mut a = diag_dominant_dense(64, GenSeed(34));
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        // Row 30 sits mid-panel for nb=8 and inside the first panel for
        // nb=64; both shapes must stop on the bad column.
        for nb in [8usize, 64] {
            let err = blocked(4, nb).factor(&a);
            assert!(
                matches!(err, Err(EbvError::SingularPivot { .. })),
                "nb={nb}: {err:?}"
            );
        }
    }

    #[test]
    fn blocked_steps_cover_each_column_once() {
        for (n, nb) in [(8usize, 3usize), (20, 8), (5, 64), (16, 1)] {
            let steps = blocked_steps(n, nb);
            let mut cols = vec![0usize; n];
            let mut updates = 0usize;
            for s in &steps {
                match *s {
                    BlockStep::Col { r, panel_end } => {
                        cols[r] += 1;
                        assert!(r < panel_end && panel_end - r <= nb, "n={n} nb={nb}");
                    }
                    BlockStep::Update { panel_start, panel_end } => {
                        updates += 1;
                        assert!(panel_end > panel_start && panel_end < n);
                        assert!(panel_end - panel_start <= nb);
                    }
                }
            }
            // Every column but the last eliminated exactly once; one
            // trailing update per panel that leaves columns behind it.
            assert_eq!(&cols[..n - 1], &vec![1usize; n - 1][..], "n={n} nb={nb}");
            assert_eq!(cols[n - 1], 0, "n={n} nb={nb}");
            // One trailing update per panel except the last.
            assert_eq!(updates, n.div_ceil(nb) - 1, "n={n} nb={nb}: updates");
        }
    }

    #[test]
    fn sequential_fallthrough_for_small_systems() {
        let a = diag_dominant_dense(16, GenSeed(24));
        // threshold 128 (default) > 16 -> sequential path, still correct.
        let f = EbvLu::with_lanes(8).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn single_lane_degenerates_to_sequential() {
        let a = diag_dominant_dense(64, GenSeed(25));
        let f1 = EbvLu::with_lanes(1).seq_threshold(0).factor(&a).unwrap();
        let f2 = SeqLu::new().factor(&a).unwrap();
        assert_eq!(f1.packed().max_abs_diff(f2.packed()), 0.0);
    }

    #[test]
    fn detects_singular_pivot_in_parallel_path() {
        let mut a = diag_dominant_dense(64, GenSeed(26));
        // Zero out a middle pivot's whole row/column region to force a
        // singular pivot mid-elimination.
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        let err = par(4, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { .. })), "{err:?}");
    }

    #[test]
    fn detects_singular_last_pivot() {
        // 2x2 with dependent rows hits the last-pivot check.
        let a = DenseMatrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]).unwrap();
        let err = par(2, RowDist::EbvFold).factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
    }

    #[test]
    fn more_lanes_than_rows_still_correct() {
        let a = diag_dominant_dense(8, GenSeed(27));
        let f = par(16, RowDist::EbvFold).factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn rejects_non_square() {
        assert!(par(2, RowDist::EbvFold).factor(&DenseMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn device_sharded_column_path_is_bitwise_flat() {
        let a = diag_dominant_dense(72, GenSeed(41));
        let reference = SeqLu::new().factor(&a).unwrap();
        for devices in [1usize, 2, 4] {
            let set = Arc::new(DeviceSet::new(devices, 2));
            let f = par(4, RowDist::EbvFold).with_devices(set).factor(&a).unwrap();
            assert_eq!(
                f.packed().max_abs_diff(reference.packed()),
                0.0,
                "devices={devices}"
            );
        }
    }

    #[test]
    fn device_sharded_blocked_path_is_bitwise_flat() {
        let n = 80;
        let nb = 8;
        let a = diag_dominant_dense(n, GenSeed(42));
        let reference = blocked(3, nb).factor(&a).unwrap();
        for devices in [2usize, 3] {
            for dist in [RowDist::EbvFold, RowDist::Cyclic] {
                let set = Arc::new(DeviceSet::new(devices, 2));
                let f = blocked(6, nb).with_dist(dist).with_devices(set).factor(&a).unwrap();
                assert_eq!(
                    f.packed().max_abs_diff(reference.packed()),
                    0.0,
                    "devices={devices} {dist:?}"
                );
            }
        }
    }

    #[test]
    fn device_sharded_counts_the_pivot_broadcast() {
        // The measured exchange of the column path must equal what the
        // cost-model plan prices: the trailing pivot row, once per step.
        let n = 64;
        let a = diag_dominant_dense(n, GenSeed(43));
        let set = Arc::new(DeviceSet::new(2, 2));
        par(4, RowDist::EbvFold).with_devices(Arc::clone(&set)).factor(&a).unwrap();
        let snap = set.snapshot();
        let expect: u64 = (0..n - 1).map(|r| (n - r) as u64).sum();
        assert_eq!(snap.exchange_elems, expect);
        assert_eq!(snap.sharded_jobs, 1);
        assert_eq!(snap.exchange_steps, (n - 1) as u64);
    }

    #[test]
    fn device_sharded_detects_singular_pivot() {
        let mut a = diag_dominant_dense(64, GenSeed(44));
        for j in 0..64 {
            a.set(30, j, 0.0);
        }
        for nb in [1usize, 8] {
            let set = Arc::new(DeviceSet::new(2, 2));
            let err =
                EbvLu::with_lanes(4).seq_threshold(0).panel(nb).with_devices(set).factor(&a);
            assert!(
                matches!(err, Err(EbvError::SingularPivot { step: 30, .. })),
                "nb={nb}: {err:?}"
            );
        }
    }

    #[test]
    fn single_device_set_keeps_the_flat_engine_path() {
        // A one-device set never enters the sharded runtime: no sharded
        // jobs are recorded and the factors stay bitwise SeqLu.
        let a = diag_dominant_dense(48, GenSeed(45));
        let set = Arc::new(DeviceSet::new(1, 2));
        let f = par(4, RowDist::EbvFold).with_devices(Arc::clone(&set)).factor(&a).unwrap();
        let reference = SeqLu::new().factor(&a).unwrap();
        assert_eq!(f.packed().max_abs_diff(reference.packed()), 0.0);
        assert_eq!(set.snapshot().sharded_jobs, 0);
    }
}
