//! Sparse LU factorization (Gilbert–Peierls style, row-wise, no
//! pivoting) with symbolic fill tracking and EBV-equalized parallel
//! triangular solves.
//!
//! Row `i` of the factors is computed by a sparse lower-triangular solve
//! against the already-finished rows: take row `i` of `A` into a sparse
//! accumulator, and for each `j < i` present in the accumulator (in
//! ascending order) subtract `acc[j]/u_jj × U[j, :]`. Entries `< i` land
//! in `L`, the rest in `U`. Fill-in appears naturally as new accumulator
//! indices. Diagonal dominance (the paper's Eq. 2 setting) makes the
//! pivot-free elimination well-defined.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::exec::Schedule;
use crate::matrix::CsrMatrix;
use crate::solver::trisolve::{
    levels_of_lower, levels_of_upper, sparse_backward, sparse_backward_dataflow,
    sparse_backward_levels, sparse_forward_unit, sparse_forward_unit_dataflow,
    sparse_forward_unit_levels,
};
use crate::util::error::{EbvError, Result};

/// Sparse LU factors: `L` strictly lower (unit diagonal implicit),
/// `U` upper including diagonal, plus the level schedules of both
/// triangles (forward solves on `L`'s levels, backward on `U`'s).
#[derive(Debug, Clone)]
pub struct SparseLuFactors {
    l: CsrMatrix,
    u: CsrMatrix,
    /// Rows grouped by dependency level of `L` (parallel forward solve).
    by_level: Vec<Vec<usize>>,
    /// Rows grouped by dependency level of `U` (parallel backward solve).
    u_by_level: Vec<Vec<usize>>,
    /// Parallel-solve scheduling discipline. [`Schedule::Barrier`] walks
    /// the level lists with one engine step per level;
    /// [`Schedule::Dataflow`] replaces the level barriers with per-row
    /// dependency counters. Per-row arithmetic is identical either way,
    /// so both produce bitwise-equal solutions — the level structure is
    /// retained as the fallback (and for sharded solves, which stay on
    /// levels regardless).
    schedule: Schedule,
}

impl SparseLuFactors {
    /// Assemble factors from finished triangles, computing both level
    /// schedules — the single construction path shared by
    /// [`SparseLu::factor`] and the symbolic/numeric split
    /// (`SparseSymbolic`), so every factor object carries consistent
    /// solve schedules.
    pub(crate) fn from_parts(l: CsrMatrix, u: CsrMatrix) -> SparseLuFactors {
        let (_, by_level) = levels_of_lower(&l);
        let (_, u_by_level) = levels_of_upper(&u);
        SparseLuFactors { l, u, by_level, u_by_level, schedule: Schedule::Barrier }
    }

    /// Pick the parallel-solve scheduling discipline (builder style, so
    /// `SparseSymbolic::assemble` can stamp its own knob onto every
    /// factor object it produces). Defaults to [`Schedule::Barrier`].
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// The scheduling discipline parallel solves will use.
    pub fn schedule_choice(&self) -> Schedule {
        self.schedule
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.l.rows()
    }

    #[inline]
    pub fn l(&self) -> &CsrMatrix {
        &self.l
    }

    #[inline]
    pub fn u(&self) -> &CsrMatrix {
        &self.u
    }

    /// Number of dependency levels in the forward solve.
    pub fn level_count(&self) -> usize {
        self.by_level.len()
    }

    /// Number of dependency levels in the backward solve (`U`'s DAG).
    pub fn backward_level_count(&self) -> usize {
        self.u_by_level.len()
    }

    /// Fill-in: factor nnz (L + U) minus original nnz.
    pub fn fill_in(&self, a: &CsrMatrix) -> isize {
        (self.l.nnz() + self.u.nnz()) as isize - a.nnz() as isize
    }

    /// Sequential solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let y = sparse_forward_unit(&self.l, b)?;
        sparse_backward(&self.u, &y)
    }

    /// Parallel solve using the level schedules with `lanes` lanes on
    /// the process-global lane engine.
    pub fn solve_par(&self, b: &[f64], lanes: usize) -> Result<Vec<f64>> {
        self.solve_par_on(b, lanes, crate::exec::global())
    }

    /// Parallel solve on a specific engine (the coordinator's workers
    /// share one engine this way): level-scheduled forward substitution
    /// on `L`'s DAG, then level-scheduled backward substitution on
    /// `U`'s — both bitwise identical to the sequential solves.
    pub fn solve_par_on(
        &self,
        b: &[f64],
        lanes: usize,
        engine: &crate::exec::LaneEngine,
    ) -> Result<Vec<f64>> {
        if self.schedule == Schedule::Dataflow {
            let y = sparse_forward_unit_dataflow(&self.l, b, lanes, engine)?;
            return sparse_backward_dataflow(&self.u, &y, lanes, engine);
        }
        let y = sparse_forward_unit_levels(&self.l, b, &self.by_level, lanes, engine)?;
        sparse_backward_levels(&self.u, &y, &self.u_by_level, lanes, engine)
    }

    /// Device-sharded parallel solve on a
    /// [`DeviceSet`](crate::exec::DeviceSet): both level-scheduled
    /// substitutions run sharded (levels dealt devices-first), bitwise
    /// identical to [`SparseLuFactors::solve`] for every device count.
    /// A single-device set falls through to [`solve_par_on`] on its
    /// engine.
    ///
    /// [`solve_par_on`]: SparseLuFactors::solve_par_on
    pub fn solve_sharded(
        &self,
        b: &[f64],
        lanes: usize,
        set: &crate::exec::DeviceSet,
    ) -> Result<Vec<f64>> {
        let y = crate::solver::trisolve::sparse_forward_unit_levels_sharded(
            &self.l,
            b,
            &self.by_level,
            lanes,
            set,
        )?;
        crate::solver::trisolve::sparse_backward_levels_sharded(
            &self.u,
            &y,
            &self.u_by_level,
            lanes,
            set,
        )
    }
}

/// Sparse LU factorizer.
#[derive(Debug, Clone)]
pub struct SparseLu {
    pivot_tol: f64,
    /// Drop tolerance for computed factor entries (0.0 = exact, keep all).
    drop_tol: f64,
}

impl SparseLu {
    pub fn new() -> Self {
        SparseLu { pivot_tol: 1e-12, drop_tol: 0.0 }
    }

    /// ILU-style variant dropping factor entries below `tol` (used by the
    /// iterative-refinement example to trade accuracy for fill).
    pub fn with_drop_tol(mut self, tol: f64) -> Self {
        self.drop_tol = tol;
        self
    }

    pub fn factor(&self, a: &CsrMatrix) -> Result<SparseLuFactors> {
        if a.rows() != a.cols() {
            return Err(EbvError::Shape("sparse LU needs a square matrix".into()));
        }
        let n = a.rows();

        // Incrementally built factors (rows arrive in order -> CSR pushes).
        let mut l_ptr = vec![0usize];
        let mut l_idx: Vec<usize> = Vec::new();
        let mut l_val: Vec<f64> = Vec::new();
        let mut u_ptr = vec![0usize];
        let mut u_idx: Vec<usize> = Vec::new();
        let mut u_val: Vec<f64> = Vec::new();

        // Dense accumulator + membership bitmap + ordered worklists.
        //
        // PERF NOTE (EXPERIMENTS.md §Perf, L3-S1): the original
        // implementation kept the row pattern in a `BTreeSet`; pointer-
        // chasing its rebalancing on ~1.7M fill entries dominated the
        // n=2000 factor at 1.17 s. A min-heap over the sub-diagonal
        // worklist plus an unsorted super-diagonal list (sorted once per
        // row) cut the same factor to ~0.35 s (3.3×).
        let mut acc = vec![0.0f64; n];
        let mut in_pattern = vec![false; n];
        // Sub-diagonal candidates, popped in ascending order (the update
        // can insert new indices mid-elimination).
        let mut lower: BinaryHeap<Reverse<usize>> = BinaryHeap::new();
        // Super-diagonal pattern, sorted once when the row is emitted.
        let mut upper: Vec<usize> = Vec::new();

        // Row views of U built so far (avoid re-walking u_ptr).
        let mut u_rows: Vec<(Vec<usize>, Vec<f64>)> = Vec::with_capacity(n);
        let mut u_diag = vec![0.0f64; n];

        for i in 0..n {
            // Scatter row i of A (CSR columns are unique within a row).
            let (cols, vals) = a.row(i);
            for (&j, &v) in cols.iter().zip(vals.iter()) {
                acc[j] = v;
                in_pattern[j] = true;
                if j < i {
                    lower.push(Reverse(j));
                } else {
                    upper.push(j);
                }
            }

            // Eliminate dependencies in ascending column order.
            let mut l_entries: Vec<(usize, f64)> = Vec::new();
            while let Some(Reverse(j)) = lower.pop() {
                let f = acc[j] / u_diag[j];
                acc[j] = 0.0;
                in_pattern[j] = false;
                if f != 0.0 && f.abs() > self.drop_tol {
                    l_entries.push((j, f));
                    let (ucols, uvals) = &u_rows[j];
                    for (&c, &v) in ucols.iter().zip(uvals.iter()) {
                        if c == j {
                            continue; // diagonal handled via u_diag
                        }
                        if !in_pattern[c] {
                            in_pattern[c] = true;
                            if c < i {
                                lower.push(Reverse(c));
                            } else {
                                upper.push(c);
                            }
                            acc[c] = -f * v;
                        } else {
                            acc[c] -= f * v;
                        }
                    }
                }
            }

            // Emit L row (heap pops were ascending).
            for (j, f) in l_entries {
                l_idx.push(j);
                l_val.push(f);
            }
            l_ptr.push(l_idx.len());

            // Emit U row from the super-diagonal pattern (>= i).
            upper.sort_unstable();
            let mut urow_cols = Vec::new();
            let mut urow_vals = Vec::new();
            let mut diag = 0.0;
            for &j in &upper {
                debug_assert!(j >= i);
                let v = acc[j];
                if j == i {
                    diag = v;
                }
                if v != 0.0 && (j == i || v.abs() > self.drop_tol) {
                    urow_cols.push(j);
                    urow_vals.push(v);
                }
            }
            // Reset accumulator state for the next row.
            for &j in &upper {
                acc[j] = 0.0;
                in_pattern[j] = false;
            }
            upper.clear();

            if diag.abs() < self.pivot_tol {
                return Err(EbvError::SingularPivot { step: i, value: diag, tol: self.pivot_tol });
            }
            u_diag[i] = diag;
            for (&c, &v) in urow_cols.iter().zip(urow_vals.iter()) {
                u_idx.push(c);
                u_val.push(v);
            }
            u_ptr.push(u_idx.len());
            u_rows.push((urow_cols, urow_vals));
        }

        let l = CsrMatrix::from_raw(n, n, l_ptr, l_idx, l_val)?;
        let u = CsrMatrix::from_raw(n, n, u_ptr, u_idx, u_val)?;
        Ok(SparseLuFactors::from_parts(l, u))
    }

    /// Factor and solve in one call.
    pub fn solve(&self, a: &CsrMatrix, b: &[f64]) -> Result<Vec<f64>> {
        self.factor(a)?.solve(b)
    }
}

impl Default for SparseLu {
    fn default() -> Self {
        SparseLu::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{
        diag_dominant_sparse, manufactured_solution, poisson_2d, GenSeed,
    };
    use crate::matrix::norms::{diff_inf, rel_residual_csr};
    use crate::matrix::DenseMatrix;
    use crate::solver::{LuSolver, SeqLu};

    #[test]
    fn matches_dense_lu_factors() {
        let a = diag_dominant_sparse(30, 4, GenSeed(41));
        let f = SparseLu::new().factor(&a).unwrap();
        let dense_f = SeqLu::new().factor(&a.to_dense()).unwrap();
        // Compare packed LU against the sparse factors densified.
        let mut packed = f.u().to_dense();
        let ld = f.l().to_dense();
        for i in 0..30 {
            for j in 0..i {
                packed.set(i, j, ld.get(i, j));
            }
        }
        assert!(packed.max_abs_diff(dense_f.packed()) < 1e-9);
    }

    #[test]
    fn l_is_strictly_lower_u_is_upper() {
        let a = diag_dominant_sparse(40, 5, GenSeed(42));
        let f = SparseLu::new().factor(&a).unwrap();
        for i in 0..40 {
            let (lcols, _) = f.l().row(i);
            assert!(lcols.iter().all(|&j| j < i), "row {i}");
            let (ucols, _) = f.u().row(i);
            assert!(ucols.iter().all(|&j| j >= i), "row {i}");
            assert!(ucols.contains(&i), "row {i} missing diagonal");
        }
    }

    #[test]
    fn solve_recovers_manufactured_solution() {
        let a = diag_dominant_sparse(100, 6, GenSeed(43));
        let (x_true, b) = manufactured_solution(&a, GenSeed(44));
        let x = SparseLu::new().solve(&a, &b).unwrap();
        assert!(diff_inf(&x, &x_true) < 1e-9);
    }

    #[test]
    fn poisson_system_solves() {
        let a = poisson_2d(12); // 144x144, weakly dominant
        let (x_true, b) = manufactured_solution(&a, GenSeed(45));
        let f = SparseLu::new().factor(&a).unwrap();
        let x = f.solve(&b).unwrap();
        assert!(diff_inf(&x, &x_true) < 1e-8);
        assert!(f.fill_in(&a) > 0, "Poisson factorization should fill in");
    }

    #[test]
    fn parallel_solve_matches_sequential() {
        let a = poisson_2d(10);
        let (_, b) = manufactured_solution(&a, GenSeed(46));
        let f = SparseLu::new().factor(&a).unwrap();
        let seq = f.solve(&b).unwrap();
        for lanes in [2usize, 4] {
            let par = f.solve_par(&b, lanes).unwrap();
            assert!(diff_inf(&seq, &par) < 1e-12, "lanes={lanes}");
        }
    }

    #[test]
    fn parallel_solve_is_bitwise_sequential() {
        // Both substitutions are level-scheduled now, and each row's op
        // sequence matches the sequential sweep exactly — the solve is
        // bit-identical, not merely close.
        let a = poisson_2d(11);
        let (_, b) = manufactured_solution(&a, GenSeed(49));
        let f = SparseLu::new().factor(&a).unwrap();
        let seq = f.solve(&b).unwrap();
        for lanes in [2usize, 3, 8] {
            assert_eq!(f.solve_par(&b, lanes).unwrap(), seq, "lanes={lanes}");
        }
    }

    #[test]
    fn dataflow_scheduled_solves_are_bitwise_barrier() {
        // The schedule knob swaps barriers for dependency counters; row
        // arithmetic is untouched, so the solves agree bit-for-bit.
        let a = poisson_2d(11);
        let (_, b) = manufactured_solution(&a, GenSeed(50));
        let f = SparseLu::new().factor(&a).unwrap();
        assert_eq!(f.schedule_choice(), Schedule::Barrier);
        let df = f.clone().with_schedule(Schedule::Dataflow);
        assert_eq!(df.schedule_choice(), Schedule::Dataflow);
        let seq = f.solve(&b).unwrap();
        for lanes in [2usize, 3, 8] {
            assert_eq!(f.solve_par(&b, lanes).unwrap(), seq, "barrier lanes={lanes}");
            assert_eq!(df.solve_par(&b, lanes).unwrap(), seq, "dataflow lanes={lanes}");
        }
    }

    #[test]
    fn level_count_is_sane() {
        let a = diag_dominant_sparse(60, 3, GenSeed(47));
        let f = SparseLu::new().factor(&a).unwrap();
        assert!(f.level_count() >= 1);
        assert!(f.level_count() <= 60);
        assert!(f.backward_level_count() >= 1);
        assert!(f.backward_level_count() <= 60);
    }

    #[test]
    fn detects_singular_pivot() {
        // Diagonal-free row -> zero pivot (no pivoting path).
        let a = CsrMatrix::from_raw(
            2,
            2,
            vec![0, 1, 2],
            vec![1, 0],
            vec![1.0, 1.0],
        )
        .unwrap();
        assert!(matches!(
            SparseLu::new().factor(&a),
            Err(EbvError::SingularPivot { .. })
        ));
    }

    #[test]
    fn drop_tolerance_reduces_fill() {
        let a = poisson_2d(14);
        let exact = SparseLu::new().factor(&a).unwrap();
        let ilu = SparseLu::new().with_drop_tol(1e-2).factor(&a).unwrap();
        assert!(
            ilu.l().nnz() + ilu.u().nnz() < exact.l().nnz() + exact.u().nnz(),
            "dropping should reduce factor nnz"
        );
        // Still a useful preconditioner-quality solve.
        let (_, b) = manufactured_solution(&a, GenSeed(48));
        let x = ilu.solve(&b).unwrap();
        assert!(rel_residual_csr(&a, &x, &b) < 0.5);
    }

    #[test]
    fn rejects_rectangular() {
        let a = CsrMatrix::zeros(2, 3);
        assert!(SparseLu::new().factor(&a).is_err());
    }

    #[test]
    fn dense_identity_round_trip() {
        let a = CsrMatrix::from_dense(&DenseMatrix::identity(5), 0.0);
        let f = SparseLu::new().factor(&a).unwrap();
        assert_eq!(f.l().nnz(), 0);
        assert_eq!(f.u().nnz(), 5);
        let x = f.solve(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }
}
