//! Row permutations and pivot selection.

use crate::matrix::DenseMatrix;
use crate::util::error::{EbvError, Result};

/// A row permutation `P`: `(P A)[i] = A[map[i]]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Permutation {
    map: Vec<usize>,
}

impl Permutation {
    /// Identity permutation of size `n`.
    pub fn identity(n: usize) -> Self {
        Permutation { map: (0..n).collect() }
    }

    /// Build from an explicit map, validating it is a permutation.
    pub fn from_map(map: Vec<usize>) -> Result<Self> {
        let n = map.len();
        let mut seen = vec![false; n];
        for &i in &map {
            if i >= n || seen[i] {
                return Err(EbvError::Shape(format!("invalid permutation map: {map:?}")));
            }
            seen[i] = true;
        }
        Ok(Permutation { map })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.map.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    #[inline]
    pub fn map(&self) -> &[usize] {
        &self.map
    }

    /// Is this the identity?
    pub fn is_identity(&self) -> bool {
        self.map.iter().enumerate().all(|(i, &p)| i == p)
    }

    /// Swap two targets (records a pivot exchange).
    pub fn swap(&mut self, i: usize, j: usize) {
        self.map.swap(i, j);
    }

    /// Apply to a vector: `out[i] = v[map[i]]`.
    pub fn apply_vec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.map.len() {
            return Err(EbvError::Shape(format!(
                "permutation of size {} applied to vector of size {}",
                self.map.len(),
                v.len()
            )));
        }
        Ok(self.map.iter().map(|&p| v[p]).collect())
    }

    /// Inverse-apply to a vector: `out[map[i]] = v[i]`.
    pub fn unapply_vec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.map.len() {
            return Err(EbvError::Shape("permutation size mismatch".into()));
        }
        let mut out = vec![0.0; v.len()];
        for (i, &p) in self.map.iter().enumerate() {
            out[p] = v[i];
        }
        Ok(out)
    }

    /// Apply to matrix rows: `out[i] = m[map[i]]`.
    pub fn apply_rows(&self, m: &DenseMatrix) -> DenseMatrix {
        m.permute_rows(&self.map).expect("size checked by construction")
    }

    /// Inverse-apply to matrix rows.
    pub fn unapply_rows(&self, m: &DenseMatrix) -> DenseMatrix {
        let mut inv = vec![0usize; self.map.len()];
        for (i, &p) in self.map.iter().enumerate() {
            inv[p] = i;
        }
        m.permute_rows(&inv).expect("size checked by construction")
    }
}

/// Find the partial-pivot row for column `col` at step `step`:
/// the row in `step..n` with the largest `|A[i][col]|`.
pub fn argmax_pivot(a: &DenseMatrix, step: usize, col: usize) -> usize {
    let mut best = step;
    let mut best_val = a.get(step, col).abs();
    for i in (step + 1)..a.rows() {
        let v = a.get(i, col).abs();
        if v > best_val {
            best = i;
            best_val = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_identity() {
        let p = Permutation::identity(4);
        assert!(p.is_identity());
        let v = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(p.apply_vec(&v).unwrap(), v);
    }

    #[test]
    fn from_map_validates() {
        assert!(Permutation::from_map(vec![1, 0, 2]).is_ok());
        assert!(Permutation::from_map(vec![0, 0, 1]).is_err());
        assert!(Permutation::from_map(vec![0, 3]).is_err());
    }

    #[test]
    fn apply_then_unapply_is_identity() {
        let p = Permutation::from_map(vec![2, 0, 3, 1]).unwrap();
        let v = vec![10.0, 20.0, 30.0, 40.0];
        let w = p.apply_vec(&v).unwrap();
        assert_eq!(w, vec![30.0, 10.0, 40.0, 20.0]);
        assert_eq!(p.unapply_vec(&w).unwrap(), v);
    }

    #[test]
    fn matrix_row_permutation_round_trip() {
        let m = DenseMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]).unwrap();
        let p = Permutation::from_map(vec![1, 0]).unwrap();
        let pm = p.apply_rows(&m);
        assert_eq!(pm.get(0, 1), 2.0);
        assert_eq!(p.unapply_rows(&pm), m);
    }

    #[test]
    fn swaps_accumulate() {
        let mut p = Permutation::identity(3);
        p.swap(0, 2);
        p.swap(1, 2);
        // map = [2, 0, 1]
        assert_eq!(p.map(), &[2, 0, 1]);
        assert!(!p.is_identity());
    }

    #[test]
    fn argmax_finds_largest_below() {
        let a = DenseMatrix::from_rows(&[
            &[1.0, 9.0],
            &[-5.0, 1.0],
        ])
        .unwrap();
        assert_eq!(argmax_pivot(&a, 0, 0), 1);
        assert_eq!(argmax_pivot(&a, 1, 1), 1);
    }
}
