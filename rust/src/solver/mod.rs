//! LU factorization solvers: sequential baseline, the paper's parallel
//! EBV method, a blocked library-style comparator, sparse LU, triangular
//! solves, pivoting and iterative refinement.
//!
//! All dense factorizations produce [`DenseLuFactors`] (packed in-place
//! LU, Doolittle convention: unit lower triangle below the diagonal, U on
//! and above it), so every algorithm is cross-checked against every other
//! in the tests.

pub mod cholesky;
pub mod gauss_jordan;
pub mod kernel;
pub mod lu_blocked;
pub mod lu_ebv;
pub mod lu_seq;
pub mod pivot;
pub mod refine;
pub mod sparse_lu;
pub mod sparse_symbolic;
pub mod thomas;
pub mod trisolve;

use crate::matrix::DenseMatrix;
use crate::util::error::Result;

pub use cholesky::{cholesky_factor, cholesky_solve, CholeskyFactors};
pub use gauss_jordan::GaussJordan;
pub use kernel::Kernel;
pub use lu_blocked::BlockedLu;
pub use lu_ebv::{EbvLu, DEFAULT_PANEL_WIDTH};
pub use lu_seq::SeqLu;
pub use pivot::Permutation;
pub use refine::Refined;
pub use sparse_lu::{SparseLu, SparseLuFactors};
pub use sparse_symbolic::SparseSymbolic;
pub use thomas::{thomas_factor, thomas_solve, ThomasFactors};

/// Packed dense LU factors (Doolittle): `L` is unit-lower (multipliers
/// stored below the diagonal), `U` is upper including the diagonal, both
/// packed into one matrix. `perm` is the row permutation applied to `A`
/// (i.e. `P A = L U` with `P` selecting row `perm[i]`), identity if the
/// factorization did not pivot.
#[derive(Debug, Clone)]
pub struct DenseLuFactors {
    lu: DenseMatrix,
    perm: Permutation,
}

impl DenseLuFactors {
    pub fn new(lu: DenseMatrix, perm: Permutation) -> Self {
        assert!(lu.is_square(), "LU factors must be square");
        assert_eq!(perm.len(), lu.rows(), "permutation size mismatch");
        DenseLuFactors { lu, perm }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.lu.rows()
    }

    /// The packed LU matrix.
    #[inline]
    pub fn packed(&self) -> &DenseMatrix {
        &self.lu
    }

    #[inline]
    pub fn perm(&self) -> &Permutation {
        &self.perm
    }

    /// Extract the unit-lower factor `L` (tests/oracles).
    pub fn l(&self) -> DenseMatrix {
        let n = self.n();
        let mut l = DenseMatrix::identity(n);
        for i in 0..n {
            for j in 0..i {
                l.set(i, j, self.lu.get(i, j));
            }
        }
        l
    }

    /// Extract the upper factor `U` (tests/oracles).
    pub fn u(&self) -> DenseMatrix {
        let n = self.n();
        let mut u = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                u.set(i, j, self.lu.get(i, j));
            }
        }
        u
    }

    /// Reconstruct `P A = L U` (test helper): returns `Pᵀ (L U)`,
    /// which must equal the original `A`.
    pub fn reconstruct(&self) -> DenseMatrix {
        let prod = self.l().matmul(&self.u()).expect("square");
        self.perm.unapply_rows(&prod)
    }

    /// Solve `A x = b` using the stored factors:
    /// forward substitution on `P b`, then backward substitution.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let pb = self.perm.apply_vec(b)?;
        let y = trisolve::forward_unit_dense(&self.lu, &pb)?;
        trisolve::backward_dense(&self.lu, &y)
    }

    /// Solve for multiple right-hand sides (columns of `B`) as a
    /// lane-distributed panel on the process-global engine.
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        self.solve_many_on(bs, crate::exec::global())
    }

    /// Panel solve on a specific engine; first failure (lowest index,
    /// matching what a sequential map would have returned) aborts.
    pub fn solve_many_on(
        &self,
        bs: &[Vec<f64>],
        engine: &crate::exec::LaneEngine,
    ) -> Result<Vec<Vec<f64>>> {
        let views: Vec<&[f64]> = bs.iter().map(Vec::as_slice).collect();
        self.solve_panel(&views, engine).into_iter().collect()
    }

    /// The panel core: one single-step engine job whose virtual lanes
    /// each run the ordinary sequential substitution on one right-hand
    /// side, so every column of the answer is bitwise identical to
    /// [`DenseLuFactors::solve`] on that column. Returns one result per
    /// panel — the coordinator's batch path needs per-request outcomes
    /// (a malformed RHS must fail alone, not drag the batch down).
    pub fn solve_panel(
        &self,
        bs: &[&[f64]],
        engine: &crate::exec::LaneEngine,
    ) -> Vec<Result<Vec<f64>>> {
        // Below ~128 unknowns a substitution is sub-microsecond and the
        // engine hand-off costs more than it parallelizes (the same
        // crossover EbvLu's seq_threshold encodes) — solve inline.
        if bs.len() < 2 || engine.lanes() == 1 || self.n() < 128 {
            return bs.iter().map(|b| self.solve(b)).collect();
        }
        let mut panels: Vec<Option<Result<Vec<f64>>>> = (0..bs.len()).map(|_| None).collect();
        let slots = crate::exec::LaneSlots::new(&mut panels);
        engine.run_steps(bs.len(), 1, |vlane, _step| {
            // SAFETY: vlane writes only its own panel slot.
            unsafe { *slots.slot(vlane) = Some(self.solve(bs[vlane])) };
            crate::exec::StepCtl::Continue
        });
        panels.into_iter().map(|slot| slot.expect("engine ran every panel")).collect()
    }
}

/// Common interface over the dense LU algorithms, so benches, the
/// coordinator and the examples can swap solvers by name.
pub trait LuSolver: Send + Sync {
    /// Short identifier used in configs and bench output.
    fn name(&self) -> &'static str;

    /// Factor `A` into packed LU.
    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors>;

    /// Factor and solve in one call.
    fn solve(&self, a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
        self.factor(a)?.solve(b)
    }
}

/// Look a solver up by its config name. `panel` is the blocked-panel
/// width the EBV solver runs with, `kernel` the trailing-update
/// microkernel both blocked solvers dispatch to, `schedule` the lane
/// scheduling discipline the EBV solver runs under (other solvers
/// ignore all three).
pub fn solver_by_name(
    name: &str,
    lanes: usize,
    panel: usize,
    kernel: Kernel,
    schedule: crate::exec::Schedule,
) -> Option<Box<dyn LuSolver>> {
    let ebv = || EbvLu::with_lanes(lanes).panel(panel).kernel(kernel).schedule(schedule);
    match name {
        "seq" => Some(Box::new(SeqLu::new())),
        "seq-pivot" => Some(Box::new(SeqLu::with_pivoting())),
        "ebv" => Some(Box::new(ebv())),
        "blocked" => Some(Box::new(BlockedLu::new().with_kernel(kernel))),
        "gauss-jordan" => Some(Box::new(GaussJordan::new())),
        "refined" => Some(Box::new(Refined::new(ebv()))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, GenSeed};

    #[test]
    fn factors_expose_l_and_u_shapes() {
        let a = diag_dominant_dense(8, GenSeed(1));
        let f = SeqLu::new().factor(&a).unwrap();
        let l = f.l();
        let u = f.u();
        for i in 0..8 {
            assert_eq!(l.get(i, i), 1.0);
            for j in (i + 1)..8 {
                assert_eq!(l.get(i, j), 0.0);
                assert_eq!(u.get(j, i), 0.0);
            }
        }
    }

    #[test]
    fn reconstruct_recovers_a() {
        let a = diag_dominant_dense(16, GenSeed(2));
        let f = SeqLu::new().factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_many_matches_individual_solves() {
        let a = diag_dominant_dense(12, GenSeed(3));
        let f = SeqLu::new().factor(&a).unwrap();
        let b1 = vec![1.0; 12];
        let b2: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let many = f.solve_many(&[b1.clone(), b2.clone()]).unwrap();
        assert_eq!(many[0], f.solve(&b1).unwrap());
        assert_eq!(many[1], f.solve(&b2).unwrap());
    }

    #[test]
    fn panel_solve_is_bitwise_for_any_engine_size() {
        // More panels than lanes: vlanes virtualize, bits don't move.
        // n >= 128 keeps the multi-lane engines on the pooled path.
        let n = 144;
        let a = diag_dominant_dense(n, GenSeed(4));
        let f = SeqLu::new().factor(&a).unwrap();
        let bs: Vec<Vec<f64>> =
            (0..7).map(|k| (0..n).map(|i| (i + k) as f64 * 0.25 - 1.0).collect()).collect();
        let individually: Vec<Vec<f64>> =
            bs.iter().map(|b| f.solve(b).unwrap()).collect();
        for engine_lanes in [1usize, 2, 3] {
            let engine = crate::exec::LaneEngine::new(engine_lanes);
            let many = f.solve_many_on(&bs, &engine).unwrap();
            assert_eq!(many, individually, "engine_lanes={engine_lanes}");
        }
    }

    #[test]
    fn panel_solve_reports_lowest_failing_index() {
        // A zero diagonal makes every panel fail; the reported error
        // must be the one a sequential map would have hit first.
        let mut lu = diag_dominant_dense(8, GenSeed(5));
        lu.set(3, 3, 0.0);
        let f = DenseLuFactors::new(lu, Permutation::identity(8));
        let bs = vec![vec![1.0; 8], vec![2.0; 8], vec![3.0; 8]];
        let engine = crate::exec::LaneEngine::new(2);
        let err = f.solve_many_on(&bs, &engine);
        assert!(err.is_err(), "{err:?}");
    }

    #[test]
    fn solver_registry_resolves_names() {
        use crate::exec::Schedule;
        for name in ["seq", "seq-pivot", "ebv", "blocked", "gauss-jordan", "refined"] {
            for schedule in Schedule::ALL {
                let s = solver_by_name(name, 2, DEFAULT_PANEL_WIDTH, Kernel::Auto, schedule);
                assert_eq!(s.expect(name).name(), name, "{name} {schedule:?}");
            }
        }
        assert!(
            solver_by_name("nope", 2, DEFAULT_PANEL_WIDTH, Kernel::Auto, Schedule::Barrier)
                .is_none()
        );
    }

    #[test]
    fn registry_refined_solves_to_tight_residual() {
        // The registered wrapper must actually refine: a refined EBV
        // solve of a well-conditioned system lands at ~machine-level
        // relative residual regardless of schedule.
        use crate::exec::Schedule;
        let n = 96;
        let a = diag_dominant_dense(n, GenSeed(6));
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).cos()).collect();
        for schedule in Schedule::ALL {
            let s = solver_by_name("refined", 3, 8, Kernel::Auto, schedule).unwrap();
            let x = s.solve(&a, &b).unwrap();
            assert!(a.residual(&x, &b) < 1e-10, "{schedule:?}");
        }
    }
}
