//! Sequential LU factorization — the paper's "CPU" baseline.
//!
//! Right-looking (Doolittle) elimination, in place, optionally with
//! partial pivoting. The non-pivoting path matches the paper's setting
//! (diagonally dominant systems, Eq. 2) and is the reference every other
//! solver is validated against.

use crate::matrix::DenseMatrix;
use crate::solver::pivot::{argmax_pivot, Permutation};
use crate::solver::{DenseLuFactors, LuSolver};
use crate::util::error::{EbvError, Result};

/// Sequential Doolittle LU.
#[derive(Debug, Clone)]
pub struct SeqLu {
    pivoting: bool,
    /// Pivot magnitude below which the matrix is declared singular.
    pivot_tol: f64,
}

impl SeqLu {
    /// Non-pivoting variant (requires a well-conditioned, e.g.
    /// diagonally dominant, matrix — the paper's assumption).
    pub fn new() -> Self {
        SeqLu { pivoting: false, pivot_tol: 1e-12 }
    }

    /// Partial-pivoting variant for general matrices.
    pub fn with_pivoting() -> Self {
        SeqLu { pivoting: true, pivot_tol: 1e-12 }
    }

    pub fn pivot_tol(mut self, tol: f64) -> Self {
        self.pivot_tol = tol;
        self
    }
}

impl Default for SeqLu {
    fn default() -> Self {
        SeqLu::new()
    }
}

impl LuSolver for SeqLu {
    fn name(&self) -> &'static str {
        if self.pivoting {
            "seq-pivot"
        } else {
            "seq"
        }
    }

    fn factor(&self, a: &DenseMatrix) -> Result<DenseLuFactors> {
        if !a.is_square() {
            return Err(EbvError::Shape("LU needs a square matrix".into()));
        }
        let n = a.rows();
        let mut lu = a.clone();
        let mut perm = Permutation::identity(n);

        for r in 0..n {
            if self.pivoting {
                let p = argmax_pivot(&lu, r, r);
                if p != r {
                    // Swap whole rows (including already-computed L part —
                    // standard LAPACK-style row interchange).
                    let (lo, hi) = (r.min(p), r.max(p));
                    let data = lu.data_mut();
                    let cols = n;
                    let (a_half, b_half) = data.split_at_mut(hi * cols);
                    a_half[lo * cols..(lo + 1) * cols]
                        .swap_with_slice(&mut b_half[..cols]);
                    perm.swap(r, p);
                }
            }
            let piv = lu.get(r, r);
            if piv.abs() < self.pivot_tol {
                return Err(EbvError::SingularPivot { step: r, value: piv, tol: self.pivot_tol });
            }
            if r + 1 == n {
                break;
            }
            // Scale the L column (the paper's Eq. 6-a) and apply the
            // rank-1 trailing update (Eq. 6-c).
            let inv = 1.0 / piv;
            for i in (r + 1)..n {
                let f = lu.get(i, r) * inv;
                lu.set(i, r, f);
                if f == 0.0 {
                    continue;
                }
                // row_i[r+1..] -= f * row_r[r+1..], via split_at_mut to
                // borrow the pivot row and target row simultaneously.
                let cols = n;
                let data = lu.data_mut();
                let (top, bottom) = data.split_at_mut(i * cols);
                let pivot_row = &top[r * cols + r + 1..r * cols + cols];
                let target = &mut bottom[r + 1..cols];
                for (t, &p) in target.iter_mut().zip(pivot_row.iter()) {
                    *t -= f * p;
                }
            }
        }
        Ok(DenseLuFactors::new(lu, perm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, rhs, GenSeed};
    use crate::matrix::norms::rel_residual_dense;

    #[test]
    fn hand_case_2x2() {
        // A = [[4, 3], [6, 3]] => L21 = 1.5, U = [[4, 3], [0, -1.5]]
        let a = DenseMatrix::from_rows(&[&[4.0, 3.0], &[6.0, 3.0]]).unwrap();
        let f = SeqLu::new().factor(&a).unwrap();
        assert!((f.packed().get(1, 0) - 1.5).abs() < 1e-15);
        assert!((f.packed().get(1, 1) + 1.5).abs() < 1e-15);
        let x = f.solve(&[7.0, 9.0]).unwrap();
        assert!(a.residual(&x, &[7.0, 9.0]) < 1e-12);
    }

    #[test]
    fn factor_reconstructs_for_random_dominant_systems() {
        for n in [1usize, 2, 3, 10, 33, 64] {
            let a = diag_dominant_dense(n, GenSeed(n as u64));
            let f = SeqLu::new().factor(&a).unwrap();
            assert!(f.reconstruct().max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn solve_has_small_residual() {
        let n = 100;
        let a = diag_dominant_dense(n, GenSeed(42));
        let b = rhs(n, GenSeed(43));
        let x = SeqLu::new().solve(&a, &b).unwrap();
        assert!(rel_residual_dense(&a, &x, &b) < 1e-12);
    }

    #[test]
    fn rejects_non_square() {
        let a = DenseMatrix::zeros(2, 3);
        assert!(SeqLu::new().factor(&a).is_err());
    }

    #[test]
    fn detects_singularity_without_pivoting() {
        let a = DenseMatrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]).unwrap();
        assert!(matches!(
            SeqLu::new().factor(&a),
            Err(EbvError::SingularPivot { step: 0, .. })
        ));
    }

    #[test]
    fn pivoting_handles_zero_leading_diagonal() {
        let a = DenseMatrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]).unwrap();
        let f = SeqLu::with_pivoting().factor(&a).unwrap();
        let x = f.solve(&[2.0, 3.0]).unwrap();
        assert!(a.residual(&x, &[2.0, 3.0]) < 1e-12);
        assert!(!f.perm().is_identity());
    }

    #[test]
    fn pivoting_reconstructs_pa_equals_lu() {
        // A general (non-dominant) matrix needing interchanges.
        let a = DenseMatrix::from_rows(&[
            &[1e-10, 1.0, 2.0],
            &[3.0, 1.0, -1.0],
            &[2.0, -2.0, 0.5],
        ])
        .unwrap();
        let f = SeqLu::with_pivoting().factor(&a).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn truly_singular_matrix_fails_even_with_pivoting() {
        let a = DenseMatrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]).unwrap();
        assert!(SeqLu::with_pivoting().factor(&a).is_err());
    }
}
