//! Sparse LU symbolic/numeric split: one-time pattern analysis, cheap
//! level-parallel refactorization.
//!
//! GLU3.0 (Peng & Tan, arXiv:1908.00204) observes that for the serving
//! pattern this repo targets — matrices whose *sparsity pattern* is
//! fixed while the *values* change between solves — almost all of the
//! Gilbert–Peierls factorization cost is re-derivable structure: the
//! fill pattern of `L`/`U`, the row-dependency DAG, and the level
//! schedule. [`SparseSymbolic`] computes that structure once:
//!
//! * the **fill pattern** of both factors (pattern-only elimination —
//!   the same worklist walk as [`SparseLu::factor`] with the values
//!   stripped out);
//! * the **row dependency DAG** levels: row `i` depends on row `j` iff
//!   `j` appears in `L`'s row-`i` pattern, so rows of equal level have
//!   no mutual dependencies and refactor in parallel;
//! * per-row **numeric cost estimates** that feed the equalized lane
//!   assignment (`ebv::equalize::equalize_weights` — the EBV balance
//!   criterion applied to level row work).
//!
//! The **numeric phase** ([`SparseSymbolic::factor_par_on`]) then
//! refactors values level-by-level as one barrier-stepped job on the
//! persistent [`LaneEngine`]: one step per DAG level, rows of a level
//! split across virtual lanes with equalized chunks, each lane
//! scattering into its own dense accumulator. Per-row arithmetic is the
//! *identical op sequence* the sequential factorizer performs (the
//! symbolic pattern is walked in the same ascending order the dynamic
//! worklist would pop, and entries the dynamic pattern never stored are
//! skipped by the same zero guards), so the produced factors are
//! **bitwise identical** to [`SparseLu::factor`] for every lane count
//! and engine size — see `rust/DESIGN.md` §Sparse symbolic/numeric
//! split and the bit-identity ledger.
//!
//! The coordinator shares one `Arc<SparseSymbolic>` per *pattern
//! fingerprint* through its `FactorCache`, so a wire request whose
//! structure matches a cached pattern skips symbolic analysis entirely
//! and pays only the parallel numeric sweep.
//!
//! Scope: the split targets the exact (`drop_tol = 0`) factorization.
//! The ILU-style [`SparseLu::with_drop_tol`] path prunes its pattern
//! *by value* and therefore cannot reuse a static symbolic analysis.
//!
//! [`SparseLu::factor`]: crate::solver::SparseLu::factor
//! [`SparseLu::with_drop_tol`]: crate::solver::SparseLu::with_drop_tol

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Mutex;

use crate::ebv::equalize::{equalize_hierarchical, equalize_weights};
use crate::exec::{run_dataflow, DepGraph, DeviceSet, LaneEngine, LaneSlots, Schedule, StepCtl};
use crate::matrix::CsrMatrix;
use crate::solver::kernel::{scatter_axpy, Kernel};
use crate::solver::sparse_lu::SparseLuFactors;
use crate::util::error::{EbvError, Result};

/// One-time symbolic analysis of a sparse matrix pattern: fill
/// structure of `L`/`U`, the factorization dependency DAG grouped into
/// levels, and per-row numeric cost estimates. Shared (via `Arc`)
/// across every same-pattern refactorization.
#[derive(Debug)]
pub struct SparseSymbolic {
    n: usize,
    pivot_tol: f64,
    /// The analyzed matrix pattern, kept verbatim so a refactorization
    /// against a structurally different matrix is rejected instead of
    /// silently corrupting the accumulator walk.
    a_row_ptr: Vec<usize>,
    a_col_idx: Vec<usize>,
    /// `L` fill pattern (strictly lower, rows ascending-sorted).
    l_ptr: Vec<usize>,
    l_idx: Vec<usize>,
    /// `U` fill pattern (upper including the diagonal, ascending).
    u_ptr: Vec<usize>,
    u_idx: Vec<usize>,
    /// Position of row `i`'s diagonal entry inside `u_idx`.
    u_diag_pos: Vec<usize>,
    /// Factorization-DAG level of each row.
    level: Vec<usize>,
    /// Rows grouped by level (ascending row order within a level).
    by_level: Vec<Vec<usize>>,
    /// Per-row numeric flop estimate — the equalization weight.
    row_cost: Vec<usize>,
    /// Microkernel selection, accepted for config symmetry with the
    /// dense solvers. The sparse accumulator always runs the
    /// scalar-guarded [`scatter_axpy`] — the emission rule pins the
    /// exact guard order, so every kernel choice is bitwise identical
    /// here (proven by `rust/tests/prop_sparse.rs`).
    kernel: Kernel,
    /// Execution schedule of the parallel numeric phase (and, carried
    /// into the assembled factors, of the parallel trisolves):
    /// [`Schedule::Barrier`] steps lanes through the DAG levels;
    /// [`Schedule::Dataflow`] gives every row a remaining-dependency
    /// counter over the symbolic `L` pattern and lets lanes
    /// self-schedule ready rows — one barrier entry per
    /// refactorization instead of one per level. Bitwise identical
    /// either way (each row's arithmetic depends only on the pattern
    /// and its finalized dependencies). The device-sharded path keeps
    /// the level schedule regardless (the staged exchange is
    /// level-structured).
    schedule: Schedule,
}

impl SparseSymbolic {
    /// Analyze the fill pattern of `a` (pattern-only Gilbert–Peierls,
    /// no pivoting — the paper's diagonally dominant setting). Errors
    /// on non-square input and on rows whose `U` pattern has no
    /// diagonal (structurally singular: every numeric factorization of
    /// this pattern would hit a zero pivot).
    pub fn analyze(a: &CsrMatrix) -> Result<SparseSymbolic> {
        if a.rows() != a.cols() {
            return Err(EbvError::Shape("sparse LU needs a square matrix".into()));
        }
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::Symbolic);
        let n = a.rows();

        let mut l_ptr = vec![0usize];
        let mut l_idx: Vec<usize> = Vec::new();
        let mut u_ptr = vec![0usize];
        let mut u_idx: Vec<usize> = Vec::new();
        let mut u_diag_pos = Vec::with_capacity(n);

        // Same worklist structure as the numeric factorizer: membership
        // bitmap, ascending min-heap for the sub-diagonal pattern,
        // sorted-once list for the super-diagonal pattern.
        let mut in_pattern = vec![false; n];
        let mut lower: BinaryHeap<Reverse<usize>> = BinaryHeap::new();
        let mut upper: Vec<usize> = Vec::new();
        // Off-diagonal U row patterns built so far (merge source).
        let mut u_rows: Vec<Vec<usize>> = Vec::with_capacity(n);

        let mut level = vec![0usize; n];
        let mut max_level = 0usize;
        let mut row_cost = vec![0usize; n];

        for i in 0..n {
            let (cols, _) = a.row(i);
            for &j in cols {
                if in_pattern[j] {
                    continue;
                }
                in_pattern[j] = true;
                if j < i {
                    lower.push(Reverse(j));
                } else {
                    upper.push(j);
                }
            }

            // Pattern elimination: every sub-diagonal index becomes an
            // `L` entry and merges its `U` row's off-diagonal pattern
            // (fill); popped ascending, fill below `i` re-enters the
            // heap ahead of its own processing because merged indices
            // are strictly greater than the row they came from.
            let mut lv = 0usize;
            let mut cost = 1usize;
            while let Some(Reverse(j)) = lower.pop() {
                in_pattern[j] = false;
                l_idx.push(j);
                lv = lv.max(level[j] + 1);
                cost += 1 + 2 * u_rows[j].len();
                for &c in &u_rows[j] {
                    if !in_pattern[c] {
                        in_pattern[c] = true;
                        if c < i {
                            lower.push(Reverse(c));
                        } else {
                            upper.push(c);
                        }
                    }
                }
            }
            l_ptr.push(l_idx.len());
            level[i] = lv;
            max_level = max_level.max(lv);
            row_cost[i] = cost;

            upper.sort_unstable();
            let row_start = u_idx.len();
            let mut diag_pos = None;
            for &j in &upper {
                debug_assert!(j >= i);
                if j == i {
                    diag_pos = Some(u_idx.len());
                }
                u_idx.push(j);
                in_pattern[j] = false;
            }
            upper.clear();
            let Some(dp) = diag_pos else {
                // No structural diagonal: the numeric phase would divide
                // by an exact zero at this row no matter the values.
                return Err(EbvError::SingularPivot { step: i, value: 0.0, tol: 0.0 });
            };
            u_diag_pos.push(dp);
            u_ptr.push(u_idx.len());
            u_rows.push(u_idx[row_start..].iter().copied().filter(|&c| c != i).collect());
        }

        let mut by_level = vec![Vec::new(); max_level + 1];
        for (i, &lv) in level.iter().enumerate() {
            by_level[lv].push(i);
        }

        Ok(SparseSymbolic {
            n,
            pivot_tol: 1e-12,
            a_row_ptr: a.row_ptr().to_vec(),
            a_col_idx: a.col_idx().to_vec(),
            l_ptr,
            l_idx,
            u_ptr,
            u_idx,
            u_diag_pos,
            level,
            by_level,
            row_cost,
            kernel: Kernel::Auto,
            schedule: Schedule::Barrier,
        })
    }

    /// Select the microkernel (default [`Kernel::Auto`]). Inert by
    /// construction — see the `kernel` field — but plumbed so the
    /// coordinator can thread one `service.kernel` choice through
    /// every solver uniformly and the property tests can prove the
    /// invariance.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Configured microkernel choice (possibly [`Kernel::Auto`]).
    pub fn kernel_choice(&self) -> Kernel {
        self.kernel
    }

    /// Select the execution schedule of the parallel numeric phase
    /// (default [`Schedule::Barrier`]); carried into the assembled
    /// factors so their parallel trisolves follow the same choice. See
    /// the field docs for the fallback matrix.
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    /// Configured execution schedule.
    pub fn schedule_choice(&self) -> Schedule {
        self.schedule
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Symbolic `L` pattern size (strictly lower entries).
    pub fn l_nnz(&self) -> usize {
        self.l_idx.len()
    }

    /// Symbolic `U` pattern size (including diagonals).
    pub fn u_nnz(&self) -> usize {
        self.u_idx.len()
    }

    /// Number of factorization-DAG levels: the barrier count of the
    /// level-parallel numeric phase.
    pub fn level_count(&self) -> usize {
        self.by_level.len()
    }

    /// Factorization-DAG level of each row.
    pub fn levels(&self) -> &[usize] {
        &self.level
    }

    /// Rows grouped by DAG level.
    pub fn rows_by_level(&self) -> &[Vec<usize>] {
        &self.by_level
    }

    /// Predicted fill-in: symbolic factor nnz minus the matrix nnz.
    pub fn fill_in(&self, a: &CsrMatrix) -> isize {
        (self.l_nnz() + self.u_nnz()) as isize - a.nnz() as isize
    }

    /// Whether `a` has exactly the analyzed pattern (shape, row
    /// pointers and column indices — values free).
    pub fn matches_pattern(&self, a: &CsrMatrix) -> bool {
        a.rows() == self.n
            && a.cols() == self.n
            && a.row_ptr() == self.a_row_ptr.as_slice()
            && a.col_idx() == self.a_col_idx.as_slice()
    }

    fn check(&self, a: &CsrMatrix) -> Result<()> {
        if self.matches_pattern(a) {
            Ok(())
        } else {
            Err(EbvError::Shape(
                "matrix pattern does not match the symbolic analysis \
                 (refactorization requires the analyzed sparsity structure)"
                    .into(),
            ))
        }
    }

    /// Numeric sweep for one row over the symbolic pattern: the exact
    /// per-row op sequence of `SparseLu::factor` (ascending dependency
    /// walk, same zero guards), reading/writing factor values through
    /// shared workspaces. Returns the row's `(step, value)` on a pivot
    /// below `pivot_tol`.
    ///
    /// # Safety
    /// Caller must guarantee (a) exclusive write access to row `i`'s
    /// `l_val`/`u_val` ranges, (b) that every dependency row's `u_val`
    /// entries are finalized and published (earlier DAG level + step
    /// barrier, or sequential order), and (c) `acc` is all-zero on
    /// entry (this function restores that invariant before returning).
    unsafe fn numeric_row(
        &self,
        i: usize,
        a: &CsrMatrix,
        acc: &mut [f64],
        l_val: *mut f64,
        u_val: *mut f64,
    ) -> std::result::Result<(), (usize, f64)> {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals.iter()) {
            acc[j] = v;
        }
        for pos in self.l_ptr[i]..self.l_ptr[i + 1] {
            let j = self.l_idx[pos];
            let f = acc[j] / *u_val.add(self.u_diag_pos[j]);
            acc[j] = 0.0;
            *l_val.add(pos) = f;
            // The sequential factorizer applies the update only for
            // multipliers it keeps (`f != 0` and `|f| > drop_tol = 0`);
            // symbolic-pattern entries the dynamic pattern never stored
            // carry an exact zero here and are skipped identically.
            let f_kept = f != 0.0 && f.abs() > 0.0;
            if !f_kept {
                continue;
            }
            // Dependency row j's U entries are finalized (earlier DAG
            // level or sequential order), so a shared slice view is
            // sound. The scatter-AXPY skips the diagonal (handled via
            // u_diag_pos) and exact-zero entries — ones the dynamic
            // pattern dropped at emission, which the sequential sweep
            // never touched.
            let (q0, q1) = (self.u_ptr[j], self.u_ptr[j + 1]);
            let u_vals = std::slice::from_raw_parts(u_val.add(q0) as *const f64, q1 - q0);
            scatter_axpy(f, &self.u_idx[q0..q1], u_vals, j, acc);
        }
        let mut diag = 0.0;
        for q in self.u_ptr[i]..self.u_ptr[i + 1] {
            let c = self.u_idx[q];
            let v = acc[c];
            *u_val.add(q) = v;
            acc[c] = 0.0;
            if c == i {
                diag = v;
            }
        }
        if diag.abs() < self.pivot_tol {
            return Err((i, diag));
        }
        Ok(())
    }

    /// Compact the value workspaces into final CSR factors, applying
    /// the sequential factorizer's emission rule (entries that computed
    /// to exact zero are dropped), so the assembled factors are
    /// structurally *and* numerically identical to `SparseLu::factor`.
    fn assemble(&self, l_val: &[f64], u_val: &[f64]) -> Result<SparseLuFactors> {
        let n = self.n;
        let mut lp = Vec::with_capacity(n + 1);
        lp.push(0usize);
        let mut li = Vec::with_capacity(l_val.len());
        let mut lv = Vec::with_capacity(l_val.len());
        let mut up = Vec::with_capacity(n + 1);
        up.push(0usize);
        let mut ui = Vec::with_capacity(u_val.len());
        let mut uv = Vec::with_capacity(u_val.len());
        for i in 0..n {
            for pos in self.l_ptr[i]..self.l_ptr[i + 1] {
                let f = l_val[pos];
                if f != 0.0 && f.abs() > 0.0 {
                    li.push(self.l_idx[pos]);
                    lv.push(f);
                }
            }
            lp.push(li.len());
            for q in self.u_ptr[i]..self.u_ptr[i + 1] {
                let c = self.u_idx[q];
                let v = u_val[q];
                if v != 0.0 && (c == i || v.abs() > 0.0) {
                    ui.push(c);
                    uv.push(v);
                }
            }
            up.push(ui.len());
        }
        let l = CsrMatrix::from_raw(n, n, lp, li, lv)?;
        let u = CsrMatrix::from_raw(n, n, up, ui, uv)?;
        // The factors inherit the schedule so their parallel trisolves
        // follow the same barrier/dataflow choice as the factorization.
        Ok(SparseLuFactors::from_parts(l, u).with_schedule(self.schedule))
    }

    /// Sequential numeric refactorization over the cached pattern.
    /// Bitwise identical to `SparseLu::factor(a)` (exact mode).
    pub fn factor(&self, a: &CsrMatrix) -> Result<SparseLuFactors> {
        self.check(a)?;
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
        let mut l_val = vec![0.0f64; self.l_idx.len()];
        let mut u_val = vec![0.0f64; self.u_idx.len()];
        let mut acc = vec![0.0f64; self.n];
        let lp = l_val.as_mut_ptr();
        let upv = u_val.as_mut_ptr();
        for i in 0..self.n {
            // SAFETY: single-threaded sweep in row order — every
            // dependency row is finalized, writes are exclusive.
            if let Err((step, value)) = unsafe { self.numeric_row(i, a, &mut acc, lp, upv) } {
                return Err(EbvError::SingularPivot { step, value, tol: self.pivot_tol });
            }
        }
        self.assemble(&l_val, &u_val)
    }

    /// Level-parallel numeric refactorization on the process-global
    /// lane engine.
    pub fn factor_par(&self, a: &CsrMatrix, lanes: usize) -> Result<SparseLuFactors> {
        self.factor_par_on(a, lanes, crate::exec::global())
    }

    /// Level-parallel numeric refactorization: one barrier-stepped
    /// engine job with a step per DAG level; within a level, rows are
    /// dealt to `lanes` virtual lanes in cost-equalized chunks. Small
    /// levels keep a single chunk (lane 0 walks them in row order), and
    /// when *no* level is big enough to split the whole refactorization
    /// keeps the zero-synchronization sequential sweep — exactly the
    /// policy of the level-scheduled triangular solves.
    ///
    /// Factors are bitwise identical to [`SparseSymbolic::factor`] and
    /// to `SparseLu::factor` for every lane count and engine size: each
    /// row's arithmetic depends only on the symbolic pattern, never on
    /// which lane executes it.
    pub fn factor_par_on(
        &self,
        a: &CsrMatrix,
        lanes: usize,
        engine: &LaneEngine,
    ) -> Result<SparseLuFactors> {
        self.check(a)?;
        if lanes <= 1 {
            return self.factor(a);
        }
        if self.schedule == Schedule::Dataflow {
            return self.factor_dataflow_on(a, lanes, engine);
        }

        enum LevelChunks<'x> {
            /// Too small to split profitably: lane 0 walks the level.
            Single(&'x [usize]),
            /// Cost-equalized chunks, one per lane (possibly empty).
            Split(Vec<Vec<usize>>),
        }
        let chunks: Vec<LevelChunks<'_>> = self
            .by_level
            .iter()
            .map(|rows| {
                if rows.len() < lanes * 4 {
                    LevelChunks::Single(rows)
                } else {
                    let weights: Vec<usize> =
                        rows.iter().map(|&i| self.row_cost[i]).collect();
                    LevelChunks::Split(
                        equalize_weights(&weights, lanes)
                            .into_iter()
                            .map(|bin| bin.into_iter().map(|k| rows[k]).collect())
                            .collect(),
                    )
                }
            })
            .collect();
        if chunks.iter().all(|c| matches!(c, LevelChunks::Single(_))) {
            return self.factor(a);
        }
        // After the fall-throughs: they delegate to `factor`, which
        // records its own NumericFactor span — no double counting.
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);

        let mut l_val = vec![0.0f64; self.l_idx.len()];
        let mut u_val = vec![0.0f64; self.u_idx.len()];
        let l_shared = SharedF64(l_val.as_mut_ptr());
        let u_shared = SharedF64(u_val.as_mut_ptr());
        // One dense accumulator per virtual lane; rows assigned to a
        // lane within a step run sequentially on its accumulator.
        let mut accs: Vec<Vec<f64>> = (0..lanes).map(|_| vec![0.0f64; self.n]).collect();
        let acc_slots = LaneSlots::new(&mut accs);
        let bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

        engine.run_steps(lanes, chunks.len(), |vlane, lvl| {
            let chunk: Option<&[usize]> = match &chunks[lvl] {
                LevelChunks::Single(rows) => (vlane == 0).then_some(*rows),
                LevelChunks::Split(cs) => cs.get(vlane).map(Vec::as_slice),
            };
            let Some(rows) = chunk else { return StepCtl::Continue };
            // SAFETY: each vlane touches only its own accumulator slot.
            let acc = unsafe { acc_slots.slot(vlane) };
            for &i in rows {
                // SAFETY: levels partition rows (disjoint l/u ranges);
                // every dependency of row i sits in an earlier level,
                // whose writes the step barrier published.
                let outcome =
                    unsafe { self.numeric_row(i, a, &mut acc[..], l_shared.0, u_shared.0) };
                if let Err((step, value)) = outcome {
                    let mut slot = bad.lock().expect("pivot slot");
                    if slot.is_none() {
                        *slot = Some((step, value));
                    }
                    return StepCtl::Break;
                }
            }
            StepCtl::Continue
        });

        if let Some((step, value)) = bad.into_inner().expect("pivot slot") {
            return Err(EbvError::SingularPivot { step, value, tol: self.pivot_tol });
        }
        self.assemble(&l_val, &u_val)
    }

    /// Dataflow numeric refactorization: one task per row, whose
    /// remaining-dependency counter is its symbolic `L`-row length and
    /// whose children are the transpose of the `L` pattern — rows run
    /// the moment their last dependency's `U` values land, with no
    /// level barriers at all (one engine step per refactorization; the
    /// level structure stays behind as the barrier fallback and the
    /// planner's cost model). Each executing lane scatters into its own
    /// dense accumulator, which [`SparseSymbolic::numeric_row`] restores
    /// to all-zero — so lane assignment, engine size, and completion
    /// interleaving are all bit-inert and the factors are bitwise
    /// identical to [`SparseSymbolic::factor`] (pinned in the tests
    /// below and `tests/prop_schedule.rs`).
    ///
    /// Tiny systems (`n < lanes * 4`, the level path's single-chunk
    /// threshold applied globally) keep the sequential sweep — task
    /// bookkeeping would dominate.
    ///
    /// Concurrent failures: every failing row records, the **lowest**
    /// step wins — the same row the sequential sweep reports unless
    /// several pivots fail in one run, where the barrier path's
    /// first-seen row is itself scheduling-dependent.
    fn factor_dataflow_on(
        &self,
        a: &CsrMatrix,
        lanes: usize,
        engine: &LaneEngine,
    ) -> Result<SparseLuFactors> {
        if self.n < lanes * 4 {
            return self.factor(a);
        }
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);

        let mut graph = DepGraph::new(self.n);
        for i in 0..self.n {
            for pos in self.l_ptr[i]..self.l_ptr[i + 1] {
                graph.add_edge(self.l_idx[pos], i);
            }
        }

        let mut l_val = vec![0.0f64; self.l_idx.len()];
        let mut u_val = vec![0.0f64; self.u_idx.len()];
        let l_shared = SharedF64(l_val.as_mut_ptr());
        let u_shared = SharedF64(u_val.as_mut_ptr());
        // One dense accumulator per *executing* lane (workers are the
        // engine's lanes here, not schedule vlanes).
        let workers = engine.lanes().max(1);
        let mut accs: Vec<Vec<f64>> = (0..workers).map(|_| vec![0.0f64; self.n]).collect();
        let acc_slots = LaneSlots::new(&mut accs);
        let bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

        run_dataflow(engine, &graph, |worker, i| {
            // SAFETY: each worker touches only its own accumulator
            // slot; row i's l/u ranges are written by this task alone;
            // every dependency row completed first (dep edges), its
            // writes published by the counters' AcqRel chain.
            let acc = unsafe { acc_slots.slot(worker) };
            let outcome = unsafe { self.numeric_row(i, a, &mut acc[..], l_shared.0, u_shared.0) };
            if let Err((step, value)) = outcome {
                let mut slot = bad.lock().expect("pivot slot");
                if slot.map_or(true, |(s, _)| step < s) {
                    *slot = Some((step, value));
                }
                return StepCtl::Break;
            }
            StepCtl::Continue
        });

        if let Some((step, value)) = bad.into_inner().expect("pivot slot") {
            return Err(EbvError::SingularPivot { step, value, tol: self.pivot_tol });
        }
        self.assemble(&l_val, &u_val)
    }

    /// Device-sharded level-parallel numeric refactorization: one
    /// sharded step per DAG level on a [`DeviceSet`], rows of a level
    /// dealt **devices-first** by the hierarchical equalizer
    /// ([`equalize_hierarchical`] over the symbolic row costs — the
    /// two-level EBV deal), each (device, vlane) scattering into its
    /// own dense accumulator. The exchange phase accounts the previous
    /// level's finalized `U` values as the per-step broadcast — the
    /// traffic the inter-partition exchange of a real multi-device
    /// triangular factorization is dominated by.
    ///
    /// Factors are bitwise identical to [`SparseSymbolic::factor`] and
    /// [`SparseSymbolic::factor_par_on`] for every device count, lane
    /// count and engine size (per-row arithmetic depends only on the
    /// symbolic pattern). A single-device set falls through to
    /// [`SparseSymbolic::factor_par_on`] on its engine; `lanes` is the
    /// total vlane budget, split `ceil(lanes / devices)` per device.
    pub fn factor_sharded(
        &self,
        a: &CsrMatrix,
        lanes: usize,
        set: &DeviceSet,
    ) -> Result<SparseLuFactors> {
        self.check(a)?;
        let d = set.devices();
        if d <= 1 {
            return self.factor_par_on(a, lanes, set.engine(0).as_ref());
        }
        let lpd = lanes.div_ceil(d).max(1);
        let total = d * lpd;

        enum LevelChunks<'x> {
            /// Too small to shard: device 0's vlane 0 walks the level.
            Single(&'x [usize]),
            /// `chunks[device][vlane]` row lists (cost-equalized).
            Split(Vec<Vec<Vec<usize>>>),
        }
        let chunks: Vec<LevelChunks<'_>> = self
            .by_level
            .iter()
            .map(|rows| {
                if rows.len() < total * 4 {
                    LevelChunks::Single(rows)
                } else {
                    let weights: Vec<usize> =
                        rows.iter().map(|&i| self.row_cost[i]).collect();
                    LevelChunks::Split(
                        equalize_hierarchical(&weights, d, lpd)
                            .into_iter()
                            .map(|dev| {
                                dev.into_iter()
                                    .map(|bin| {
                                        bin.into_iter().map(|k| rows[k]).collect()
                                    })
                                    .collect()
                            })
                            .collect(),
                    )
                }
            })
            .collect();
        if chunks.iter().all(|c| matches!(c, LevelChunks::Single(_))) {
            return self.factor(a);
        }
        // After the fall-throughs (`factor`, `factor_par_on` record
        // their own spans — no double counting).
        let _t = crate::obs::SpanTimer::start(crate::obs::Phase::NumericFactor);
        // Exchange accounting: a level's refactorization reads the `U`
        // rows its dependencies finalized at the previous level.
        let level_u_elems: Vec<usize> = self
            .by_level
            .iter()
            .map(|rows| rows.iter().map(|&i| self.u_ptr[i + 1] - self.u_ptr[i]).sum())
            .collect();

        let mut l_val = vec![0.0f64; self.l_idx.len()];
        let mut u_val = vec![0.0f64; self.u_idx.len()];
        let l_shared = SharedF64(l_val.as_mut_ptr());
        let u_shared = SharedF64(u_val.as_mut_ptr());
        // One dense accumulator per (device, vlane), device-major.
        let mut accs: Vec<Vec<f64>> = (0..total).map(|_| vec![0.0f64; self.n]).collect();
        let acc_slots = LaneSlots::new(&mut accs);
        let bad: Mutex<Option<(usize, f64)>> = Mutex::new(None);

        set.run_sharded(
            lpd,
            chunks.len(),
            |lvl| {
                if lvl > 0 {
                    set.record_exchange(level_u_elems[lvl - 1]);
                }
                StepCtl::Continue
            },
            |dev, vlane, lvl| {
                let rows: Option<&[usize]> = match &chunks[lvl] {
                    LevelChunks::Single(rows) => {
                        (dev == 0 && vlane == 0).then_some(*rows)
                    }
                    LevelChunks::Split(cs) => {
                        cs.get(dev).and_then(|c| c.get(vlane)).map(Vec::as_slice)
                    }
                };
                let Some(rows) = rows else { return StepCtl::Continue };
                // SAFETY: each (device, vlane) touches only its own slot.
                let acc = unsafe { acc_slots.slot(dev * lpd + vlane) };
                for &i in rows {
                    // SAFETY: levels partition rows (disjoint l/u
                    // ranges); every dependency of row i sits in an
                    // earlier level, published by the cross-device
                    // step barrier.
                    let outcome = unsafe {
                        self.numeric_row(i, a, &mut acc[..], l_shared.0, u_shared.0)
                    };
                    if let Err((step, value)) = outcome {
                        let mut slot = bad.lock().expect("pivot slot");
                        if slot.is_none() {
                            *slot = Some((step, value));
                        }
                        return StepCtl::Break;
                    }
                }
                StepCtl::Continue
            },
        );

        if let Some((step, value)) = bad.into_inner().expect("pivot slot") {
            return Err(EbvError::SingularPivot { step, value, tol: self.pivot_tol });
        }
        self.assemble(&l_val, &u_val)
    }
}

/// Raw-pointer wrapper making the factor-value workspaces shareable
/// across lanes (writes are disjoint by row ownership).
struct SharedF64(*mut f64);
unsafe impl Send for SharedF64 {}
unsafe impl Sync for SharedF64 {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{
        diag_dominant_sparse, manufactured_solution, poisson_2d, GenSeed,
    };
    use crate::matrix::norms::diff_inf;
    use crate::solver::SparseLu;
    use crate::testutil::rescale_csr;

    #[test]
    fn symbolic_pattern_matches_numeric_factor() {
        let a = poisson_2d(10);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let f = SparseLu::new().factor(&a).unwrap();
        // Exact arithmetic produces no accidental zeros here, so the
        // symbolic pattern equals the factored pattern exactly.
        assert_eq!(sym.l_nnz(), f.l().nnz());
        assert_eq!(sym.u_nnz(), f.u().nnz());
        assert_eq!(sym.fill_in(&a), f.fill_in(&a));
    }

    #[test]
    fn sequential_numeric_is_bitwise_sparse_lu() {
        for seed in [50u64, 51, 52] {
            let a = diag_dominant_sparse(60, 5, GenSeed(seed));
            let sym = SparseSymbolic::analyze(&a).unwrap();
            let reference = SparseLu::new().factor(&a).unwrap();
            let f = sym.factor(&a).unwrap();
            assert_eq!(f.l(), reference.l(), "seed={seed}");
            assert_eq!(f.u(), reference.u(), "seed={seed}");
        }
    }

    #[test]
    fn parallel_numeric_is_bitwise_sequential() {
        let a = poisson_2d(12);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let reference = SparseLu::new().factor(&a).unwrap();
        for lanes in [1usize, 2, 3, 4, 8] {
            for engine_lanes in [1usize, 2, 4] {
                let engine = LaneEngine::new(engine_lanes);
                let f = sym.factor_par_on(&a, lanes, &engine).unwrap();
                assert_eq!(f.l(), reference.l(), "lanes={lanes} engine={engine_lanes}");
                assert_eq!(f.u(), reference.u(), "lanes={lanes} engine={engine_lanes}");
            }
        }
    }

    #[test]
    fn dataflow_numeric_is_bitwise_sequential() {
        // Per-row dependency counters replace the level barriers; each
        // row still computes from the same finalized dependencies, so
        // the factors are bitwise identical for every lane count and
        // engine size.
        let a = poisson_2d(12);
        let sym = SparseSymbolic::analyze(&a).unwrap().with_schedule(Schedule::Dataflow);
        let reference = SparseLu::new().factor(&a).unwrap();
        for lanes in [2usize, 3, 8] {
            for engine_lanes in [1usize, 2, 4] {
                let engine = LaneEngine::new(engine_lanes);
                let f = sym.factor_par_on(&a, lanes, &engine).unwrap();
                assert_eq!(f.l(), reference.l(), "lanes={lanes} engine={engine_lanes}");
                assert_eq!(f.u(), reference.u(), "lanes={lanes} engine={engine_lanes}");
            }
        }
    }

    #[test]
    fn dataflow_costs_one_engine_step() {
        let a = poisson_2d(12);
        let sym = SparseSymbolic::analyze(&a).unwrap().with_schedule(Schedule::Dataflow);
        let engine = LaneEngine::new(3);
        let before = engine.stats();
        let dep_before = engine.dep_stats();
        sym.factor_par_on(&a, 4, &engine).unwrap();
        let after = engine.stats();
        let dep_after = engine.dep_stats();
        assert_eq!(after.steps - before.steps, 1, "whole DAG in one barrier entry");
        assert_eq!(dep_after.runs - dep_before.runs, 1);
        assert_eq!(dep_after.tasks - dep_before.tasks, sym.n() as u64);
    }

    #[test]
    fn dataflow_detects_numerically_singular_pivot() {
        let a = CsrMatrix::from_raw(
            2,
            2,
            vec![0, 2, 4],
            vec![0, 1, 0, 1],
            vec![1.0, 2.0, 0.5, 1.0],
        )
        .unwrap();
        let sym = SparseSymbolic::analyze(&a).unwrap().with_schedule(Schedule::Dataflow);
        // n < lanes*4 falls back to the sequential sweep — still the
        // same error.
        let err = sym.factor_par_on(&a, 4, &LaneEngine::new(2));
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
        // A grid large enough to run the dataflow path proper, with one
        // poisoned row: an all-zero row pins its pivot to exact zero
        // (its multipliers and updates all vanish), and no other pivot
        // fails, so the reported step is deterministic in both modes.
        let g = poisson_2d(10);
        let n = g.rows();
        let bad_row = n / 2;
        let mut vals = g.values().to_vec();
        for v in &mut vals[g.row_ptr()[bad_row]..g.row_ptr()[bad_row + 1]] {
            *v = 0.0;
        }
        let poisoned =
            CsrMatrix::from_raw(n, n, g.row_ptr().to_vec(), g.col_idx().to_vec(), vals).unwrap();
        let sym = SparseSymbolic::analyze(&poisoned)
            .unwrap()
            .with_schedule(Schedule::Dataflow);
        let seq = sym.factor(&poisoned);
        let par = sym.factor_par_on(&poisoned, 4, &LaneEngine::new(4));
        let step_of = |r: &Result<SparseLuFactors>| match r {
            Err(EbvError::SingularPivot { step, .. }) => *step,
            other => panic!("expected SingularPivot, got {other:?}"),
        };
        assert_eq!(step_of(&seq), bad_row);
        assert_eq!(step_of(&par), bad_row);
    }

    #[test]
    fn refactor_same_pattern_new_values() {
        let a = poisson_2d(9);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let a2 = rescale_csr(&a, -2.5);
        assert!(sym.matches_pattern(&a2));
        let reference = SparseLu::new().factor(&a2).unwrap();
        let f = sym.factor_par(&a2, 4).unwrap();
        assert_eq!(f.l(), reference.l());
        assert_eq!(f.u(), reference.u());
        // And the refactored system still solves.
        let (x_true, b) = manufactured_solution(&a2, GenSeed(61));
        let x = f.solve(&b).unwrap();
        assert!(diff_inf(&x, &x_true) < 1e-8);
    }

    #[test]
    fn rejects_mismatched_pattern() {
        let a = diag_dominant_sparse(30, 4, GenSeed(53));
        let other = diag_dominant_sparse(30, 4, GenSeed(54));
        let sym = SparseSymbolic::analyze(&a).unwrap();
        assert!(!sym.matches_pattern(&other));
        assert!(matches!(sym.factor(&other), Err(EbvError::Shape(_))));
        assert!(matches!(sym.factor_par(&other, 4), Err(EbvError::Shape(_))));
    }

    #[test]
    fn levels_respect_dependencies_and_partition_rows() {
        let a = poisson_2d(8);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let level = sym.levels();
        // Every L dependency j of row i sits at a strictly lower level.
        for i in 0..sym.n() {
            for pos in sym.l_ptr[i]..sym.l_ptr[i + 1] {
                let j = sym.l_idx[pos];
                assert!(level[j] < level[i], "row {i} dep {j}");
            }
        }
        let total: usize = sym.rows_by_level().iter().map(Vec::len).sum();
        assert_eq!(total, sym.n());
        assert!(sym.level_count() >= 1);
        assert!(sym.level_count() < sym.n(), "Poisson DAG must be shallow");
    }

    #[test]
    fn detects_structurally_singular_diagonal() {
        // Row 1 has no diagonal and nothing below to fill it.
        let a = CsrMatrix::from_raw(2, 2, vec![0, 1, 2], vec![0, 0], vec![1.0, 1.0]).unwrap();
        assert!(matches!(
            SparseSymbolic::analyze(&a),
            Err(EbvError::SingularPivot { step: 1, .. })
        ));
    }

    #[test]
    fn detects_numerically_singular_pivot() {
        // Structurally fine diagonal whose value is zero.
        let a = CsrMatrix::from_raw(
            2,
            2,
            vec![0, 2, 4],
            vec![0, 1, 0, 1],
            vec![1.0, 2.0, 0.5, 1.0],
        )
        .unwrap();
        let sym = SparseSymbolic::analyze(&a).unwrap();
        // a21/a11 * a12 = 0.5 * 2 = 1 -> u22 = 1 - 1 = 0: singular.
        let err = sym.factor(&a);
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
        let err = sym.factor_par_on(&a, 4, &LaneEngine::new(2));
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 1, .. })), "{err:?}");
    }

    #[test]
    fn sharded_numeric_is_bitwise_sequential() {
        let a = poisson_2d(12);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let reference = SparseLu::new().factor(&a).unwrap();
        for devices in [1usize, 2, 4] {
            let set = DeviceSet::new(devices, 2);
            let f = sym.factor_sharded(&a, 4, &set).unwrap();
            assert_eq!(f.l(), reference.l(), "devices={devices}");
            assert_eq!(f.u(), reference.u(), "devices={devices}");
        }
    }

    #[test]
    fn sharded_wide_levels_run_sharded_and_account_exchange() {
        // Two wide DAG levels by construction: rows 0..20 are diagonal
        // (level 0), rows 20..40 each depend on one level-0 row.
        let n = 40;
        let mut ptr = vec![0usize];
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for i in 0..n {
            if i >= 20 {
                idx.push(i - 20);
                val.push(1.0);
            }
            idx.push(i);
            val.push(2.0);
            ptr.push(idx.len());
        }
        let a = CsrMatrix::from_raw(n, n, ptr, idx, val).unwrap();
        let sym = SparseSymbolic::analyze(&a).unwrap();
        assert_eq!(sym.level_count(), 2);
        let reference = SparseLu::new().factor(&a).unwrap();
        let set = DeviceSet::new(2, 2);
        let f = sym.factor_sharded(&a, 4, &set).unwrap();
        assert_eq!(f.l(), reference.l());
        assert_eq!(f.u(), reference.u());
        let snap = set.snapshot();
        assert_eq!(snap.sharded_jobs, 1, "{snap:?}");
        // Level 1's exchange broadcasts level 0's 20 finalized U rows
        // (one diagonal entry each).
        assert_eq!(snap.exchange_elems, 20, "{snap:?}");
        assert_eq!(snap.exchange_steps, 2, "{snap:?}");
    }

    #[test]
    fn sharded_detects_numerically_singular_pivot() {
        // Identity pattern with one zero diagonal: every row is level 0,
        // so the sharded path engages (16 rows >= total * 4).
        let n = 16;
        let mut vals = vec![3.0; n];
        vals[9] = 0.0;
        let a = CsrMatrix::from_raw(n, n, (0..=n).collect(), (0..n).collect(), vals).unwrap();
        let sym = SparseSymbolic::analyze(&a).unwrap();
        let set = DeviceSet::new(2, 2);
        let err = sym.factor_sharded(&a, 2, &set);
        assert!(matches!(err, Err(EbvError::SingularPivot { step: 9, .. })), "{err:?}");
    }

    #[test]
    fn rejects_rectangular() {
        assert!(SparseSymbolic::analyze(&CsrMatrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn identity_analysis_is_trivial() {
        let a = CsrMatrix::from_dense(&crate::matrix::DenseMatrix::identity(5), 0.0);
        let sym = SparseSymbolic::analyze(&a).unwrap();
        assert_eq!(sym.l_nnz(), 0);
        assert_eq!(sym.u_nnz(), 5);
        assert_eq!(sym.level_count(), 1, "independent rows share level 0");
        let f = sym.factor_par(&a, 4).unwrap();
        let x = f.solve(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }
}
