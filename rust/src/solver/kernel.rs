//! Shared f64 trailing-update microkernel.
//!
//! One module owns the hot inner loop of every factorization in the
//! crate: the rank-`nb` trailing update `A22 -= L21 · U12` that PR 3
//! made the dominant cost of blocked elimination, plus the guarded
//! scatter-AXPY at the heart of the sparse numeric refactorization.
//! `EbvLu`'s flat and device-sharded blocked paths, `BlockedLu`'s GEMM
//! step and `SparseSymbolic::numeric_row` all call in here — the
//! previously duplicated hand-fused loops in `lu_ebv.rs` and
//! `lu_blocked.rs` are gone.
//!
//! ## Kernel variants
//!
//! [`Kernel`] selects the dense update shape (`--kernel`,
//! `service.kernel`, or the `EBV_KERNEL` environment variable through
//! [`Kernel::resolve`]):
//!
//! * **`unroll4`** — the historical kernel, byte-for-byte: four panel
//!   columns fused per sweep of the trailing row (quarters the write
//!   traffic; EXPERIMENTS.md §Perf, L3-D1), all-zero multiplier groups
//!   skipped, scalar remainder skipping zero multipliers. Plain
//!   indexed loops over `f64` slices with no data-dependent exits
//!   inside the j-loop — the pattern LLVM's loop vectorizer provably
//!   turns into SIMD.
//! * **`unroll8`** — the same shape fused eight wide. Fusing more
//!   terms re-associates the per-element sum, so `unroll8` factors
//!   agree with `unroll4` (and `SeqLu`) componentwise, not bitwise.
//! * **`tiled`** (the `auto` default) — `unroll4` arithmetic under an
//!   `MC × KC × NR` cache tiling of the `ikj` sweep (see the cache
//!   model below). Because [`KC`] is a multiple of the fuse width and
//!   k ascends within every `(i, j)` element, tiling only *partitions*
//!   the `unroll4` iteration space — tiled factors are **bitwise
//!   identical** to `unroll4` for every matrix and every tile size
//!   satisfying those two constraints (pinned in the tests here and in
//!   `rust/tests/prop_panel.rs`).
//!
//! Every variant is deterministic: for a fixed kernel choice the
//! factors are bit-stable across lane counts, row distributions,
//! engine sizes and device counts, because the caller's row set only
//! partitions the **M dimension** — see [`trailing_update`].
//!
//! ## Cache model
//!
//! Tile sizes come from a small compile-time model in the spirit of
//! the fixed VMEM tile shapes of the Pallas kernels
//! (`python/` pipeline; a Pallas grid step stages an `(bm, bk)×(bk,
//! bn)` block pair into VMEM exactly like the KC×NR panel block here
//! stays L1-resident):
//!
//! * The `KC × NR` slab of `U12` is the block every row of the tile
//!   re-reads; budget half of L1 for it → `NR = (L1/2) / (KC · 8)`.
//! * The `MC`-row working set (`MC × (KC + NR)` elements: multipliers
//!   plus updated trailing columns) should sit in half of L2 →
//!   `MC = (L2/2) / ((KC + NR) · 8)`.
//! * `KC` is fixed at 32 — deep enough to amortize the per-tile loop
//!   overhead, shallow enough that `NR` stays a useful 64 columns —
//!   and **must** stay a multiple of 8 (a multiple of both fuse
//!   widths) or the bitwise tiling guarantee above breaks; a const
//!   assertion enforces it.
//!
//! The constants assume 32 KiB L1d / 512 KiB L2 per core — the
//! conservative end of current x86/ARM server cores. They are
//! deliberately compile-time: runtime cache probing would make factor
//! bits host-dependent, which the bit-identity ledger forbids.

/// L1 data cache budget assumed by the tile model (bytes).
pub const L1_BYTES: usize = 32 * 1024;
/// L2 cache budget assumed by the tile model (bytes).
pub const L2_BYTES: usize = 512 * 1024;
const F64_BYTES: usize = std::mem::size_of::<f64>();

/// Panel-depth tile: columns of `L21` / rows of `U12` per sweep.
pub const KC: usize = 32;
/// Trailing-column tile: `KC × NR × 8` bytes is half of L1.
pub const NR: usize = (L1_BYTES / 2) / (KC * F64_BYTES);
/// Row tile: `MC × (KC + NR) × 8` bytes is half of L2.
pub const MC: usize = (L2_BYTES / 2) / ((KC + NR) * F64_BYTES);

// The bitwise tiled≡unroll4 guarantee needs every interior k-tile
// boundary to land on a fuse-group boundary: KC must be a multiple of
// both fuse widths (4 and 8). The others just guard against a future
// cache-budget edit degenerating the tiling.
const _: () = assert!(KC % 8 == 0, "KC must be a multiple of the fuse widths");
const _: () = assert!(NR > 0 && MC > 0, "degenerate tile sizes");

/// Dense trailing-update kernel selection.
///
/// Follows the [`RowDist`](crate::ebv::schedule::RowDist) idiom:
/// [`Kernel::ALL`] + [`Kernel::name`] + [`Kernel::parse`] keep the
/// CLI, config file and wire codec spelling in one place.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// Defer the choice to [`Kernel::resolve`]: the `EBV_KERNEL`
    /// environment variable if set to a concrete kernel, else
    /// [`Kernel::Tiled`].
    #[default]
    Auto,
    /// The historical 4-wide fused kernel, byte-for-byte.
    Unroll4,
    /// 8-wide fusion: halves write traffic again, re-associates the
    /// per-element sum (componentwise contract, not bitwise).
    Unroll8,
    /// `unroll4` arithmetic under MC×KC×NR cache tiling — bitwise
    /// identical to [`Kernel::Unroll4`].
    Tiled,
}

impl Kernel {
    /// Every variant, in presentation order.
    pub const ALL: [Kernel; 4] = [Kernel::Auto, Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled];

    /// Config/CLI/wire spelling.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Auto => "auto",
            Kernel::Unroll4 => "unroll4",
            Kernel::Unroll8 => "unroll8",
            Kernel::Tiled => "tiled",
        }
    }

    /// Inverse of [`Kernel::name`].
    pub fn parse(name: &str) -> Option<Kernel> {
        Kernel::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Collapse [`Kernel::Auto`] to a concrete kernel: a concrete
    /// `EBV_KERNEL` environment value wins (the CI smoke matrix drives
    /// default-configured benches this way), anything else — unset,
    /// `auto`, or unparseable — falls back to [`Kernel::Tiled`].
    /// Concrete variants return themselves without touching the
    /// environment, so callers may resolve once per factorization and
    /// pass the result down.
    pub fn resolve(self) -> Kernel {
        match self {
            Kernel::Auto => match std::env::var("EBV_KERNEL") {
                Ok(v) => match Kernel::parse(v.trim()) {
                    Some(Kernel::Auto) | None => Kernel::Tiled,
                    Some(k) => k,
                },
                Err(_) => Kernel::Tiled,
            },
            k => k,
        }
    }
}

/// Raw row-major matrix view the kernel reads panel rows from and
/// writes trailing rows through. A thin, `Copy` cousin of the solver
/// paths' `SharedMatrix`: the callers' safety argument (disjoint row
/// ownership, barrier-sequenced panel reads) is exactly the one they
/// already make; this type just carries the pointer across the call.
#[derive(Clone, Copy)]
pub struct MatView {
    ptr: *mut f64,
    stride: usize,
}

impl MatView {
    /// View over a row-major buffer with `stride` columns per row.
    ///
    /// The returned view is only as valid as `ptr`: every row index
    /// later passed to [`trailing_update`] must lie inside the
    /// allocation, and the caller keeps the aliasing obligations
    /// documented there.
    pub fn from_raw(ptr: *mut f64, stride: usize) -> MatView {
        MatView { ptr, stride }
    }

    /// Columns `[lo, hi)` of row `r`, immutable.
    ///
    /// # Safety
    /// No concurrent write may overlap the range (panel rows are
    /// finalized before the kernel runs).
    #[inline]
    unsafe fn row(&self, r: usize, lo: usize, hi: usize) -> &[f64] {
        std::slice::from_raw_parts(self.ptr.add(r * self.stride + lo), hi - lo)
    }

    /// Columns `[lo, hi)` of row `i`, mutable.
    ///
    /// # Safety
    /// The caller must have exclusive access to the range.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&self, i: usize, lo: usize, hi: usize) -> &mut [f64] {
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride + lo), hi - lo)
    }
}

/// Rank-`nb` trailing update over an explicit row set:
///
/// ```text
/// for i in rows:  A[i, panel_end..cols_end] -= A[i, panel_start..panel_end] · U12
/// where U12 = A[panel_start..panel_end, panel_end..cols_end]
/// ```
///
/// `rows` is the caller's ownership set and forms the **outer M
/// partition** of the tiling: the EBV paths pass each lane's
/// `LaneSchedule::rows_from` range, `BlockedLu` passes the whole
/// trailing range. Kernel choice and tile sizes only subdivide the
/// iteration space *within* that set — no kernel ever moves a row
/// across lanes, which is why factors are bit-stable across lane
/// counts, distributions and device counts for a fixed kernel.
///
/// [`Kernel::Auto`] is resolved here (cheap for concrete variants),
/// so callers may pass the configured choice straight through.
///
/// # Safety
/// * Every index in `rows`, and every row in
///   `[panel_start, panel_end)`, must be in bounds of `view`, with
///   `panel_start <= panel_end <= cols_end <= stride`.
/// * The caller has exclusive write access to
///   `[panel_start, cols_end)` of every row in `rows` for the
///   duration of the call (rows owned by this lane, disjoint across
///   lanes).
/// * No row in `rows` lies in `[panel_start, panel_end)`, and the
///   panel rows' `[panel_end, cols_end)` ranges (`U12`) are finalized
///   and published before the call (barrier-sequenced by the callers).
pub unsafe fn trailing_update(
    kernel: Kernel,
    view: MatView,
    rows: &[usize],
    panel_start: usize,
    panel_end: usize,
    cols_end: usize,
) {
    let width = panel_end - panel_start;
    if width == 0 || panel_end >= cols_end || rows.is_empty() {
        return;
    }
    match kernel.resolve() {
        Kernel::Auto => unreachable!("resolve() returns a concrete kernel"),
        Kernel::Unroll4 => {
            for &i in rows {
                let row_i = view.row_mut(i, panel_start, cols_end);
                let (l_i, tail) = row_i.split_at_mut(width);
                axpy_rank_k_4(view, l_i, panel_start, tail, panel_end);
            }
        }
        Kernel::Unroll8 => {
            for &i in rows {
                let row_i = view.row_mut(i, panel_start, cols_end);
                let (l_i, tail) = row_i.split_at_mut(width);
                axpy_rank_k_8(view, l_i, panel_start, tail, panel_end);
            }
        }
        Kernel::Tiled => {
            // ikj sweep tiled MC×KC×NR: the innermost row loop re-reads
            // one KC×NR slab of U12 (L1-resident by construction) for up
            // to MC rows, and each row's k-tiles ascend — so per (i, j)
            // element the update order, fuse grouping and zero-group
            // skips are exactly unroll4's. Bitwise identical.
            for row_chunk in rows.chunks(MC) {
                let mut k0 = panel_start;
                while k0 < panel_end {
                    let k1 = (k0 + KC).min(panel_end);
                    let mut j0 = panel_end;
                    while j0 < cols_end {
                        let j1 = (j0 + NR).min(cols_end);
                        for &i in row_chunk {
                            // SAFETY: [k0, j1) of row i splits into the
                            // read-only multiplier slice (within the
                            // finalized-for-this-row panel columns) and
                            // the owned trailing tile, per the function
                            // contract.
                            let row_i = view.row_mut(i, k0, j1);
                            let (head, rest) = row_i.split_at_mut(panel_end - k0);
                            let l_i = &head[..k1 - k0];
                            let tail = &mut rest[j0 - panel_end..];
                            axpy_rank_k_4(view, l_i, k0, tail, j0);
                        }
                        j0 = j1;
                    }
                    k0 = k1;
                }
            }
        }
    }
}

/// Column-ranged sibling of [`trailing_update`]: apply the same
/// rank-`nb` update to columns `[cols_lo, cols_hi)` only, where
/// `panel_end <= cols_lo <= cols_hi <= stride`.
///
/// **Bit-inertness of the column split.** Every kernel's inner loops
/// iterate over the *panel* (`k`) dimension with fuse groups anchored
/// at `panel_start` (unrolled paths) or at the fixed
/// `panel_start + m·KC` tile boundaries (tiled path, `KC % 8 == 0`);
/// the trailing (`j`) dimension only selects which independent output
/// elements receive that identical k-sweep. Splitting the call at any
/// column therefore changes no element's operand order or fuse
/// grouping: any partition of `[panel_end, cols_end)` into
/// `trailing_update_cols` calls is **bitwise identical** to one full
/// [`trailing_update`], for every kernel (pinned by
/// `column_partition_never_changes_bits` below). This is what lets the
/// dataflow dense path carve one panel's trailing sweep into
/// lookahead pieces without touching the numeric ledger.
///
/// # Safety
/// As [`trailing_update`], with the write range narrowed: the caller
/// has exclusive write access to `[cols_lo, cols_hi)` of every row in
/// `rows`, read access to their finalized `[panel_start, panel_end)`
/// multipliers, and the panel rows' `[cols_lo, cols_hi)` (`U12` slab)
/// are finalized and published before the call.
pub unsafe fn trailing_update_cols(
    kernel: Kernel,
    view: MatView,
    rows: &[usize],
    panel_start: usize,
    panel_end: usize,
    cols_lo: usize,
    cols_hi: usize,
) {
    debug_assert!(panel_end <= cols_lo && cols_lo <= cols_hi);
    let width = panel_end - panel_start;
    if width == 0 || cols_lo >= cols_hi || rows.is_empty() {
        return;
    }
    match kernel.resolve() {
        Kernel::Auto => unreachable!("resolve() returns a concrete kernel"),
        Kernel::Unroll4 => {
            for &i in rows {
                // SAFETY: the multiplier slice [panel_start, panel_end)
                // is finalized and disjoint from the written tail
                // (cols_lo >= panel_end), per the function contract.
                let l_i = view.row(i, panel_start, panel_end);
                let tail = view.row_mut(i, cols_lo, cols_hi);
                axpy_rank_k_4(view, l_i, panel_start, tail, cols_lo);
            }
        }
        Kernel::Unroll8 => {
            for &i in rows {
                let l_i = view.row(i, panel_start, panel_end);
                let tail = view.row_mut(i, cols_lo, cols_hi);
                axpy_rank_k_8(view, l_i, panel_start, tail, cols_lo);
            }
        }
        Kernel::Tiled => {
            // Same MC×KC×NR sweep as the full call; k-tile anchors stay
            // at panel_start + m·KC, so fuse grouping per element is
            // unchanged no matter where the column range starts.
            for row_chunk in rows.chunks(MC) {
                let mut k0 = panel_start;
                while k0 < panel_end {
                    let k1 = (k0 + KC).min(panel_end);
                    let mut j0 = cols_lo;
                    while j0 < cols_hi {
                        let j1 = (j0 + NR).min(cols_hi);
                        for &i in row_chunk {
                            // SAFETY: per the function contract — the
                            // multiplier k-tile is finalized and
                            // disjoint from the owned trailing tile.
                            let l_i = view.row(i, k0, k1);
                            let tail = view.row_mut(i, j0, j1);
                            axpy_rank_k_4(view, l_i, k0, tail, j0);
                        }
                        j0 = j1;
                    }
                    k0 = k1;
                }
            }
        }
    }
}

/// One row's rank-`l.len()` update over `tail`, four panel columns
/// fused per sweep: `tail[j] -= Σ_p l[p] · U[k_base + p, j_base + j]`.
///
/// This is the historical `lu_ebv.rs`/`lu_blocked.rs` loop verbatim:
/// four multipliers per group (skipped when all four are zero — the
/// multipliers the factorization dropped), scalar remainder skipping
/// zero multipliers. The j-loop bodies index plain `f64` slices with
/// no side exits, which LLVM autovectorizes.
///
/// # Safety
/// Rows `k_base..k_base + l.len()` of `view` at columns
/// `[j_base, j_base + tail.len())` must be in bounds, finalized, and
/// disjoint from `tail`.
#[inline]
unsafe fn axpy_rank_k_4(view: MatView, l: &[f64], k_base: usize, tail: &mut [f64], j_base: usize) {
    let width = l.len();
    let hi = j_base + tail.len();
    let mut p = 0usize;
    while p + 4 <= width {
        let (l0, l1, l2, l3) = (l[p], l[p + 1], l[p + 2], l[p + 3]);
        if l0 == 0.0 && l1 == 0.0 && l2 == 0.0 && l3 == 0.0 {
            p += 4;
            continue;
        }
        let u0 = view.row(k_base + p, j_base, hi);
        let u1 = view.row(k_base + p + 1, j_base, hi);
        let u2 = view.row(k_base + p + 2, j_base, hi);
        let u3 = view.row(k_base + p + 3, j_base, hi);
        for (j, t) in tail.iter_mut().enumerate() {
            *t -= l0 * u0[j] + l1 * u1[j] + l2 * u2[j] + l3 * u3[j];
        }
        p += 4;
    }
    while p < width {
        let lp = l[p];
        if lp != 0.0 {
            let up = view.row(k_base + p, j_base, hi);
            for (t, &u) in tail.iter_mut().zip(up.iter()) {
                *t -= lp * u;
            }
        }
        p += 1;
    }
}

/// Eight-wide sibling of [`axpy_rank_k_4`]: same shape, eight panel
/// columns fused per sweep (one trailing-row write per eight
/// multiply-adds). The wider fusion re-associates each element's sum,
/// so results differ from `unroll4` in rounding — componentwise
/// contract — but remain fully deterministic for a fixed panel
/// decomposition.
///
/// # Safety
/// As [`axpy_rank_k_4`].
#[inline]
unsafe fn axpy_rank_k_8(view: MatView, l: &[f64], k_base: usize, tail: &mut [f64], j_base: usize) {
    let width = l.len();
    let hi = j_base + tail.len();
    let mut p = 0usize;
    while p + 8 <= width {
        let (l0, l1, l2, l3) = (l[p], l[p + 1], l[p + 2], l[p + 3]);
        let (l4, l5, l6, l7) = (l[p + 4], l[p + 5], l[p + 6], l[p + 7]);
        if l0 == 0.0
            && l1 == 0.0
            && l2 == 0.0
            && l3 == 0.0
            && l4 == 0.0
            && l5 == 0.0
            && l6 == 0.0
            && l7 == 0.0
        {
            p += 8;
            continue;
        }
        let u0 = view.row(k_base + p, j_base, hi);
        let u1 = view.row(k_base + p + 1, j_base, hi);
        let u2 = view.row(k_base + p + 2, j_base, hi);
        let u3 = view.row(k_base + p + 3, j_base, hi);
        let u4 = view.row(k_base + p + 4, j_base, hi);
        let u5 = view.row(k_base + p + 5, j_base, hi);
        let u6 = view.row(k_base + p + 6, j_base, hi);
        let u7 = view.row(k_base + p + 7, j_base, hi);
        for (j, t) in tail.iter_mut().enumerate() {
            *t -= l0 * u0[j]
                + l1 * u1[j]
                + l2 * u2[j]
                + l3 * u3[j]
                + l4 * u4[j]
                + l5 * u5[j]
                + l6 * u6[j]
                + l7 * u7[j];
        }
        p += 8;
    }
    while p < width {
        let lp = l[p];
        if lp != 0.0 {
            let up = view.row(k_base + p, j_base, hi);
            for (t, &u) in tail.iter_mut().zip(up.iter()) {
                *t -= lp * u;
            }
        }
        p += 1;
    }
}

/// Guarded scatter-AXPY of the sparse numeric sweep: for each stored
/// entry of one dependency `U` row, `acc[cols[q]] -= f * vals[q]`,
/// skipping the diagonal (`cols[q] == diag`, handled separately via
/// `u_diag_pos`) and entries whose stored value is exactly zero (ones
/// the dynamic pattern dropped at emission — the sequential sweep
/// never touched them).
///
/// The emission rule makes this loop's guards and order load-bearing:
/// `SparseSymbolic::assemble` must reproduce `SparseLu::factor`'s
/// structure *and* values bitwise, so every [`Kernel`] variant routes
/// the sparse accumulator through this one scalar-guarded form —
/// kernel choice is accepted for config symmetry and proven inert by
/// `rust/tests/prop_sparse.rs`.
#[inline]
pub fn scatter_axpy(f: f64, cols: &[usize], vals: &[f64], diag: usize, acc: &mut [f64]) {
    for (&c, &v) in cols.iter().zip(vals.iter()) {
        if c == diag {
            continue;
        }
        let v_kept = v != 0.0 && v.abs() > 0.0;
        if !v_kept {
            continue;
        }
        acc[c] -= f * v;
    }
}

/// Flops of one rank-`width` trailing update over `rows` rows and
/// `trailing` columns: one multiply + one subtract per (row, panel
/// column, trailing column). Tiling only partitions that iteration
/// space, so the MC×KC×NR decomposition sums back to exactly this
/// count — which is why `FactorPlan::dense_blocked`'s per-Update-step
/// accounting (`2 · rows · width · trailing`) stays conserved for
/// every kernel and tile size (pinned here and in `ebv::plan`).
pub fn tile_flops(rows: usize, width: usize, trailing: usize) -> u64 {
    2 * rows as u64 * width as u64 * trailing as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill (splitmix-style) — no external
    /// RNG, bit-reproducible across hosts.
    fn fill(buf: &mut [f64], mut seed: u64) {
        for v in buf.iter_mut() {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            *v = ((seed >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0;
        }
    }

    /// Run `kernel` on a fresh copy of `a` (row-major `n × n`) over the
    /// given geometry and return the updated buffer.
    fn run(
        kernel: Kernel,
        a: &[f64],
        n: usize,
        rows: &[usize],
        panel_start: usize,
        panel_end: usize,
    ) -> Vec<f64> {
        let mut m = a.to_vec();
        let view = MatView::from_raw(m.as_mut_ptr(), n);
        // SAFETY: exclusive buffer, disjoint panel/trailing rows,
        // indices in bounds by construction of the tests.
        unsafe { trailing_update(kernel, view, rows, panel_start, panel_end, n) };
        m
    }

    /// Naive reference: independent scalar saxpy per panel column, the
    /// textbook order (componentwise oracle, not bitwise).
    fn reference(a: &[f64], n: usize, rows: &[usize], panel_start: usize, panel_end: usize) -> Vec<f64> {
        let mut m = a.to_vec();
        for &i in rows {
            for p in panel_start..panel_end {
                let l = m[i * n + p];
                for j in panel_end..n {
                    m[i * n + j] -= l * a[p * n + j];
                }
            }
        }
        // The reference reads the original panel rows (`a`), which is
        // fine: trailing_update never writes rows < panel_end either.
        m
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn names_parse_round_trip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::parse(k.name()), Some(k), "{k:?}");
        }
        assert_eq!(Kernel::parse("nope"), None);
        assert_eq!(Kernel::default(), Kernel::Auto);
    }

    #[test]
    fn concrete_kernels_resolve_to_themselves() {
        // Never reads the environment for concrete variants, so this
        // is safe to assert regardless of EBV_KERNEL.
        for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
            assert_eq!(k.resolve(), k);
        }
    }

    #[test]
    fn auto_resolves_via_env_then_tiled() {
        // Serialized env mutation: this is the only test touching
        // EBV_KERNEL (resolve() of concrete kernels never reads it).
        std::env::remove_var("EBV_KERNEL");
        assert_eq!(Kernel::Auto.resolve(), Kernel::Tiled);
        std::env::set_var("EBV_KERNEL", "unroll8");
        assert_eq!(Kernel::Auto.resolve(), Kernel::Unroll8);
        std::env::set_var("EBV_KERNEL", "auto");
        assert_eq!(Kernel::Auto.resolve(), Kernel::Tiled);
        std::env::set_var("EBV_KERNEL", "garbage");
        assert_eq!(Kernel::Auto.resolve(), Kernel::Tiled);
        std::env::remove_var("EBV_KERNEL");
    }

    #[test]
    fn tile_model_constants() {
        // The documented cache-budget formulas, spelled out so a
        // future budget edit shows up as a named failure.
        assert_eq!(NR, 64);
        assert_eq!(MC, 341);
        assert_eq!(KC % 8, 0);
    }

    /// Geometry grid exercising fuse remainders (widths not multiples
    /// of 4/8), multiple KC tiles (width > KC), multiple NR tiles
    /// (trailing > NR) and a sparse row set.
    fn geometries() -> Vec<(usize, usize, usize)> {
        // (n, panel_start, panel_end)
        vec![(24, 0, 5), (40, 8, 16), (96, 10, 13), (180, 16, 16 + KC + 7), (200, 0, 3)]
    }

    #[test]
    fn tiled_is_bitwise_unroll4() {
        for (case, &(n, ps, pe)) in geometries().iter().enumerate() {
            let mut a = vec![0.0f64; n * n];
            fill(&mut a, 0x9E3779B9 + case as u64);
            // A few exact-zero multipliers to exercise the group-skip
            // and scalar-skip paths on both kernels identically.
            for i in (pe..n).step_by(3) {
                a[i * n + ps] = 0.0;
                if pe - ps > 2 {
                    a[i * n + ps + 1] = 0.0;
                }
            }
            let rows: Vec<usize> = (pe..n).filter(|r| r % 5 != 0).collect();
            let u4 = run(Kernel::Unroll4, &a, n, &rows, ps, pe);
            let tiled = run(Kernel::Tiled, &a, n, &rows, ps, pe);
            assert_eq!(bits(&u4), bits(&tiled), "case {case}: tiled must be bitwise unroll4");
        }
    }

    #[test]
    fn every_kernel_matches_the_reference_componentwise() {
        for (case, &(n, ps, pe)) in geometries().iter().enumerate() {
            let mut a = vec![0.0f64; n * n];
            fill(&mut a, 0xC0FFEE + case as u64);
            let rows: Vec<usize> = (pe..n).collect();
            let oracle = reference(&a, n, &rows, ps, pe);
            for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled, Kernel::Auto] {
                let got = run(k, &a, n, &rows, ps, pe);
                let diff = got
                    .iter()
                    .zip(oracle.iter())
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f64, f64::max);
                assert!(diff < 1e-12, "case {case} kernel {k:?}: diff {diff}");
            }
        }
    }

    #[test]
    fn kernels_are_deterministic_run_to_run() {
        let n = 120;
        let (ps, pe) = (8usize, 8 + KC + 3);
        let mut a = vec![0.0f64; n * n];
        fill(&mut a, 42);
        let rows: Vec<usize> = (pe..n).collect();
        for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
            let one = run(k, &a, n, &rows, ps, pe);
            let two = run(k, &a, n, &rows, ps, pe);
            assert_eq!(bits(&one), bits(&two), "{k:?}");
        }
    }

    #[test]
    fn row_partition_never_changes_bits() {
        // Split the row set as a LaneSchedule would (rows are the
        // outer M partition): updating in two disjoint calls must be
        // bitwise identical to one call — for every kernel.
        let n = 150;
        let (ps, pe) = (0usize, 36);
        let mut a = vec![0.0f64; n * n];
        fill(&mut a, 7);
        let rows: Vec<usize> = (pe..n).collect();
        let (lo, hi) = rows.split_at(rows.len() / 3);
        for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
            let whole = run(k, &a, n, &rows, ps, pe);
            let mut m = a.clone();
            let view = MatView::from_raw(m.as_mut_ptr(), n);
            // SAFETY: as in `run`; the two row sets are disjoint.
            unsafe {
                trailing_update(k, view, hi, ps, pe, n);
                trailing_update(k, view, lo, ps, pe, n);
            }
            assert_eq!(bits(&whole), bits(&m), "{k:?}");
        }
    }

    #[test]
    fn column_partition_never_changes_bits() {
        // Split the trailing columns as the dataflow lookahead does
        // (near / far pieces): covering [pe, n) with ranged calls at
        // deliberately NR/KC-misaligned cut points must be bitwise
        // identical to one full call — for every kernel, including
        // cuts landing mid-tile and a degenerate empty range.
        let n = 180;
        let (ps, pe) = (8usize, 8 + KC + 7);
        let mut a = vec![0.0f64; n * n];
        fill(&mut a, 11);
        for i in (pe..n).step_by(4) {
            a[i * n + ps] = 0.0; // exercise zero-skip paths both sides
        }
        let rows: Vec<usize> = (pe..n).filter(|r| r % 7 != 0).collect();
        let cuts = [pe, pe + 5, pe + NR - 1, pe + NR - 1, pe + NR + KC + 3, n];
        for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
            let whole = run(k, &a, n, &rows, ps, pe);
            let mut m = a.clone();
            let view = MatView::from_raw(m.as_mut_ptr(), n);
            for w in cuts.windows(2) {
                // SAFETY: as in `run`; the column ranges are disjoint
                // and all ≥ pe.
                unsafe {
                    trailing_update_cols(k, view, &rows, ps, pe, w[0], w[1]);
                }
            }
            assert_eq!(bits(&whole), bits(&m), "{k:?}: column split must be bit-inert");
        }
    }

    #[test]
    fn degenerate_geometries_are_no_ops() {
        let n = 16;
        let mut a = vec![0.0f64; n * n];
        fill(&mut a, 3);
        for k in [Kernel::Unroll4, Kernel::Unroll8, Kernel::Tiled] {
            // Empty panel, empty trailing block, empty row set.
            assert_eq!(bits(&run(k, &a, n, &[12, 13], 4, 4)), bits(&a), "{k:?} width 0");
            assert_eq!(bits(&run(k, &a, n, &[12], 0, n)), bits(&a), "{k:?} no trailing");
            assert_eq!(bits(&run(k, &a, n, &[], 0, 4)), bits(&a), "{k:?} no rows");
        }
    }

    #[test]
    fn scatter_axpy_applies_guards() {
        let cols = [0usize, 2, 3, 5];
        let vals = [2.0, 0.0, -1.5, 4.0];
        let mut acc = vec![1.0f64; 6];
        // diag = 5 skips the last entry; the exact zero at column 2 is
        // skipped (emission-rule guard); the rest apply.
        scatter_axpy(0.5, &cols, &vals, 5, &mut acc);
        assert_eq!(acc, vec![0.0, 1.0, 1.0, 1.75, 1.0, 1.0]);
        // A zero multiplier still walks the row (the caller guards f,
        // mirroring the sequential sweep's `f_kept` check upstream).
        scatter_axpy(0.0, &cols, &vals, 5, &mut acc);
        assert_eq!(acc, vec![0.0, 1.0, 1.0, 1.75, 1.0, 1.0]);
    }

    #[test]
    fn tile_flops_conserved_under_tiling() {
        // Sum the per-tile counts of the exact MC×KC×NR decomposition
        // trailing_update walks; must equal the untiled total that
        // FactorPlan::dense_blocked accounts per Update step.
        for &(rows, width, trailing) in
            &[(500usize, 64usize, 960usize), (MC + 5, KC + 3, NR + 1), (3, 1, 2)]
        {
            let total = tile_flops(rows, width, trailing);
            let mut summed = 0u64;
            let mut r0 = 0;
            while r0 < rows {
                let r1 = (r0 + MC).min(rows);
                let mut k0 = 0;
                while k0 < width {
                    let k1 = (k0 + KC).min(width);
                    let mut j0 = 0;
                    while j0 < trailing {
                        let j1 = (j0 + NR).min(trailing);
                        summed += tile_flops(r1 - r0, k1 - k0, j1 - j0);
                        j0 = j1;
                    }
                    k0 = k1;
                }
                r0 = r1;
            }
            assert_eq!(summed, total, "rows={rows} width={width} trailing={trailing}");
        }
    }
}
