//! The paper's contribution: **Equal bi-Vectorization**.
//!
//! LU elimination produces, at pivot step `r` of an `n×n` matrix, two
//! vectors (the paper's Eq. 5): the sub-diagonal L-column of length
//! `n-1-r` and the super-diagonal U-row of the same length. Processing
//! the factorization as this stream of `2(n-1)` vectors is
//! **bi-vectorization** ([`bivector`]).
//!
//! Those vectors shrink linearly (`n-1, n-2, …, 1`), so mapping "one
//! vector → one thread" is badly load-imbalanced. The paper's fix —
//! **equalization** — pairs vector `r` with vector `n-2-r` so every work
//! unit has combined length exactly `n` (Eq. 7): `(n-1)/2` equal units
//! per triangle, `n-1` in total ([`equalize`]).
//!
//! [`schedule`] turns the equalized pairing into an executable,
//! dependency-safe lane schedule for the parallel solvers, and
//! [`plan`] derives the op/byte counts the GPU cost model consumes.

pub mod bivector;
pub mod equalize;
pub mod plan;
pub mod schedule;

pub use bivector::{bivectorize, row_total_work, BiVector, Triangle};
pub use equalize::{
    equalize, equalize_hierarchical, equalize_weights, imbalance, max_mean_imbalance,
    PairingMode, WorkUnit,
};
pub use schedule::{LaneSchedule, RowDist};
