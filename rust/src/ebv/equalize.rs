//! Equalization: pairing unequal elimination vectors into equal work units.
//!
//! This is the paper's central idea (Eq. 7): within each triangle, pair
//! vector `r` (length `n-1-r`) with vector `n-2-r` (length `r+1`) so the
//! combined unit always has length `n`. We implement the paper's exact
//! fold pairing plus three comparison strategies used by the ablation
//! bench (`ablation_equalize`).

use crate::ebv::bivector::{BiVector, Triangle};

/// How vectors are grouped into work units.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PairingMode {
    /// The paper's scheme: fold the stream — first with last, second with
    /// second-to-last — within each triangle. Every unit has total length
    /// exactly `n` (odd middle vector stands alone at length ~n/2).
    PaperFold,
    /// Contiguous runs of `k` vectors per unit (the naive mapping the
    /// paper argues against).
    Block,
    /// Round-robin dealing of vectors to units.
    Cyclic,
    /// Greedy longest-processing-time bin packing onto `units` bins —
    /// the "optimal-ish" comparator.
    GreedyLpt,
}

/// A unit of work: one or more bi-vectors processed by a single lane.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkUnit {
    pub items: Vec<BiVector>,
    pub total_len: usize,
}

impl WorkUnit {
    fn new() -> Self {
        WorkUnit { items: Vec::new(), total_len: 0 }
    }

    fn push(&mut self, v: BiVector) {
        self.total_len += v.len;
        self.items.push(v);
    }
}

/// Group `vectors` into `target_units` work units using `mode`.
///
/// For [`PairingMode::PaperFold`] the unit count is derived from the
/// paper's pairing (⌈(n-1)/2⌉ per triangle) and `target_units` only
/// controls the subsequent lane assignment; for the other modes the
/// vectors are packed directly into `target_units` bins.
pub fn equalize(vectors: &[BiVector], mode: PairingMode, target_units: usize) -> Vec<WorkUnit> {
    assert!(target_units > 0, "equalize: target_units must be positive");
    if vectors.is_empty() {
        return Vec::new();
    }
    match mode {
        PairingMode::PaperFold => fold_pairs(vectors),
        PairingMode::Block => block_pack(vectors, target_units),
        PairingMode::Cyclic => cyclic_pack(vectors, target_units),
        PairingMode::GreedyLpt => greedy_lpt(vectors, target_units),
    }
}

/// The paper's fold: within each triangle, pair first with last.
fn fold_pairs(vectors: &[BiVector]) -> Vec<WorkUnit> {
    let mut units = Vec::new();
    for tri in [Triangle::Lower, Triangle::Upper] {
        let tri_vecs: Vec<BiVector> =
            vectors.iter().copied().filter(|v| v.triangle == tri).collect();
        let m = tri_vecs.len();
        for k in 0..m.div_ceil(2) {
            let mut u = WorkUnit::new();
            u.push(tri_vecs[k]);
            let j = m - 1 - k;
            if j != k {
                u.push(tri_vecs[j]);
            }
            units.push(u);
        }
    }
    units
}

fn block_pack(vectors: &[BiVector], bins: usize) -> Vec<WorkUnit> {
    let chunk = vectors.len().div_ceil(bins);
    vectors
        .chunks(chunk)
        .map(|c| {
            let mut u = WorkUnit::new();
            for &v in c {
                u.push(v);
            }
            u
        })
        .collect()
}

fn cyclic_pack(vectors: &[BiVector], bins: usize) -> Vec<WorkUnit> {
    let bins = bins.min(vectors.len());
    let mut units = vec![WorkUnit::new(); bins];
    for (i, &v) in vectors.iter().enumerate() {
        units[i % bins].push(v);
    }
    units
}

fn greedy_lpt(vectors: &[BiVector], bins: usize) -> Vec<WorkUnit> {
    let bins = bins.min(vectors.len());
    let mut sorted: Vec<BiVector> = vectors.to_vec();
    sorted.sort_by(|a, b| b.len.cmp(&a.len));
    let mut units = vec![WorkUnit::new(); bins];
    for v in sorted {
        let target = units
            .iter()
            .enumerate()
            .min_by_key(|(_, u)| u.total_len)
            .map(|(i, _)| i)
            .unwrap();
        units[target].push(v);
    }
    units
}

/// Equalize arbitrary per-item weights over `bins` bins (greedy LPT,
/// deterministic tie-breaking: heavier first, then lower index; ties in
/// bin load go to the lower bin). Returns one *index* list per bin,
/// each sorted ascending, always exactly `bins` lists (possibly empty).
///
/// This is the paper's balance criterion lifted off the dense
/// bi-vector stream and applied to irregular work — the sparse
/// symbolic/numeric split uses it to deal a DAG level's rows to lanes
/// by estimated refactorization cost (`SparseSymbolic` row costs), the
/// sparse counterpart of [`equalize`] on [`BiVector`] lengths.
/// Zero weights count as 1 so empty rows still spread across bins.
pub fn equalize_weights(weights: &[usize], bins: usize) -> Vec<Vec<usize>> {
    assert!(bins > 0, "equalize_weights: bins must be positive");
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(weights[i].max(1)), i));
    let mut out = vec![Vec::new(); bins];
    let mut load = vec![0usize; bins];
    for i in order {
        let b = (0..bins).min_by_key(|&b| load[b]).expect("bins > 0");
        out[b].push(i);
        load[b] += weights[i].max(1);
    }
    for bin in &mut out {
        bin.sort_unstable();
    }
    out
}

/// Hierarchical deal for the two-level device runtime: weights go
/// first to `devices` bins (greedy LPT), then each device's share goes
/// to `lanes` bins (greedy LPT again) — the EBV balance criterion
/// applied at cluster scope and then within a device, matching the
/// paper's "convenient for … multi devices" claim. Returns
/// `out[device][lane]` index lists, each sorted ascending; always
/// exactly `devices × lanes` lists (possibly empty). Fully
/// deterministic (inherits [`equalize_weights`]'s tie-breaking).
pub fn equalize_hierarchical(
    weights: &[usize],
    devices: usize,
    lanes: usize,
) -> Vec<Vec<Vec<usize>>> {
    assert!(devices > 0, "equalize_hierarchical: devices must be positive");
    assert!(lanes > 0, "equalize_hierarchical: lanes must be positive");
    equalize_weights(weights, devices)
        .into_iter()
        .map(|dev_items| {
            let dev_weights: Vec<usize> = dev_items.iter().map(|&i| weights[i]).collect();
            equalize_weights(&dev_weights, lanes)
                .into_iter()
                .map(|bin| bin.into_iter().map(|k| dev_items[k]).collect())
                .collect()
        })
        .collect()
}

/// `max / mean` of a load vector — **the** balance metric of the repo
/// (1.0 is perfect), shared by the pairing-level [`imbalance`], the
/// schedule-level `LaneSchedule::work_imbalance`, the per-device stats
/// of the sharded runtime and the cost-model plans. Empty or all-zero
/// loads read as perfectly balanced.
pub fn max_mean_imbalance(loads: &[usize]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let max = *loads.iter().max().expect("non-empty") as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Load imbalance of a unit set: `max(total_len) / mean(total_len)`.
/// 1.0 is perfect balance; the paper's fold achieves exactly 1.0 for
/// even `n-1`.
pub fn imbalance(units: &[WorkUnit]) -> f64 {
    let loads: Vec<usize> = units.iter().map(|u| u.total_len).collect();
    max_mean_imbalance(&loads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ebv::bivector::bivectorize;

    fn total_len(units: &[WorkUnit]) -> usize {
        units.iter().map(|u| u.total_len).sum()
    }

    #[test]
    fn fold_units_have_equal_length_for_odd_n() {
        // n=9 -> 8 vectors per triangle -> 4 exact pairs of length 9.
        let n = 9;
        let units = equalize(&bivectorize(n), PairingMode::PaperFold, 4);
        assert_eq!(units.len(), 8); // 4 per triangle
        assert!(units.iter().all(|u| u.total_len == n), "{units:?}");
        assert_eq!(imbalance(&units), 1.0);
    }

    #[test]
    fn fold_middle_vector_stands_alone_for_even_n() {
        // n=8 -> 7 vectors per triangle -> 3 pairs of length 8 + middle (len 4).
        let n = 8;
        let units = equalize(&bivectorize(n), PairingMode::PaperFold, 4);
        assert_eq!(units.len(), 8);
        let lens: Vec<usize> = units.iter().map(|u| u.total_len).collect();
        assert_eq!(lens.iter().filter(|&&l| l == n).count(), 6);
        assert_eq!(lens.iter().filter(|&&l| l == n / 2).count(), 2);
    }

    #[test]
    fn all_modes_conserve_total_work() {
        let vs = bivectorize(17);
        let total: usize = vs.iter().map(|v| v.len).sum();
        for mode in
            [PairingMode::PaperFold, PairingMode::Block, PairingMode::Cyclic, PairingMode::GreedyLpt]
        {
            let units = equalize(&vs, mode, 4);
            assert_eq!(total_len(&units), total, "{mode:?}");
            // Every vector appears exactly once.
            let count: usize = units.iter().map(|u| u.items.len()).sum();
            assert_eq!(count, vs.len(), "{mode:?}");
        }
    }

    #[test]
    fn fold_beats_block_on_imbalance() {
        let vs = bivectorize(64);
        let fold = imbalance(&equalize(&vs, PairingMode::PaperFold, 8));
        let block = imbalance(&equalize(&vs, PairingMode::Block, 8));
        assert!(fold < block, "fold={fold} block={block}");
        assert!(fold <= 1.04, "fold imbalance should be ~1, got {fold}");
    }

    #[test]
    fn greedy_lpt_is_near_perfect() {
        let vs = bivectorize(33);
        let lpt = imbalance(&equalize(&vs, PairingMode::GreedyLpt, 4));
        assert!(lpt < 1.05, "lpt={lpt}");
    }

    #[test]
    fn cyclic_is_reasonable() {
        let vs = bivectorize(64);
        let cyc = imbalance(&equalize(&vs, PairingMode::Cyclic, 8));
        assert!(cyc < 1.2, "cyclic={cyc}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(equalize(&[], PairingMode::PaperFold, 4).is_empty());
        let vs = bivectorize(2); // one vector per triangle
        let units = equalize(&vs, PairingMode::PaperFold, 4);
        assert_eq!(units.len(), 2);
        assert_eq!(units[0].total_len, 1);
    }

    #[test]
    #[should_panic(expected = "target_units")]
    fn zero_units_panics() {
        equalize(&bivectorize(4), PairingMode::Block, 0);
    }

    #[test]
    fn weights_partition_all_indices() {
        let weights: Vec<usize> = (0..37).map(|i| (i * 7 + 3) % 11).collect();
        let bins = equalize_weights(&weights, 4);
        assert_eq!(bins.len(), 4);
        let mut all: Vec<usize> = bins.concat();
        all.sort_unstable();
        assert_eq!(all, (0..37).collect::<Vec<_>>());
        for bin in &bins {
            assert!(bin.windows(2).all(|w| w[0] < w[1]), "bins sorted ascending");
        }
    }

    #[test]
    fn weights_balance_is_near_perfect() {
        let weights: Vec<usize> = (1..=64).collect();
        let bins = equalize_weights(&weights, 4);
        let loads: Vec<usize> =
            bins.iter().map(|b| b.iter().map(|&i| weights[i]).sum()).collect();
        let max = *loads.iter().max().unwrap() as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
        assert!(max / mean < 1.05, "loads={loads:?}");
    }

    #[test]
    fn weights_are_deterministic_and_handle_edges() {
        let weights = vec![5usize, 5, 5, 0, 0];
        assert_eq!(equalize_weights(&weights, 3), equalize_weights(&weights, 3));
        // More bins than items leaves trailing bins empty, never drops.
        let bins = equalize_weights(&[2usize], 4);
        assert_eq!(bins.len(), 4);
        assert_eq!(bins[0], vec![0]);
        assert!(bins[1..].iter().all(Vec::is_empty));
        assert_eq!(equalize_weights(&[], 2), vec![Vec::new(), Vec::new()]);
    }

    #[test]
    #[should_panic(expected = "bins")]
    fn zero_bins_panics() {
        equalize_weights(&[1, 2], 0);
    }

    #[test]
    fn hierarchical_partitions_all_indices() {
        let weights: Vec<usize> = (0..53).map(|i| (i * 13 + 5) % 17).collect();
        let deal = equalize_hierarchical(&weights, 3, 4);
        assert_eq!(deal.len(), 3);
        assert!(deal.iter().all(|d| d.len() == 4));
        let mut all: Vec<usize> = deal.iter().flatten().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..53).collect::<Vec<_>>());
        for lane in deal.iter().flatten() {
            assert!(lane.windows(2).all(|w| w[0] < w[1]), "lanes sorted ascending");
        }
    }

    #[test]
    fn hierarchical_balances_both_levels() {
        let weights: Vec<usize> = (1..=96).collect();
        let deal = equalize_hierarchical(&weights, 4, 2);
        let device_loads: Vec<usize> = deal
            .iter()
            .map(|d| d.iter().flatten().map(|&i| weights[i]).sum())
            .collect();
        assert!(max_mean_imbalance(&device_loads) < 1.05, "{device_loads:?}");
        let lane_loads: Vec<usize> = deal
            .iter()
            .flatten()
            .map(|lane| lane.iter().map(|&i| weights[i]).sum())
            .collect();
        assert!(max_mean_imbalance(&lane_loads) < 1.1, "{lane_loads:?}");
    }

    #[test]
    fn hierarchical_is_deterministic_and_degenerates() {
        let weights = vec![7usize, 7, 3, 3, 1];
        assert_eq!(
            equalize_hierarchical(&weights, 2, 3),
            equalize_hierarchical(&weights, 2, 3)
        );
        // One device degenerates to the flat deal.
        let flat = equalize_weights(&weights, 3);
        assert_eq!(equalize_hierarchical(&weights, 1, 3), vec![flat]);
    }

    #[test]
    fn max_mean_imbalance_matches_unit_imbalance() {
        let vs = bivectorize(33);
        for mode in [PairingMode::PaperFold, PairingMode::Block, PairingMode::GreedyLpt] {
            let units = equalize(&vs, mode, 4);
            let loads: Vec<usize> = units.iter().map(|u| u.total_len).collect();
            assert_eq!(imbalance(&units), max_mean_imbalance(&loads), "{mode:?}");
        }
        assert_eq!(max_mean_imbalance(&[]), 1.0);
        assert_eq!(max_mean_imbalance(&[0, 0]), 1.0);
        assert_eq!(max_mean_imbalance(&[4, 2]), 4.0 / 3.0);
    }
}
