//! Executable lane schedules derived from the equalized decomposition.
//!
//! Elimination steps are sequential (step `r+1` reads step `r`'s trailing
//! update), so parallelism lives *within* a step: the updated rows are
//! partitioned across lanes. The partition is **static** (ownership fixed
//! for the whole factorization — no per-step repartitioning traffic,
//! matching the paper's "first contribution, then decomposition"), and
//! the ownership pattern is where equalization enters:
//!
//! * [`RowDist::Block`] — contiguous chunks. Badly imbalanced: early
//!   rows retire early, so the first lane idles for most of the run.
//! * [`RowDist::Cyclic`] — round-robin. The classic balanced choice.
//! * [`RowDist::EbvFold`] — the paper's equalization: row `i` is paired
//!   with row `n-1-i` (first-with-last), and pairs are dealt to lanes;
//!   each pair's total elimination work is near-constant, so lanes get
//!   equal totals.
//! * [`RowDist::GreedyLpt`] — greedy packing on exact per-row work
//!   ([`row_total_work`]): the "optimal-ish" comparator.

use crate::ebv::bivector::row_total_work;

/// Static row-ownership strategy for the parallel elimination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowDist {
    Block,
    Cyclic,
    /// The paper's equal-bi-vectorized fold pairing.
    EbvFold,
    GreedyLpt,
}

impl RowDist {
    pub const ALL: [RowDist; 4] =
        [RowDist::Block, RowDist::Cyclic, RowDist::EbvFold, RowDist::GreedyLpt];

    pub fn name(&self) -> &'static str {
        match self {
            RowDist::Block => "block",
            RowDist::Cyclic => "cyclic",
            RowDist::EbvFold => "ebv-fold",
            RowDist::GreedyLpt => "greedy-lpt",
        }
    }

    /// Parse a config/CLI strategy name (the inverse of [`RowDist::name`]).
    pub fn parse(name: &str) -> Option<RowDist> {
        RowDist::ALL.into_iter().find(|d| d.name() == name)
    }
}

/// A static assignment of matrix rows to `lanes` worker lanes —
/// optionally a **two-level** assignment where the lanes are grouped
/// into device shards (see [`LaneSchedule::build_sharded`]): global
/// lane `g` belongs to device `g / lanes_per_device`.
#[derive(Debug, Clone)]
pub struct LaneSchedule {
    n: usize,
    lanes: usize,
    /// Device shards the lanes are grouped into (1 for flat builds).
    devices: usize,
    /// Lanes per device shard (= `lanes` for flat builds).
    lanes_per_device: usize,
    /// `owner[i]` = (global) lane that owns row `i`.
    owner: Vec<usize>,
    /// `rows[l]` = sorted rows owned by lane `l`.
    rows: Vec<Vec<usize>>,
}

/// Assign `rows_in` (ascending) to `lanes` local lanes with `dist`,
/// writing `lane_base + local` into `owner`. The flat build passes the
/// identity row list; the sharded build passes each device's share, so
/// the distribution patterns apply *within* a device exactly as they
/// apply to the whole matrix in the flat case.
fn deal_rows(rows_in: &[usize], lanes: usize, n: usize, dist: RowDist, lane_base: usize, owner: &mut [usize]) {
    let m = rows_in.len();
    match dist {
        RowDist::Block => {
            let chunk = m.div_ceil(lanes);
            for (k, &i) in rows_in.iter().enumerate() {
                owner[i] = lane_base + (k / chunk.max(1)).min(lanes - 1);
            }
        }
        RowDist::Cyclic => {
            for (k, &i) in rows_in.iter().enumerate() {
                owner[i] = lane_base + k % lanes;
            }
        }
        RowDist::EbvFold => {
            // Deal fold pairs (first, last) round-robin to lanes: pair k
            // goes to lane k % lanes; both members share the lane.
            let mut k = 0usize;
            let (mut lo, mut hi) = (0usize, m.saturating_sub(1));
            while lo < hi {
                owner[rows_in[lo]] = lane_base + k % lanes;
                owner[rows_in[hi]] = lane_base + k % lanes;
                k += 1;
                lo += 1;
                hi -= 1;
            }
            if lo == hi && m > 0 {
                owner[rows_in[lo]] = lane_base + k % lanes;
            }
        }
        RowDist::GreedyLpt => {
            // Exact per-row elimination work, largest-first, onto the
            // least-loaded lane.
            let mut idx: Vec<usize> = rows_in.to_vec();
            idx.sort_by_key(|&i| std::cmp::Reverse(row_total_work(i, n)));
            let mut load = vec![0usize; lanes];
            for i in idx {
                let lane = (0..lanes).min_by_key(|&l| load[l]).expect("lanes > 0");
                owner[i] = lane_base + lane;
                load[lane] += row_total_work(i, n);
            }
        }
    }
}

impl LaneSchedule {
    /// Build the ownership map for an `n×n` elimination on `lanes` lanes.
    pub fn build(n: usize, lanes: usize, dist: RowDist) -> LaneSchedule {
        assert!(lanes > 0, "LaneSchedule: lanes must be positive");
        let mut owner = vec![0usize; n];
        let all: Vec<usize> = (0..n).collect();
        deal_rows(&all, lanes, n, dist, 0, &mut owner);
        let mut rows = vec![Vec::new(); lanes];
        for (i, &o) in owner.iter().enumerate() {
            rows[o].push(i);
        }
        LaneSchedule { n, lanes, devices: 1, lanes_per_device: lanes, owner, rows }
    }

    /// Build a **two-level** ownership map for the device-sharded
    /// runtime: rows are first dealt to `devices` shards by greedy LPT
    /// over exact per-row elimination work (the EBV balance criterion
    /// at cluster scope — deterministic, heavier rows first), then each
    /// device's share is dealt to its `lanes_per_device` lanes with
    /// `dist`, exactly as the flat build deals the whole matrix. Global
    /// lane ids are device-major: device `d` owns lanes
    /// `d*lanes_per_device .. (d+1)*lanes_per_device`.
    ///
    /// `build_sharded(n, 1, lanes, dist)` is identical to
    /// `build(n, lanes, dist)` (one device's share is every row).
    pub fn build_sharded(
        n: usize,
        devices: usize,
        lanes_per_device: usize,
        dist: RowDist,
    ) -> LaneSchedule {
        assert!(devices > 0, "LaneSchedule: devices must be positive");
        assert!(lanes_per_device > 0, "LaneSchedule: lanes_per_device must be positive");
        let weights: Vec<usize> = (0..n).map(|i| row_total_work(i, n)).collect();
        let shards = crate::ebv::equalize::equalize_weights(&weights, devices);
        let mut owner = vec![0usize; n];
        for (d, shard) in shards.iter().enumerate() {
            deal_rows(shard, lanes_per_device, n, dist, d * lanes_per_device, &mut owner);
        }
        let lanes = devices * lanes_per_device;
        let mut rows = vec![Vec::new(); lanes];
        for (i, &o) in owner.iter().enumerate() {
            rows[o].push(i);
        }
        LaneSchedule { n, lanes, devices, lanes_per_device, owner, rows }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Device shards the lanes are grouped into (1 for flat builds).
    #[inline]
    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Lanes per device shard (= [`LaneSchedule::lanes`] for flat builds).
    #[inline]
    pub fn lanes_per_device(&self) -> usize {
        self.lanes_per_device
    }

    /// Device owning global lane `l`.
    #[inline]
    pub fn device_of_lane(&self, l: usize) -> usize {
        l / self.lanes_per_device
    }

    /// Device owning row `i`.
    #[inline]
    pub fn device_of_row(&self, i: usize) -> usize {
        self.device_of_lane(self.owner[i])
    }

    /// Lane owning row `i`.
    #[inline]
    pub fn owner(&self, i: usize) -> usize {
        self.owner[i]
    }

    /// Rows owned by lane `l` (sorted ascending).
    #[inline]
    pub fn rows_of(&self, l: usize) -> &[usize] {
        &self.rows[l]
    }

    /// Rows owned by lane `l` that are strictly below pivot `r`
    /// (the active set during elimination step `r`).
    pub fn active_rows_of(&self, l: usize, r: usize) -> &[usize] {
        let rows = &self.rows[l];
        let start = rows.partition_point(|&i| i <= r);
        &rows[start..]
    }

    /// Rows owned by lane `l` at or below row `start` — the active set
    /// of a blocked trailing-update step whose panel ends at `start`
    /// (every row past the panel absorbs the panel's rank-`nb` update).
    pub fn rows_from(&self, l: usize, start: usize) -> &[usize] {
        let rows = &self.rows[l];
        &rows[rows.partition_point(|&i| i < start)..]
    }

    /// Rows owned by lane `l` that are strictly above pivot `j`
    /// (the active set during a backward-substitution column step).
    pub fn upper_rows_of(&self, l: usize, j: usize) -> &[usize] {
        let rows = &self.rows[l];
        let end = rows.partition_point(|&i| i < j);
        &rows[..end]
    }

    /// Total elimination work assigned to each lane.
    pub fn lane_work(&self) -> Vec<usize> {
        let mut w = vec![0usize; self.lanes];
        for (i, &o) in self.owner.iter().enumerate() {
            w[o] += row_total_work(i, self.n);
        }
        w
    }

    /// `max / mean` of per-lane work — the schedule-level balance
    /// metric (the shared [`max_mean_imbalance`] formula).
    ///
    /// [`max_mean_imbalance`]: crate::ebv::equalize::max_mean_imbalance
    pub fn work_imbalance(&self) -> f64 {
        crate::ebv::equalize::max_mean_imbalance(&self.lane_work())
    }

    /// Total elimination work assigned to each device shard (the
    /// per-lane totals folded by device).
    pub fn device_work(&self) -> Vec<usize> {
        let mut w = vec![0usize; self.devices];
        for (l, lw) in self.lane_work().into_iter().enumerate() {
            w[self.device_of_lane(l)] += lw;
        }
        w
    }

    /// `max / mean` of per-device work — the cluster-level balance
    /// metric (same shared formula as [`LaneSchedule::work_imbalance`]).
    pub fn device_imbalance(&self) -> f64 {
        crate::ebv::equalize::max_mean_imbalance(&self.device_work())
    }
}

/// Panel decomposition of an `n`-column elimination into `nb`-wide
/// panels: consecutive `(start, end)` column ranges covering `0..n`.
/// The blocked factorization builds its equalized update vectors per
/// panel from these ranges instead of per column; `nb = 1` degenerates
/// to the column-at-a-time decomposition.
pub fn panels(n: usize, nb: usize) -> Vec<(usize, usize)> {
    assert!(nb > 0, "panels: panel width must be positive");
    let mut out = Vec::with_capacity(n.div_ceil(nb));
    let mut k = 0usize;
    while k < n {
        let end = (k + nb).min(n);
        out.push((k, end));
        k = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_partition(s: &LaneSchedule) {
        // Every row owned exactly once; rows_of is consistent with owner.
        let mut seen = vec![false; s.n()];
        for l in 0..s.lanes() {
            for &i in s.rows_of(l) {
                assert!(!seen[i], "row {i} owned twice");
                seen[i] = true;
                assert_eq!(s.owner(i), l);
            }
        }
        assert!(seen.into_iter().all(|b| b), "not all rows owned");
    }

    #[test]
    fn all_dists_are_valid_partitions() {
        for dist in RowDist::ALL {
            for (n, lanes) in [(1usize, 1usize), (7, 3), (16, 4), (33, 5), (100, 8)] {
                let s = LaneSchedule::build(n, lanes, dist);
                check_partition(&s);
            }
        }
    }

    #[test]
    fn block_layout() {
        let s = LaneSchedule::build(8, 2, RowDist::Block);
        assert_eq!(s.rows_of(0), &[0, 1, 2, 3]);
        assert_eq!(s.rows_of(1), &[4, 5, 6, 7]);
    }

    #[test]
    fn cyclic_layout() {
        let s = LaneSchedule::build(6, 3, RowDist::Cyclic);
        assert_eq!(s.rows_of(0), &[0, 3]);
        assert_eq!(s.rows_of(2), &[2, 5]);
    }

    #[test]
    fn fold_pairs_first_with_last() {
        let s = LaneSchedule::build(8, 4, RowDist::EbvFold);
        // pair 0 = (0,7) -> lane 0; pair 1 = (1,6) -> lane 1; etc.
        assert_eq!(s.owner(0), s.owner(7));
        assert_eq!(s.owner(1), s.owner(6));
        assert_eq!(s.owner(2), s.owner(5));
        assert_eq!(s.owner(3), s.owner(4));
        assert_ne!(s.owner(0), s.owner(1));
    }

    #[test]
    fn fold_handles_odd_n() {
        let s = LaneSchedule::build(7, 2, RowDist::EbvFold);
        check_partition(&s);
        assert_eq!(s.owner(0), s.owner(6));
    }

    #[test]
    fn ebv_fold_beats_block_on_work_balance() {
        for (n, lanes) in [(64usize, 4usize), (256, 8), (1000, 6)] {
            let fold = LaneSchedule::build(n, lanes, RowDist::EbvFold).work_imbalance();
            let block = LaneSchedule::build(n, lanes, RowDist::Block).work_imbalance();
            assert!(
                fold < block,
                "n={n} lanes={lanes}: fold={fold:.3} block={block:.3}"
            );
            assert!(fold < 1.1, "n={n} lanes={lanes}: fold imbalance {fold:.3}");
        }
    }

    #[test]
    fn greedy_lpt_is_best_or_tied() {
        let n = 128;
        let lanes = 4;
        let lpt = LaneSchedule::build(n, lanes, RowDist::GreedyLpt).work_imbalance();
        for dist in RowDist::ALL {
            let other = LaneSchedule::build(n, lanes, dist).work_imbalance();
            assert!(lpt <= other + 1e-9, "{dist:?}: lpt={lpt} other={other}");
        }
    }

    #[test]
    fn active_rows_shrink_as_pivot_advances() {
        let s = LaneSchedule::build(8, 2, RowDist::Cyclic);
        // Lane 0 owns {0,2,4,6}. After pivot 3, active = {4,6}.
        assert_eq!(s.active_rows_of(0, 3), &[4, 6]);
        assert_eq!(s.active_rows_of(0, 6), &[] as &[usize]);
        // All rows active before step 0 except row 0 itself.
        assert_eq!(s.active_rows_of(0, 0), &[2, 4, 6]);
    }

    #[test]
    fn upper_rows_mirror_active_rows() {
        let s = LaneSchedule::build(8, 2, RowDist::Cyclic);
        // Lane 0 owns {0,2,4,6}; strictly above pivot 5 -> {0, 2, 4}.
        assert_eq!(s.upper_rows_of(0, 5), &[0, 2, 4]);
        assert_eq!(s.upper_rows_of(0, 0), &[] as &[usize]);
        // Together, upper + owner-or-below cover the lane's rows.
        for l in 0..2 {
            for j in 0..8 {
                let upper = s.upper_rows_of(l, j).len();
                let lower = s.active_rows_of(l, j).len();
                let at_j = usize::from(s.owner(j) == l);
                assert_eq!(upper + lower + at_j, s.rows_of(l).len(), "l={l} j={j}");
            }
        }
    }

    #[test]
    fn rows_from_is_the_at_or_below_set() {
        let s = LaneSchedule::build(8, 2, RowDist::Cyclic);
        // Lane 0 owns {0,2,4,6}.
        assert_eq!(s.rows_from(0, 0), &[0, 2, 4, 6]);
        assert_eq!(s.rows_from(0, 3), &[4, 6]);
        assert_eq!(s.rows_from(0, 4), &[4, 6]);
        assert_eq!(s.rows_from(0, 7), &[] as &[usize]);
        // rows_from(l, r + 1) == active_rows_of(l, r) for every (l, r).
        for l in 0..2 {
            for r in 0..8 {
                assert_eq!(s.rows_from(l, r + 1), s.active_rows_of(l, r), "l={l} r={r}");
            }
        }
    }

    #[test]
    fn panels_cover_all_columns_contiguously() {
        for (n, nb) in [(1usize, 1usize), (7, 3), (8, 4), (64, 64), (10, 256), (100, 1)] {
            let ps = panels(n, nb);
            assert_eq!(ps.len(), n.div_ceil(nb), "n={n} nb={nb}");
            let mut expect_start = 0usize;
            for &(k, end) in &ps {
                assert_eq!(k, expect_start, "n={n} nb={nb}");
                assert!(end > k && end - k <= nb, "n={n} nb={nb}");
                expect_start = end;
            }
            assert_eq!(expect_start, n, "n={n} nb={nb}");
        }
        assert!(panels(0, 8).is_empty());
    }

    #[test]
    #[should_panic(expected = "panel width")]
    fn zero_panel_width_panics() {
        panels(8, 0);
    }

    #[test]
    fn dist_names_round_trip() {
        for dist in RowDist::ALL {
            assert_eq!(RowDist::parse(dist.name()), Some(dist));
        }
        assert_eq!(RowDist::parse("zigzag"), None);
    }

    #[test]
    fn lane_work_sums_to_total() {
        let n = 50;
        let total: usize = (0..n).map(|i| row_total_work(i, n)).sum();
        for dist in RowDist::ALL {
            let s = LaneSchedule::build(n, 4, dist);
            assert_eq!(s.lane_work().iter().sum::<usize>(), total);
        }
    }

    #[test]
    fn more_lanes_than_rows_is_fine() {
        for dist in RowDist::ALL {
            let s = LaneSchedule::build(3, 8, dist);
            check_partition(&s);
        }
    }

    #[test]
    fn flat_build_reports_one_device() {
        let s = LaneSchedule::build(16, 4, RowDist::EbvFold);
        assert_eq!(s.devices(), 1);
        assert_eq!(s.lanes_per_device(), 4);
        assert_eq!(s.device_work(), vec![s.lane_work().iter().sum::<usize>()]);
        assert_eq!(s.device_imbalance(), 1.0);
    }

    #[test]
    fn sharded_build_is_a_valid_partition_with_device_major_lanes() {
        for dist in RowDist::ALL {
            for (n, devices, lpd) in [(1usize, 2usize, 2usize), (17, 2, 3), (64, 4, 2), (33, 3, 5)]
            {
                let s = LaneSchedule::build_sharded(n, devices, lpd, dist);
                check_partition(&s);
                assert_eq!(s.lanes(), devices * lpd, "{dist:?} n={n}");
                assert_eq!(s.devices(), devices);
                assert_eq!(s.lanes_per_device(), lpd);
                // Global lanes are device-major and rows agree with
                // their owning lane's device.
                for i in 0..n {
                    assert_eq!(s.device_of_row(i), s.owner(i) / lpd, "{dist:?} n={n} row={i}");
                }
            }
        }
    }

    #[test]
    fn sharded_one_device_equals_flat_build() {
        for dist in RowDist::ALL {
            for (n, lanes) in [(8usize, 2usize), (33, 5), (100, 8)] {
                let flat = LaneSchedule::build(n, lanes, dist);
                let sharded = LaneSchedule::build_sharded(n, 1, lanes, dist);
                assert_eq!(sharded.owner, flat.owner, "{dist:?} n={n} lanes={lanes}");
            }
        }
    }

    #[test]
    fn sharded_devices_are_work_balanced() {
        for devices in [2usize, 4] {
            let s = LaneSchedule::build_sharded(256, devices, 4, RowDist::EbvFold);
            let imb = s.device_imbalance();
            assert!(imb < 1.02, "devices={devices}: device imbalance {imb:.4}");
            assert_eq!(s.device_work().len(), devices);
            // Devices partition the total work.
            let total: usize = (0..256).map(|i| row_total_work(i, 256)).sum();
            assert_eq!(s.device_work().iter().sum::<usize>(), total);
        }
    }
}
