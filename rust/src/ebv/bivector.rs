//! Bi-vectorization: the factorization as a stream of elimination vectors.

/// Which triangular factor a vector belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Triangle {
    /// Sub-diagonal column of `L` at a pivot step.
    Lower,
    /// Super-diagonal row of `U` at a pivot step.
    Upper,
}

/// One elimination vector — the unit of the paper's "bi-vectorized"
/// decomposition (Eq. 5). At 0-based pivot step `r` of an `n×n` matrix:
///
/// * the `Lower` vector is `A[r+1..n, r]` (the multipliers), and
/// * the `Upper` vector is `A[r, r+1..n]` (the pivot row tail),
///
/// both of length `n - 1 - r`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BiVector {
    pub triangle: Triangle,
    /// 0-based pivot step this vector belongs to.
    pub step: usize,
    /// Vector length `n - 1 - step`.
    pub len: usize,
}

impl BiVector {
    pub fn lower(step: usize, n: usize) -> BiVector {
        debug_assert!(step < n);
        BiVector { triangle: Triangle::Lower, step, len: n - 1 - step }
    }

    pub fn upper(step: usize, n: usize) -> BiVector {
        debug_assert!(step < n);
        BiVector { triangle: Triangle::Upper, step, len: n - 1 - step }
    }
}

/// The full bi-vectorized stream for an `n×n` factorization, in the
/// paper's Eq. (4-a) order: `L(1) … L(n-1)` then `U(1) … U(n-1)`.
/// `2(n-1)` vectors with total length `n(n-1)`.
pub fn bivectorize(n: usize) -> Vec<BiVector> {
    let mut out = Vec::with_capacity(2 * n.saturating_sub(1));
    for r in 0..n.saturating_sub(1) {
        out.push(BiVector::lower(r, n));
    }
    for r in 0..n.saturating_sub(1) {
        out.push(BiVector::upper(r, n));
    }
    out
}

/// Total elimination work attributed to row `i` across the whole
/// factorization under static row ownership: row `i` is an *updated* row
/// at every step `r < i`, and each update touches `n - r` trailing
/// elements (1 multiplier + `n-1-r` row entries). This is the quantity
/// the equalized row distribution balances across lanes.
pub fn row_total_work(i: usize, n: usize) -> usize {
    // sum_{r=0}^{i-1} (n - r) = i*n - i*(i-1)/2
    // (`saturating_sub` keeps the i = 0 case from underflowing before
    // the multiply-by-zero saves it — caught by debug overflow checks.)
    i * n - i * i.saturating_sub(1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_has_2n_minus_2_vectors() {
        let vs = bivectorize(8);
        assert_eq!(vs.len(), 14);
        assert!(vs[..7].iter().all(|v| v.triangle == Triangle::Lower));
        assert!(vs[7..].iter().all(|v| v.triangle == Triangle::Upper));
    }

    #[test]
    fn lengths_shrink_linearly() {
        let vs = bivectorize(6);
        let lower_lens: Vec<usize> =
            vs.iter().filter(|v| v.triangle == Triangle::Lower).map(|v| v.len).collect();
        assert_eq!(lower_lens, vec![5, 4, 3, 2, 1]);
    }

    #[test]
    fn total_length_is_n_times_n_minus_1() {
        for n in [2usize, 5, 16, 33] {
            let total: usize = bivectorize(n).iter().map(|v| v.len).sum();
            assert_eq!(total, n * (n - 1));
        }
    }

    #[test]
    fn degenerate_sizes() {
        assert!(bivectorize(0).is_empty());
        assert!(bivectorize(1).is_empty());
    }

    #[test]
    fn row_work_is_monotone_and_closed_form() {
        let n = 10;
        // Recompute by direct summation.
        for i in 0..n {
            let direct: usize = (0..i).map(|r| n - r).sum();
            assert_eq!(row_total_work(i, n), direct);
        }
        assert!(row_total_work(9, n) > row_total_work(1, n));
        assert_eq!(row_total_work(0, n), 0);
    }
}
