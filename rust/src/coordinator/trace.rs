//! Request-trace recording and replay.
//!
//! Production solver services are tuned against recorded traffic; this
//! module persists a workload trace (and the per-request outcomes of a
//! run) as JSON so benchmark campaigns are reproducible and shareable.
//! `ablation_batch`-style experiments can be replayed bit-identically
//! from a file instead of regenerating from a seed.

use std::path::Path;

use crate::util::error::{EbvError, Result};
use crate::util::json::Json;
use crate::workload::{Job, SystemKind};

/// One recorded outcome (subset of `SolveResponse` that is stable
/// across runs).
#[derive(Debug, Clone, PartialEq)]
pub struct RecordedOutcome {
    pub id: u64,
    pub ok: bool,
    pub backend: String,
    pub batch_size: usize,
    pub residual: f64,
    pub total_secs: f64,
}

/// A persisted trace: the jobs plus (optionally) one run's outcomes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub jobs: Vec<Job>,
    pub outcomes: Vec<RecordedOutcome>,
}

fn kind_str(k: SystemKind) -> &'static str {
    match k {
        SystemKind::Dense => "dense",
        SystemKind::Sparse => "sparse",
        SystemKind::Poisson => "poisson",
    }
}

fn kind_parse(s: &str) -> Result<SystemKind> {
    match s {
        "dense" => Ok(SystemKind::Dense),
        "sparse" => Ok(SystemKind::Sparse),
        "poisson" => Ok(SystemKind::Poisson),
        other => Err(EbvError::Json(format!("unknown system kind `{other}`"))),
    }
}

impl Trace {
    pub fn from_jobs(jobs: Vec<Job>) -> Trace {
        Trace { jobs, outcomes: Vec::new() }
    }

    pub fn record(&mut self, o: RecordedOutcome) {
        self.outcomes.push(o);
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("version", Json::from(1usize)),
            (
                "jobs",
                Json::arr(self.jobs.iter().map(|j| {
                    Json::obj([
                        ("id", Json::from(j.id as usize)),
                        ("arrival", Json::from(j.arrival)),
                        ("kind", Json::from(kind_str(j.kind))),
                        ("n", Json::from(j.n)),
                        // u64 seeds exceed f64's 53-bit integer range;
                        // persist as a decimal string.
                        ("seed", Json::from(j.seed.to_string())),
                    ])
                })),
            ),
            (
                "outcomes",
                Json::arr(self.outcomes.iter().map(|o| {
                    Json::obj([
                        ("id", Json::from(o.id as usize)),
                        ("ok", Json::from(o.ok)),
                        ("backend", Json::from(o.backend.clone())),
                        ("batch_size", Json::from(o.batch_size)),
                        ("residual", Json::from(o.residual)),
                        ("total_secs", Json::from(o.total_secs)),
                    ])
                })),
            ),
        ])
    }

    /// Parse from JSON.
    pub fn from_json(v: &Json) -> Result<Trace> {
        let version = v.require("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(EbvError::Json(format!("unsupported trace version {version}")));
        }
        let jobs = v
            .require("jobs")?
            .as_arr()
            .ok_or_else(|| EbvError::Json("jobs must be an array".into()))?
            .iter()
            .map(|j| {
                Ok(Job {
                    id: j.require("id")?.as_usize().unwrap_or(0) as u64,
                    arrival: j.require("arrival")?.as_f64().unwrap_or(0.0),
                    kind: kind_parse(
                        j.require("kind")?
                            .as_str()
                            .ok_or_else(|| EbvError::Json("kind must be a string".into()))?,
                    )?,
                    n: j.require("n")?.as_usize().unwrap_or(0),
                    seed: j
                        .require("seed")?
                        .as_str()
                        .ok_or_else(|| EbvError::Json("seed must be a string".into()))?
                        .parse::<u64>()
                        .map_err(|_| EbvError::Json("seed must be a u64 string".into()))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let outcomes = match v.get("outcomes").and_then(Json::as_arr) {
            None => Vec::new(),
            Some(arr) => arr
                .iter()
                .map(|o| {
                    Ok(RecordedOutcome {
                        id: o.require("id")?.as_usize().unwrap_or(0) as u64,
                        ok: o.require("ok")?.as_bool().unwrap_or(false),
                        backend: o
                            .require("backend")?
                            .as_str()
                            .unwrap_or("unknown")
                            .to_string(),
                        batch_size: o.require("batch_size")?.as_usize().unwrap_or(1),
                        residual: o.require("residual")?.as_f64().unwrap_or(f64::NAN),
                        total_secs: o.require("total_secs")?.as_f64().unwrap_or(0.0),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(Trace { jobs, outcomes })
    }

    /// Write pretty JSON to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().emit_pretty())
            .map_err(|e| EbvError::io(format!("write trace {}", path.display()), e))
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| EbvError::io(format!("read trace {}", path.display()), e))?;
        Trace::from_json(&Json::parse(&text)?)
    }

    /// Summary statistics of the recorded outcomes.
    pub fn summary(&self) -> String {
        let n = self.outcomes.len();
        if n == 0 {
            return format!("{} jobs, no outcomes recorded", self.jobs.len());
        }
        let ok = self.outcomes.iter().filter(|o| o.ok).count();
        let mean_lat =
            self.outcomes.iter().map(|o| o.total_secs).sum::<f64>() / n as f64;
        let mean_batch =
            self.outcomes.iter().map(|o| o.batch_size).sum::<usize>() as f64 / n as f64;
        format!(
            "{} jobs, {ok}/{n} ok, mean latency {:.3} ms, mean batch {:.2}",
            self.jobs.len(),
            mean_lat * 1e3,
            mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceSpec};

    fn sample() -> Trace {
        let mut t = Trace::from_jobs(generate_trace(&TraceSpec {
            count: 10,
            ..Default::default()
        }));
        t.record(RecordedOutcome {
            id: 0,
            ok: true,
            backend: "native-ebv".into(),
            batch_size: 4,
            residual: 1e-12,
            total_secs: 0.004,
        });
        t
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let t = sample();
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn file_round_trip() {
        let t = sample();
        let dir = std::env::temp_dir().join("ebv_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
    }

    #[test]
    fn rejects_bad_versions_and_kinds() {
        assert!(Trace::from_json(&Json::parse(r#"{"version": 2, "jobs": []}"#).unwrap()).is_err());
        let bad = r#"{"version": 1, "jobs": [{"id": 0, "arrival": 0.0,
            "kind": "hexagonal", "n": 4, "seed": "1"}]}"#;
        assert!(Trace::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn summary_reports_rates() {
        let t = sample();
        let s = t.summary();
        assert!(s.contains("10 jobs"), "{s}");
        assert!(s.contains("1/1 ok"), "{s}");
        assert!(Trace::default().summary().contains("no outcomes"));
    }

    #[test]
    fn replayed_jobs_rebuild_identical_systems() {
        let t = sample();
        let back = Trace::from_json(&t.to_json()).unwrap();
        for (a, b) in t.jobs.iter().zip(back.jobs.iter()) {
            if a.kind == SystemKind::Dense {
                let (ma, _) = a.dense_system();
                let (mb, _) = b.dense_system();
                assert_eq!(ma, mb);
            }
        }
    }
}
