//! Request/response types of the solve service.

use std::sync::Arc;
use std::time::Instant;

use crate::matrix::{CsrMatrix, DenseMatrix};

/// The linear system carried by a request. Matrices are `Arc`-shared so
/// batched requests against the same system don't copy it.
#[derive(Debug, Clone)]
pub enum Payload {
    Dense { a: Arc<DenseMatrix>, b: Vec<f64> },
    Sparse { a: Arc<CsrMatrix>, b: Vec<f64> },
}

impl Payload {
    /// System size.
    pub fn n(&self) -> usize {
        match self {
            Payload::Dense { a, .. } => a.rows(),
            Payload::Sparse { a, .. } => a.rows(),
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Payload::Dense { .. })
    }

    /// RHS access.
    pub fn rhs(&self) -> &[f64] {
        match self {
            Payload::Dense { b, .. } => b,
            Payload::Sparse { b, .. } => b,
        }
    }

    /// ∞-norm residual of a candidate solution.
    pub fn residual(&self, x: &[f64]) -> f64 {
        match self {
            Payload::Dense { a, b } => a.residual(x, b),
            Payload::Sparse { a, b } => a.residual(x, b),
        }
    }
}

/// A solve request.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub id: u64,
    pub payload: Payload,
    /// Identifies the coefficient matrix across requests: requests with
    /// equal keys share `A` and are batched into one factorization.
    /// `None` disables batching for this request. Wire-layer requests
    /// get this auto-populated with a streaming content fingerprint
    /// (see `wire::fingerprint`), so remote repeat traffic coalesces
    /// without clients choosing keys.
    pub matrix_key: Option<u64>,
    /// Identifies the *sparsity pattern* of a sparse coefficient matrix
    /// independently of its values (`wire::fingerprint_csr_pattern`).
    /// When the value-keyed factor cache misses, a matching cached
    /// symbolic analysis under this key skips straight to the
    /// level-parallel numeric refactorization. `None` (the default for
    /// in-process constructors) disables symbolic reuse only — the
    /// request still solves and still caches its full factors.
    pub pattern_key: Option<u64>,
    pub submitted_at: Instant,
}

impl SolveRequest {
    pub fn dense(id: u64, a: Arc<DenseMatrix>, b: Vec<f64>, matrix_key: Option<u64>) -> Self {
        SolveRequest {
            id,
            payload: Payload::Dense { a, b },
            matrix_key,
            pattern_key: None,
            submitted_at: Instant::now(),
        }
    }

    pub fn sparse(id: u64, a: Arc<CsrMatrix>, b: Vec<f64>, matrix_key: Option<u64>) -> Self {
        SolveRequest {
            id,
            payload: Payload::Sparse { a, b },
            matrix_key,
            pattern_key: None,
            submitted_at: Instant::now(),
        }
    }

    /// Attach a sparsity-pattern key (sparse requests; the wire layer
    /// populates it from the structure fingerprint).
    pub fn with_pattern_key(mut self, pattern_key: Option<u64>) -> Self {
        self.pattern_key = pattern_key;
        self
    }
}

/// Phase timing of one served request.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Timings {
    /// submit → dequeue by the batcher.
    pub queue_secs: f64,
    /// dequeue → batch flush (batching window share).
    pub batch_secs: f64,
    /// execution (factor amortized + solve).
    pub exec_secs: f64,
}

impl Timings {
    pub fn total(&self) -> f64 {
        self.queue_secs + self.batch_secs + self.exec_secs
    }
}

/// A solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub id: u64,
    /// The solution, or the error message if the solve failed.
    pub result: std::result::Result<Vec<f64>, String>,
    /// ∞-norm residual of the returned solution (NaN on failure).
    pub residual: f64,
    /// Which backend served it (router decision).
    pub backend: &'static str,
    /// Requests that shared the factorization with this one.
    pub batch_size: usize,
    pub timings: Timings,
    /// Span timeline of the worker execution that served this request
    /// (`None` unless the service ran with profiling on). Batched
    /// requests share the batch's timeline.
    pub trace: Option<crate::obs::SolveTrace>,
}

impl SolveResponse {
    /// Whether the solve succeeded.
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    pub fn failed(id: u64, err: String, backend: &'static str) -> Self {
        SolveResponse {
            id,
            result: Err(err),
            residual: f64::NAN,
            backend,
            batch_size: 1,
            timings: Timings::default(),
            trace: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, GenSeed};

    #[test]
    fn payload_accessors() {
        let a = Arc::new(diag_dominant_dense(8, GenSeed(1)));
        let p = Payload::Dense { a: a.clone(), b: vec![1.0; 8] };
        assert_eq!(p.n(), 8);
        assert!(p.is_dense());
        assert_eq!(p.rhs().len(), 8);
    }

    #[test]
    fn residual_uses_underlying_matrix() {
        let a = Arc::new(DenseMatrix::identity(3));
        let p = Payload::Dense { a, b: vec![1.0, 2.0, 3.0] };
        assert_eq!(p.residual(&[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(p.residual(&[0.0, 2.0, 3.0]), 1.0);
    }

    #[test]
    fn response_ok_accessor() {
        let failed = SolveResponse::failed(1, "boom".into(), "native-ebv");
        assert!(!failed.is_ok());
        assert!(failed.residual.is_nan());
    }

    #[test]
    fn timings_total() {
        let t = Timings { queue_secs: 1.0, batch_secs: 2.0, exec_secs: 3.0 };
        assert_eq!(t.total(), 6.0);
    }

    use crate::matrix::DenseMatrix;
}
