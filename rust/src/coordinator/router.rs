//! Backend routing: which engine serves a request.
//!
//! Dense systems with a compiled PJRT artifact (and `use_runtime = true`)
//! go to the JAX/Pallas path; other dense systems to the native EBV
//! lanes; sparse systems to the sparse LU engine. The router is pure and
//! unit-testable; the service applies its decisions.

use std::collections::BTreeSet;

use crate::coordinator::request::Payload;

/// Execution backend for one batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native multithreaded EBV LU (dense).
    NativeEbv,
    /// Native sparse LU with level-scheduled solves.
    NativeSparse,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Pjrt,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::NativeEbv => "native-ebv",
            Backend::NativeSparse => "native-sparse",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Routing table.
#[derive(Debug, Clone, Default)]
pub struct Router {
    /// Dense sizes with a compiled `lu_solve` artifact.
    runtime_sizes: BTreeSet<usize>,
    /// Whether the PJRT path is enabled at all.
    use_runtime: bool,
}

impl Router {
    pub fn new(use_runtime: bool, runtime_sizes: impl IntoIterator<Item = usize>) -> Router {
        Router { runtime_sizes: runtime_sizes.into_iter().collect(), use_runtime }
    }

    /// Decide the backend for a payload.
    pub fn route(&self, payload: &Payload) -> Backend {
        match payload {
            Payload::Sparse { .. } => Backend::NativeSparse,
            Payload::Dense { a, .. } => {
                if self.use_runtime && self.runtime_sizes.contains(&a.rows()) {
                    Backend::Pjrt
                } else {
                    Backend::NativeEbv
                }
            }
        }
    }

    pub fn runtime_sizes(&self) -> impl Iterator<Item = usize> + '_ {
        self.runtime_sizes.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::generate::{diag_dominant_dense, diag_dominant_sparse, GenSeed};
    use std::sync::Arc;

    fn dense(n: usize) -> Payload {
        Payload::Dense { a: Arc::new(diag_dominant_dense(n, GenSeed(1))), b: vec![0.0; n] }
    }

    fn sparse(n: usize) -> Payload {
        Payload::Sparse { a: Arc::new(diag_dominant_sparse(n, 3, GenSeed(1))), b: vec![0.0; n] }
    }

    #[test]
    fn sparse_always_goes_native() {
        let r = Router::new(true, [64]);
        assert_eq!(r.route(&sparse(64)), Backend::NativeSparse);
    }

    #[test]
    fn dense_with_artifact_goes_pjrt() {
        let r = Router::new(true, [64, 128]);
        assert_eq!(r.route(&dense(64)), Backend::Pjrt);
        assert_eq!(r.route(&dense(65)), Backend::NativeEbv);
    }

    #[test]
    fn runtime_disabled_forces_native() {
        let r = Router::new(false, [64]);
        assert_eq!(r.route(&dense(64)), Backend::NativeEbv);
    }

    #[test]
    fn backend_names_are_stable() {
        assert_eq!(Backend::NativeEbv.as_str(), "native-ebv");
        assert_eq!(Backend::NativeSparse.as_str(), "native-sparse");
        assert_eq!(Backend::Pjrt.as_str(), "pjrt");
    }
}
