//! Service metrics: counters and latency histogram, lock-shared between
//! the service threads and whoever reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed-bucket latency histogram (log-spaced, 1 µs … 100 s).
#[derive(Debug)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    /// Sum of all observations (for mean), in nanoseconds.
    sum_ns: AtomicU64,
    total: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs, ~3.16µs, 10µs, ..., 100s (log10 half-decades).
        let mut bounds = Vec::new();
        let mut b = 1e-6f64;
        while b <= 100.0 {
            bounds.push(b);
            bounds.push(b * 3.1622776601683795);
            b *= 10.0;
        }
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        LatencyHistogram { bounds, counts, sum_ns: AtomicU64::new(0), total: AtomicU64::new(0) }
    }
}

impl LatencyHistogram {
    pub fn observe(&self, secs: f64) {
        let idx = self.bounds.partition_point(|&b| b < secs);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / n as f64
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket containing the q-th observation).
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
            }
        }
        f64::INFINITY
    }
}

/// Point-in-time copy of the service counters, detached from the
/// atomics so it can be carried in wire frames and compared in tests.
/// Produced by [`ServiceMetrics::snapshot`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub factor_hits: u64,
    pub factor_misses: u64,
    /// Sparse solves that skipped symbolic analysis because the request
    /// pattern matched a cached `SparseSymbolic` (full-factor cache
    /// missed, structure cache hit).
    pub symbolic_reuse: u64,
    /// Sparse factorizations executed as level-parallel numeric sweeps
    /// over a symbolic analysis (fresh or reused).
    pub numeric_refactor: u64,
    pub mean_batch: f64,
    pub lat_mean_s: f64,
    pub lat_p50_s: f64,
    pub lat_p99_s: f64,
    /// Lane-engine counters (resident lanes, pooled jobs, barrier-
    /// separated steps, lane-barrier crossings). Zero until merged by
    /// [`ServiceHandle::metrics_snapshot`](crate::coordinator::ServiceHandle::metrics_snapshot)
    /// — `ServiceMetrics` itself has no engine reference.
    pub engine_lanes: u64,
    pub engine_jobs: u64,
    pub engine_steps: u64,
    pub engine_barrier_waits: u64,
    /// Effective blocked-factorization panel width the workers run
    /// (`service.panel_width`). Zero until a
    /// [`ServiceHandle::metrics_snapshot`](crate::coordinator::ServiceHandle::metrics_snapshot)
    /// fills it in — `ServiceMetrics` itself has no solver config.
    pub panel_width: u64,
    /// Resolved trailing-update microkernel the workers dispatch
    /// (`service.kernel` with `auto` collapsed — never `Auto` once a
    /// service handle fills it in; `Auto` until then, like
    /// `panel_width`'s zero).
    pub kernel: crate::solver::Kernel,
    /// Lane scheduling discipline the workers run (`service.schedule`:
    /// barrier-stepped or dependency-counted dataflow). `Barrier` until
    /// a service handle fills it in, like `kernel`'s `Auto`.
    pub schedule: crate::exec::Schedule,
    /// Device shards of the two-level runtime (`service.devices`;
    /// 1 = flat engine). Like the engine fields, zero until a
    /// service handle merges its device-set stats in.
    pub devices: u64,
    /// Resident lanes per device engine (0 when running flat).
    pub device_lanes: u64,
    /// Device-sharded jobs executed across the set.
    pub device_jobs: u64,
    /// Exchange stages executed (one per sharded step).
    pub exchange_steps: u64,
    /// `f64` elements broadcast through the staged exchange (×8 for
    /// bytes) — the measured counterpart of the cost model's
    /// interconnect term.
    pub exchange_elems: u64,
    /// Dense solves observed by the per-frame-class latency histogram.
    pub dense_solves: u64,
    /// Sparse solves observed by the per-frame-class latency histogram.
    pub sparse_solves: u64,
    pub dense_lat_mean_s: f64,
    pub dense_lat_p99_s: f64,
    pub sparse_lat_mean_s: f64,
    pub sparse_lat_p99_s: f64,
    /// Measured lane profiler accumulators (`obs` subsystem): summed
    /// per-lane compute ns and barrier-wait ns of the flat engine, and
    /// the number of jobs profiled into them. Zero unless the service
    /// ran with profiling on (`service.profiling` / `--profile`).
    pub busy_ns: u64,
    pub wait_ns: u64,
    pub profiled_jobs: u64,
    /// Measured max/mean imbalance of per-lane busy time — the runtime
    /// counterpart of the `FactorPlan` predicted imbalance, computed by
    /// the same `max_mean_imbalance` statistic. `1.0` when nothing was
    /// profiled.
    pub measured_imbalance: f64,
    /// Summed per-device compute ns of the sharded runtime (profiling
    /// on and `devices > 1` only).
    pub device_busy_ns: u64,
    /// Nanoseconds spent in the exchange phase of sharded jobs
    /// (profiling on only).
    pub exchange_ns: u64,
    /// Measured max/mean imbalance of per-device busy time — the
    /// runtime counterpart of `DevicePlan::device_imbalance`. `1.0`
    /// when nothing was profiled.
    pub device_measured_imbalance: f64,
    /// Wire sessions ever opened against this service (stdio counts as
    /// one). Zero for services never fronted by `wire`.
    pub sessions_total: u64,
    /// Sessions currently open (`opened - closed` at snapshot time).
    pub active_sessions: u64,
    /// High-water mark of concurrently open sessions.
    pub peak_sessions: u64,
    /// Connections refused by admission control (`busy` error frame
    /// written, socket dropped) because `max_sessions` were active.
    pub sessions_shed: u64,
    /// Request frames decoded across all sessions (every line that
    /// produced a request, valid or not — decode failures count too,
    /// they consumed a frame slot).
    pub wire_frames: u64,
    /// Solve requests among `wire_frames` that reached the coordinator.
    pub wire_solves: u64,
    /// Error frames written across all sessions (any [`ErrorCode`]
    /// class — see `docs/PROTOCOL.md` §Error frames).
    ///
    /// [`ErrorCode`]: crate::wire::ErrorCode
    pub wire_errors: u64,
    /// Nanoseconds spent decoding request frames (wire `Ingest` spans),
    /// summed across sessions. Zero unless profiling is on.
    pub wire_ingest_ns: u64,
    /// Nanoseconds spent encoding response frames (wire `Encode`
    /// spans), summed across sessions. Zero unless profiling is on.
    pub wire_encode_ns: u64,
    /// Sessions that negotiated the binary frame encoding
    /// (`accept_binary` — see `docs/PROTOCOL.md` §Binary frames).
    pub binary_sessions: u64,
    /// Transport bytes read from peers across all sessions, both
    /// formats (discarded oversized payloads count — they were
    /// consumed).
    pub wire_bytes_in: u64,
    /// Transport bytes written to peers across all sessions, both
    /// formats.
    pub wire_bytes_out: u64,
}

/// All service-level metrics.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
    /// Factor-cache hits/misses in the workers.
    pub factor_hits: AtomicU64,
    pub factor_misses: AtomicU64,
    /// Sparse symbolic/numeric split counters (see [`MetricsSnapshot`]).
    pub symbolic_reuse: AtomicU64,
    pub numeric_refactor: AtomicU64,
    /// Serving-edge counters (see the `sessions_*`/`wire_*` snapshot
    /// fields). Bumped by `wire::server`/`wire::listener`; zero for
    /// services never fronted by the wire layer.
    pub sessions_opened: AtomicU64,
    pub sessions_closed: AtomicU64,
    pub peak_sessions: AtomicU64,
    pub sessions_shed: AtomicU64,
    pub wire_frames: AtomicU64,
    pub wire_solves: AtomicU64,
    pub wire_errors: AtomicU64,
    pub wire_ingest_ns: AtomicU64,
    pub wire_encode_ns: AtomicU64,
    /// Sessions that latched the binary encoding (bumped once per
    /// session by `wire::server` at negotiation time).
    pub binary_sessions: AtomicU64,
    pub wire_bytes_in: AtomicU64,
    pub wire_bytes_out: AtomicU64,
    pub latency: LatencyHistogram,
    /// Per-frame-class latency histograms (dense vs sparse solves) —
    /// the all-traffic `latency` histogram stays authoritative for the
    /// headline quantiles.
    pub dense_latency: LatencyHistogram,
    pub sparse_latency: LatencyHistogram,
    /// Per-backend completion counts.
    backend_counts: Mutex<Vec<(&'static str, u64)>>,
}

impl ServiceMetrics {
    pub fn record_backend(&self, backend: &'static str) {
        let mut v = self.backend_counts.lock().expect("metrics lock");
        if let Some(slot) = v.iter_mut().find(|(b, _)| *b == backend) {
            slot.1 += 1;
        } else {
            v.push((backend, 1));
        }
    }

    pub fn backend_counts(&self) -> Vec<(&'static str, u64)> {
        self.backend_counts.lock().expect("metrics lock").clone()
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Snapshot the counters (individually `Relaxed`-loaded; a snapshot
    /// taken under traffic is approximate, like any metrics scrape).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            factor_hits: self.factor_hits.load(Ordering::Relaxed),
            factor_misses: self.factor_misses.load(Ordering::Relaxed),
            symbolic_reuse: self.symbolic_reuse.load(Ordering::Relaxed),
            numeric_refactor: self.numeric_refactor.load(Ordering::Relaxed),
            mean_batch: self.mean_batch_size(),
            lat_mean_s: self.latency.mean(),
            lat_p50_s: self.latency.quantile(0.5),
            lat_p99_s: self.latency.quantile(0.99),
            engine_lanes: 0,
            engine_jobs: 0,
            engine_steps: 0,
            engine_barrier_waits: 0,
            panel_width: 0,
            kernel: crate::solver::Kernel::Auto,
            schedule: crate::exec::Schedule::Barrier,
            devices: 0,
            device_lanes: 0,
            device_jobs: 0,
            exchange_steps: 0,
            exchange_elems: 0,
            dense_solves: self.dense_latency.count(),
            sparse_solves: self.sparse_latency.count(),
            dense_lat_mean_s: self.dense_latency.mean(),
            dense_lat_p99_s: self.dense_latency.quantile(0.99),
            sparse_lat_mean_s: self.sparse_latency.mean(),
            sparse_lat_p99_s: self.sparse_latency.quantile(0.99),
            busy_ns: 0,
            wait_ns: 0,
            profiled_jobs: 0,
            measured_imbalance: 0.0,
            device_busy_ns: 0,
            exchange_ns: 0,
            device_measured_imbalance: 0.0,
            sessions_total: self.sessions_opened.load(Ordering::Relaxed),
            active_sessions: self
                .sessions_opened
                .load(Ordering::Relaxed)
                .saturating_sub(self.sessions_closed.load(Ordering::Relaxed)),
            peak_sessions: self.peak_sessions.load(Ordering::Relaxed),
            sessions_shed: self.sessions_shed.load(Ordering::Relaxed),
            wire_frames: self.wire_frames.load(Ordering::Relaxed),
            wire_solves: self.wire_solves.load(Ordering::Relaxed),
            wire_errors: self.wire_errors.load(Ordering::Relaxed),
            wire_ingest_ns: self.wire_ingest_ns.load(Ordering::Relaxed),
            wire_encode_ns: self.wire_encode_ns.load(Ordering::Relaxed),
            binary_sessions: self.binary_sessions.load(Ordering::Relaxed),
            wire_bytes_in: self.wire_bytes_in.load(Ordering::Relaxed),
            wire_bytes_out: self.wire_bytes_out.load(Ordering::Relaxed),
        }
    }

    /// Record a session opening: bumps `sessions_opened` and maintains
    /// the concurrent high-water mark. Pair with [`session_closed`].
    ///
    /// [`session_closed`]: ServiceMetrics::session_closed
    pub fn session_opened(&self) {
        let opened = self.sessions_opened.fetch_add(1, Ordering::Relaxed) + 1;
        let active = opened.saturating_sub(self.sessions_closed.load(Ordering::Relaxed));
        self.peak_sessions.fetch_max(active, Ordering::Relaxed);
    }

    /// Record a session closing and fold its frame/solve/error/byte
    /// counts into the service-wide wire totals.
    pub fn session_closed(
        &self,
        frames: u64,
        solves: u64,
        errors: u64,
        bytes_in: u64,
        bytes_out: u64,
    ) {
        self.sessions_closed.fetch_add(1, Ordering::Relaxed);
        self.wire_frames.fetch_add(frames, Ordering::Relaxed);
        self.wire_solves.fetch_add(solves, Ordering::Relaxed);
        self.wire_errors.fetch_add(errors, Ordering::Relaxed);
        self.wire_bytes_in.fetch_add(bytes_in, Ordering::Relaxed);
        self.wire_bytes_out.fetch_add(bytes_out, Ordering::Relaxed);
    }

    /// Fold a lane-engine snapshot into a metrics snapshot (the service
    /// handle does this; standalone `ServiceMetrics` users report zeros).
    pub fn merge_engine(
        mut snap: MetricsSnapshot,
        engine: crate::exec::EngineStatsSnapshot,
    ) -> MetricsSnapshot {
        snap.engine_lanes = engine.lanes;
        snap.engine_jobs = engine.jobs;
        snap.engine_steps = engine.steps;
        snap.engine_barrier_waits = engine.barrier_waits;
        snap.busy_ns = engine.busy_ns;
        snap.wait_ns = engine.wait_ns;
        snap.profiled_jobs = engine.profiled_jobs;
        snap
    }

    /// Fold the measured lane-profile imbalance in (the service handle
    /// does this from the engine's [`LaneProfile`](crate::obs::LaneProfile)
    /// snapshot — the per-lane vector never travels in the scalar-only
    /// engine snapshot).
    pub fn merge_lane_profile(
        mut snap: MetricsSnapshot,
        profile: &crate::obs::LaneProfileSnapshot,
    ) -> MetricsSnapshot {
        snap.measured_imbalance = profile.measured_imbalance();
        snap
    }

    /// Fold a device-set snapshot into a metrics snapshot (the service
    /// handle does this when `service.devices > 1`; a flat service
    /// reports `devices = 1` with the per-device fields zero).
    pub fn merge_devices(
        mut snap: MetricsSnapshot,
        devices: crate::exec::DeviceSetSnapshot,
    ) -> MetricsSnapshot {
        snap.devices = devices.devices;
        snap.device_lanes = devices.lanes_per_device;
        snap.device_jobs = devices.sharded_jobs;
        snap.exchange_steps = devices.exchange_steps;
        snap.exchange_elems = devices.exchange_elems;
        snap.device_busy_ns = devices.busy_ns;
        snap.exchange_ns = devices.exchange_ns;
        snap
    }

    /// One-line human summary for service logs and examples.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} rejected={} completed={} failed={} batches={} mean_batch={:.2} \
             factor_hit_rate={:.0}% symbolic_reuse={} lat_mean={:.3}ms lat_p50={:.3}ms \
             lat_p99={:.3}ms",
            self.submitted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            {
                let h = self.factor_hits.load(Ordering::Relaxed);
                let m = self.factor_misses.load(Ordering::Relaxed);
                if h + m == 0 { 0.0 } else { 100.0 * h as f64 / (h + m) as f64 }
            },
            self.symbolic_reuse.load(Ordering::Relaxed),
            self.latency.mean() * 1e3,
            self.latency.quantile(0.5) * 1e3,
            self.latency.quantile(0.99) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::default();
        for _ in 0..90 {
            h.observe(1e-3);
        }
        for _ in 0..10 {
            h.observe(1.0);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - (90.0 * 1e-3 + 10.0) / 100.0).abs() < 1e-6);
        assert!(h.quantile(0.5) <= 1e-3 * 1.01);
        assert!(h.quantile(0.95) >= 0.9);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.99), 0.0);
    }

    #[test]
    fn backend_counters_accumulate() {
        let m = ServiceMetrics::default();
        m.record_backend("ebv");
        m.record_backend("ebv");
        m.record_backend("pjrt");
        let counts = m.backend_counts();
        assert!(counts.contains(&("ebv", 2)));
        assert!(counts.contains(&("pjrt", 1)));
    }

    #[test]
    fn snapshot_copies_counters() {
        let m = ServiceMetrics::default();
        m.submitted.store(7, Ordering::Relaxed);
        m.factor_hits.store(3, Ordering::Relaxed);
        m.factor_misses.store(1, Ordering::Relaxed);
        m.symbolic_reuse.store(2, Ordering::Relaxed);
        m.numeric_refactor.store(4, Ordering::Relaxed);
        m.latency.observe(1e-3);
        let s = m.snapshot();
        assert_eq!(s.submitted, 7);
        assert_eq!(s.factor_hits, 3);
        assert_eq!(s.factor_misses, 1);
        assert_eq!(s.symbolic_reuse, 2);
        assert_eq!(s.numeric_refactor, 4);
        assert!(s.lat_mean_s > 0.0);
        // Snapshots are detached: mutating the live metrics afterwards
        // does not change the copy.
        m.submitted.store(100, Ordering::Relaxed);
        assert_eq!(s.submitted, 7);
        // Engine fields are zero until a handle merges them in.
        assert_eq!(s.engine_lanes, 0);
        assert_eq!(s.engine_jobs, 0);
    }

    #[test]
    fn merge_engine_fills_engine_fields() {
        let m = ServiceMetrics::default();
        m.completed.store(3, Ordering::Relaxed);
        let e = crate::exec::EngineStatsSnapshot {
            lanes: 4,
            jobs: 9,
            inline_jobs: 2,
            steps: 120,
            barrier_waits: 480,
            slow_waits: 1,
            busy_ns: 7_000,
            wait_ns: 300,
            profiled_jobs: 6,
        };
        let s = ServiceMetrics::merge_engine(m.snapshot(), e);
        assert_eq!(s.completed, 3);
        assert_eq!(s.engine_lanes, 4);
        assert_eq!(s.engine_jobs, 9);
        assert_eq!(s.engine_steps, 120);
        assert_eq!(s.engine_barrier_waits, 480);
        assert_eq!(s.busy_ns, 7_000);
        assert_eq!(s.wait_ns, 300);
        assert_eq!(s.profiled_jobs, 6);
        // merge_engine only fills engine fields; the panel width and
        // kernel come from the service handle.
        assert_eq!(s.panel_width, 0);
        assert_eq!(s.kernel, crate::solver::Kernel::Auto);
        assert_eq!(s.schedule, crate::exec::Schedule::Barrier);
        assert_eq!(s.devices, 0, "device fields come from merge_devices");
    }

    #[test]
    fn merge_devices_fills_device_fields() {
        let m = ServiceMetrics::default();
        m.completed.store(2, Ordering::Relaxed);
        let d = crate::exec::DeviceSetSnapshot {
            devices: 4,
            lanes_per_device: 2,
            sharded_jobs: 5,
            exchange_steps: 300,
            exchange_elems: 12_000,
            busy_ns: 9_000,
            exchange_ns: 450,
        };
        let s = ServiceMetrics::merge_devices(m.snapshot(), d);
        assert_eq!(s.completed, 2);
        assert_eq!(s.devices, 4);
        assert_eq!(s.device_lanes, 2);
        assert_eq!(s.device_jobs, 5);
        assert_eq!(s.exchange_steps, 300);
        assert_eq!(s.exchange_elems, 12_000);
        assert_eq!(s.device_busy_ns, 9_000);
        assert_eq!(s.exchange_ns, 450);
        // merge_devices leaves the engine fields alone.
        assert_eq!(s.engine_lanes, 0);
    }

    #[test]
    fn single_observation_pins_every_quantile() {
        let h = LatencyHistogram::default();
        h.observe(2e-3);
        // One sample: every quantile resolves to that sample's bucket
        // bound (the half-decade above 1e-3).
        let bucket = h.quantile(0.5);
        assert!(bucket >= 2e-3 && bucket <= 1e-2, "{bucket}");
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), bucket, "q={q}");
        }
        assert!((h.mean() - 2e-3).abs() < 1e-9);
    }

    #[test]
    fn quantiles_are_monotone_in_q() {
        let h = LatencyHistogram::default();
        for i in 1..=200u32 {
            h.observe(i as f64 * 1e-4); // 0.1ms .. 20ms
        }
        let mut prev = 0.0;
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= prev, "q={q}: {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn bucket_edge_observations_land_in_their_bound() {
        let h = LatencyHistogram::default();
        // Exactly on a bucket bound: partition_point(|b| b < secs)
        // keeps the observation in the bucket whose bound equals it.
        h.observe(1e-3);
        assert_eq!(h.quantile(0.5), 1e-3);
        // Below the first bound and beyond the last both stay finite /
        // infinite as documented.
        let lo = LatencyHistogram::default();
        lo.observe(1e-9);
        assert_eq!(lo.quantile(0.5), 1e-6, "underflow clamps to the first bound");
        let hi = LatencyHistogram::default();
        hi.observe(1e4);
        assert_eq!(hi.quantile(0.5), f64::INFINITY, "overflow bucket has no bound");
    }

    #[test]
    fn mean_survives_concurrent_observes() {
        let h = std::sync::Arc::new(LatencyHistogram::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for _ in 0..250 {
                        h.observe(1e-3);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 1000);
        // All observations identical: the mean must be exact up to the
        // ns quantization of sum_ns, no lost updates.
        assert!((h.mean() - 1e-3).abs() < 1e-9, "{}", h.mean());
    }

    #[test]
    fn per_class_histograms_split_dense_and_sparse() {
        let m = ServiceMetrics::default();
        m.dense_latency.observe(1e-3);
        m.dense_latency.observe(1e-3);
        m.sparse_latency.observe(1e-2);
        let s = m.snapshot();
        assert_eq!(s.dense_solves, 2);
        assert_eq!(s.sparse_solves, 1);
        assert!((s.dense_lat_mean_s - 1e-3).abs() < 1e-9);
        assert!((s.sparse_lat_mean_s - 1e-2).abs() < 1e-9);
        assert!(s.dense_lat_p99_s > 0.0 && s.sparse_lat_p99_s > 0.0);
        // The headline histogram is separate: untouched here.
        assert_eq!(s.lat_mean_s, 0.0);
    }

    #[test]
    fn merge_lane_profile_fills_measured_imbalance() {
        let m = ServiceMetrics::default();
        let profile = crate::obs::LaneProfileSnapshot {
            busy_ns: vec![300, 100],
            wait_ns: vec![5, 205],
            jobs: 2,
        };
        let s = ServiceMetrics::merge_lane_profile(m.snapshot(), &profile);
        assert!((s.measured_imbalance - 1.5).abs() < 1e-12);
        // An unprofiled service reports the vacuous 1.0, mirroring the
        // FactorPlan convention for empty schedules.
        let s = ServiceMetrics::merge_lane_profile(
            m.snapshot(),
            &crate::obs::LaneProfileSnapshot::default(),
        );
        assert_eq!(s.measured_imbalance, 1.0);
    }

    #[test]
    fn session_counters_track_active_peak_and_fold_totals() {
        let m = ServiceMetrics::default();
        let s = m.snapshot();
        assert_eq!((s.sessions_total, s.active_sessions, s.peak_sessions), (0, 0, 0));
        m.session_opened();
        m.session_opened();
        m.session_opened();
        m.session_closed(10, 7, 1, 4096, 2048);
        let s = m.snapshot();
        assert_eq!(s.sessions_total, 3);
        assert_eq!(s.active_sessions, 2);
        assert_eq!(s.peak_sessions, 3);
        assert_eq!(s.wire_frames, 10);
        assert_eq!(s.wire_solves, 7);
        assert_eq!(s.wire_errors, 1);
        assert_eq!((s.wire_bytes_in, s.wire_bytes_out), (4096, 2048));
        m.session_closed(5, 5, 0, 100, 200);
        m.session_closed(1, 0, 1, 10, 20);
        m.sessions_shed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.active_sessions, 0);
        assert_eq!(s.peak_sessions, 3, "peak is a high-water mark, not current");
        assert_eq!(s.sessions_shed, 2);
        assert_eq!((s.wire_frames, s.wire_solves, s.wire_errors), (16, 12, 2));
        assert_eq!((s.wire_bytes_in, s.wire_bytes_out), (4206, 2268));
        assert_eq!(s.binary_sessions, 0, "negotiation is latched by the session loop");
        // Reopening after a drain keeps the peak monotone.
        m.session_opened();
        assert_eq!(m.snapshot().peak_sessions, 3);
    }

    #[test]
    fn summary_mentions_key_fields() {
        let m = ServiceMetrics::default();
        m.submitted.store(5, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        m.batched_requests.store(5, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=5"));
        assert!(s.contains("mean_batch=2.50"));
    }
}
